"""The fused dispatch kernel (decision + compaction + in-ring enqueue, one
program) against the composed three-program chain it replaced: bitwise
parity across backends and ring states (wraparound, overflow backpressure),
a hypothesis property over random shapes/thresholds/fills, the memoized
backend resolution, the single-launch steady-state tick contract, and the
pred-as-emitted-token equivalence (satellite of the same PR: the decision
kernel's argmax IS the greedy token, so no second logits pass exists)."""
import functools
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.runtime import scheduler as SCH
from repro.runtime import serve_loop as SL
from repro.runtime.scheduler import (ContinuousScheduler, LogicalClock,
                                     Request)

try:
    from hypothesis import given, settings, strategies as st_h
    _HAVE_HYP = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYP = False


# ---------------------------------------------------------------------------
# helpers: build a ring in an arbitrary state, run the composed chain the
# fused op replaced, compare pytrees bitwise
# ---------------------------------------------------------------------------

def _copy_tree(t):
    # jax.tree.map(lambda x: x, t) would alias the same buffers — a donated
    # call downstream would delete them. jnp.copy makes real copies.
    return jax.tree.map(jnp.copy, t)


def _assert_tree_equal(a, b, what=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def _mk_case(b, v, d, key):
    """Random (logits, sample_ids, payload pytree, row_spec) of width b."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    logits = jax.random.normal(k1, (b, v), jnp.float32) * 3.0
    payload = {
        "h": jax.random.normal(k2, (b, d), jnp.float32),
        "cache": {"sid": jax.random.randint(k3, (b, 1), 0, 97, jnp.int32)},
        "step": jax.random.randint(k4, (b,), 0, 31, jnp.int32),
    }
    sample_ids = jnp.arange(b, dtype=jnp.int32) * 3 + 1
    spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), payload)
    return logits, sample_ids, payload, spec


def _mk_ring(size, row_spec, head, count, key):
    """A ring pre-filled with junk rows/ids so untouched-slot parity is a
    real assertion, with arbitrary head/count cursors."""
    ring = SCH.ring_init(size, row_spec)

    def junk(d):
        if jnp.issubdtype(d.dtype, jnp.floating):
            return jax.random.normal(key, d.shape).astype(d.dtype)
        return jax.random.randint(key, d.shape, 0, 89).astype(d.dtype)

    ring["data"] = jax.tree.map(junk, ring["data"])
    ring["ids"] = jax.random.randint(key, (size,), -1, 50, jnp.int32)
    ring["head"] = jnp.asarray(head % size, jnp.int32)
    ring["count"] = jnp.asarray(count, jnp.int32)
    return ring


def _composed(logits, active, sample_ids, payload, ring, c_thr, backend):
    """The three-program chain fused_dispatch replaced: exit decision,
    per-leaf gather-compact, ranged ring enqueue clipped to free space.
    Operates on a COPY of the ring (the enqueue step donates its input)."""
    exit_mask, pred, conf = dispatch.exit_decision_op(logits, c_thr,
                                                      backend=backend)
    hard = ~exit_mask if active is None else active & ~exit_mask
    b = logits.shape[0]
    slab = jax.tree.map(
        lambda x: dispatch.gather_compact_op(x, hard, b, backend=backend)[0],
        payload)
    _, src, n_hard = dispatch.gather_compact_op(
        jnp.zeros((b, 1), jnp.float32), hard, b, backend=backend)
    slab_ids = jnp.where(src >= 0,
                         jnp.take(sample_ids, jnp.maximum(src, 0)), -1)
    size = ring["ids"].shape[0]
    n_enq = min(int(n_hard), size - int(ring["count"]))
    new = SCH._ring_enqueue_range(_copy_tree(ring), slab, slab_ids, 0, n_enq)
    return new, exit_mask, pred, conf, src, n_hard


def _check_parity(logits, active, sample_ids, payload, ring, c_thr, backend):
    got = dispatch.fused_dispatch_op(logits, active, sample_ids, payload,
                                     ring, c_thr, backend=backend,
                                     donate=False)
    want = _composed(logits, active, sample_ids, payload, ring, c_thr,
                     backend)
    g_ring, g_exit, g_pred, g_conf, g_src, g_nh = got
    w_ring, w_exit, w_pred, w_conf, w_src, w_nh = want
    np.testing.assert_array_equal(np.asarray(g_exit), np.asarray(w_exit))
    np.testing.assert_array_equal(np.asarray(g_pred), np.asarray(w_pred))
    np.testing.assert_array_equal(np.asarray(g_conf), np.asarray(w_conf))
    np.testing.assert_array_equal(np.asarray(g_src), np.asarray(w_src))
    assert int(g_nh) == int(w_nh)
    _assert_tree_equal(g_ring, w_ring, what=f"ring state ({backend})")


# ---------------------------------------------------------------------------
# bitwise parity: fused vs composed, per backend, across ring states
# ---------------------------------------------------------------------------

# (size, head, count): empty, wrapping tail, and nearly-full (the enqueue
# overflows free space and must leave rows [free, n_hard) unwritten)
_RING_STATES = [(24, 0, 0), (24, 20, 5), (24, 7, 21), (8, 3, 8)]


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("size,head,count", _RING_STATES)
def test_fused_dispatch_parity(backend, size, head, count):
    key = jax.random.PRNGKey(size * 7 + head * 3 + count)
    logits, sample_ids, payload, spec = _mk_case(16, 32, 8, key)
    b = logits.shape[0]
    mask = jax.random.bernoulli(jax.random.fold_in(key, 9), 0.7, (b,))
    for c_thr in (0.0, 0.6, 1.1):
        for active in (None, mask):
            ring = _mk_ring(size, spec, head, count,
                            jax.random.fold_in(key, 5))
            _check_parity(logits, active, sample_ids, payload, ring, c_thr,
                          backend)


def test_fused_dispatch_does_not_mutate_input_without_donation():
    key = jax.random.PRNGKey(0)
    logits, sample_ids, payload, spec = _mk_case(8, 16, 4, key)
    ring = _mk_ring(12, spec, 2, 3, jax.random.fold_in(key, 1))
    before = _copy_tree(ring)
    dispatch.fused_dispatch_op(logits, None, sample_ids, payload, ring, 1.1,
                               backend="ref", donate=False)
    _assert_tree_equal(ring, before, what="donate=False input ring")


# ---------------------------------------------------------------------------
# hypothesis property: fused ≡ composed over random shapes / thresholds /
# hard fractions / ring fill levels (wraparound and overflow included by
# drawing head and count freely) — satellite 3
# ---------------------------------------------------------------------------

if _HAVE_HYP:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st_h.integers(1, 12),
        v=st_h.integers(2, 40),
        d=st_h.integers(1, 6),
        size=st_h.integers(2, 10),
        head=st_h.integers(0, 30),
        fill_pct=st_h.integers(0, 100),
        c_thr=st_h.floats(0.0, 1.2),
        use_active=st_h.booleans(),
        seed=st_h.integers(0, 2 ** 16),
    )
    def test_fused_equals_composed_property(b, v, d, size, head, fill_pct,
                                            c_thr, use_active, seed):
        key = jax.random.PRNGKey(seed)
        logits, sample_ids, payload, spec = _mk_case(b, v, d, key)
        active = (jax.random.bernoulli(jax.random.fold_in(key, 11), 0.6,
                                       (b,)) if use_active else None)
        count = (size * fill_pct) // 100
        ring = _mk_ring(size, spec, head, count, jax.random.fold_in(key, 13))
        _check_parity(logits, active, sample_ids, payload, ring, c_thr,
                      "ref")


# ---------------------------------------------------------------------------
# memoized backend resolution (satellite 2): override precedence, cache
# invalidation, live env var
# ---------------------------------------------------------------------------

@pytest.fixture
def clean_backend(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    dispatch.set_backend(None)
    yield monkeypatch
    dispatch.set_backend(None)


def test_kernel_backend_precedence(clean_backend):
    monkeypatch = clean_backend
    auto = dispatch.kernel_backend()
    assert auto == ("pallas" if jax.default_backend() == "tpu" else "ref")
    # env var beats auto
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert dispatch.kernel_backend() == "interpret"
    # set_backend beats env
    dispatch.set_backend("ref")
    assert dispatch.kernel_backend() == "ref"
    # explicit argument beats everything
    assert dispatch.kernel_backend("interpret") == "interpret"
    # restoring the override re-exposes the env var
    dispatch.set_backend(None)
    assert dispatch.kernel_backend() == "interpret"


def test_kernel_backend_memoized_and_invalidated(clean_backend):
    calls = {"n": 0}

    def probed():
        calls["n"] += 1
        return False

    clean_backend.setattr(dispatch, "_on_tpu", probed)
    dispatch.set_backend(None)                      # clear the cache
    assert dispatch.kernel_backend() == "ref"
    assert dispatch.kernel_backend() == "ref"
    assert calls["n"] == 1                          # resolution memoized
    assert (None, None, None) in dispatch._resolve_cache
    dispatch.set_backend(None)
    assert not dispatch._resolve_cache              # invalidated
    assert dispatch.kernel_backend() == "ref"
    assert calls["n"] == 2                          # re-probed once


def test_kernel_backend_pallas_degrades_off_tpu(clean_backend):
    clean_backend.setattr(dispatch, "_on_tpu", lambda: False)
    dispatch.set_backend(None)
    assert dispatch.kernel_backend("pallas") == "interpret"


def test_kernel_backend_rejects_unknown(clean_backend):
    with pytest.raises(ValueError):
        dispatch.set_backend("bogus")
    clean_backend.setenv("REPRO_KERNEL_BACKEND", "bogus")
    with pytest.raises(ValueError):
        dispatch.kernel_backend()
    with pytest.raises(ValueError):
        dispatch.kernel_backend("nope")


# ---------------------------------------------------------------------------
# satellite 1: the decision kernel's pred IS the greedy token — bitwise equal
# to jnp.argmax of the exit logits, first-occurrence tie-break included
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_pred_matches_argmax_bitwise(backend):
    key = jax.random.PRNGKey(17)
    x = jax.random.normal(key, (8, 33), jnp.float32) * 4.0
    # force ties: column 5 equals each row's max, so first-occurrence
    # tie-breaking is what distinguishes a correct pred from a plausible one
    x = x.at[:, 5].set(x.max(axis=-1))
    _, pred, _ = dispatch.exit_decision_op(x, 0.5, backend=backend)
    np.testing.assert_array_equal(
        np.asarray(pred),
        np.asarray(jnp.argmax(x, axis=-1).astype(jnp.int32)))


# ---------------------------------------------------------------------------
# toy-fns scheduler runs: fused vs composed token-stream + stats parity, and
# the single-launch steady-state tick contract
# ---------------------------------------------------------------------------

_TOY_VOCAB = 32
_TOY_S = 4


def _toy_tok(sid, t):
    return (3 + sid * 31 + t * 7) % _TOY_VOCAB


def _toy_hard(sid, t, q_pct):
    return ((sid * 131 + t * 17) % 100) < q_pct


def _toy_decode_fns(q_pct: int, trace_counter=None):
    """Analytic DecodeFns (same construction as test_scheduler's): exit
    decisions and greedy tokens are pure functions of (sample id, decode
    index). ``trace_counter`` counts s1_raw TRACES (not executions) — the
    single-program assertion below."""

    def _logits(sid, t):
        tok = _toy_tok(sid, t)
        hard = _toy_hard(sid, t, q_pct)
        oh = jax.nn.one_hot(tok, _TOY_VOCAB)
        return jnp.where(hard[:, None], oh * 1e-3, oh * 50.0)

    def prefill(prompts, max_len):
        sid = prompts[:, 0].astype(jnp.int32)
        caches = {"first": [sid[:, None]], "blocks": (), "rem": []}
        return _logits(sid, jnp.zeros_like(sid)), caches

    def split(caches):
        return caches, {"sid": caches["first"][0]}

    def s1_raw(tok, c1, pos):
        if trace_counter is not None:
            trace_counter["n"] += 1          # runs at trace time only
        sid = c1["first"][0][:, 0]
        t = pos - _TOY_S + 1
        h = jnp.stack([sid, pos], 1).astype(jnp.float32)
        return h, c1, _logits(sid, t)

    def s2(h_rows, cache_rows, step):
        sid = cache_rows["sid"][:, 0]
        return _logits(sid, step - _TOY_S + 1), cache_rows

    return SL.DecodeFns(prefill, split, jax.jit(s1_raw), s2, s1_raw)


def _toy_run(q_pct, n_toks, *, n_slots, capacity, queue_depth):
    fns = _toy_decode_fns(q_pct)
    sc = SL.ServeConfig(capacity=capacity, queue_depth=queue_depth,
                        c_thr=0.5)
    sched = ContinuousScheduler(fns, sc, n_slots=n_slots,
                                max_len=_TOY_S + max(n_toks),
                                clock=LogicalClock())
    for i, n in enumerate(n_toks):
        sched.submit(Request(sample_id=i,
                             prompt=np.full((_TOY_S,), i, np.int32),
                             n_tokens=n))
    return sched.run(), sched.stats


@pytest.mark.parametrize("q_pct", [40, 100])
def test_fused_vs_composed_streams_and_stats(q_pct):
    """Same trace through the fused single-launch tick and the composed
    three-program tick: identical per-sample token streams AND identical
    serving counters — incl. n_stalls, so the fused overflow spill enters
    backpressure exactly where the composed chain would (q=100 with a
    2-row ring under a 6-slot pool overflows every tick)."""
    n_toks = [5, 3, 6, 1, 4, 5]
    with mock.patch.object(ContinuousScheduler, "_use_fused",
                           lambda self: False):
        res_c, st_c = _toy_run(q_pct, n_toks, n_slots=6, capacity=2,
                               queue_depth=1)
    res_f, st_f = _toy_run(q_pct, n_toks, n_slots=6, capacity=2,
                           queue_depth=1)
    expect = {i: [_toy_tok(i, t) for t in range(n)]
              for i, n in enumerate(n_toks)}
    assert res_f == expect
    assert res_c == expect
    for fld in ("n_decisions", "n_exited", "n_stage2", "n_stalls",
                "n_stage1_batches", "n_buckets"):
        assert getattr(st_f, fld) == getattr(st_c, fld), fld
    if q_pct == 100:
        assert st_f.n_stalls > 0        # the overflow spill really stalled


def test_steady_state_tick_is_single_program(monkeypatch):
    """The acceptance bar: a no-admission no-drain decode tick is ONE
    compiled program. Counted three ways — every tick goes through the
    fused launch (the composed tick would raise), no separate enqueue
    program runs, and the stage-1 body is never retraced once warm."""
    traces = {"n": 0}
    fns = _toy_decode_fns(0, trace_counter=traces)     # all-easy traffic
    fused_calls = {"n": 0}
    real_fused = SCH._pool_tick_fused

    def counting_fused(*a, **k):
        fused_calls["n"] += 1
        return real_fused(*a, **k)

    def no_composed(*a, **k):
        raise AssertionError("composed _pool_tick ran in fused mode")

    def no_enqueue_range(*a, **k):
        raise AssertionError("separate ring-enqueue program launched "
                             "during an all-easy steady-state tick")

    monkeypatch.setattr(SCH, "_pool_tick_fused", counting_fused)
    monkeypatch.setattr(SCH, "_pool_tick", no_composed)
    monkeypatch.setattr(SCH, "_ring_enqueue_range", no_enqueue_range)

    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    sched = ContinuousScheduler(fns, sc, n_slots=4, max_len=_TOY_S + 12,
                                clock=LogicalClock())
    for i in range(4):
        sched.submit(Request(sample_id=i,
                             prompt=np.full((_TOY_S,), i, np.int32),
                             n_tokens=10))
    assert sched.step() == "busy"          # admission + first (warm-up) tick
    assert fused_calls["n"] == 1
    warm_traces = traces["n"]
    assert warm_traces >= 1                # eval_shape + the tick compile
    for k in range(5):                     # steady state: pool full, ring
        assert sched.step() == "busy"      # empty, nothing admitted
        assert fused_calls["n"] == 2 + k
    assert traces["n"] == warm_traces      # zero retraces: one program
    res = sched.run()
    assert res == {i: [_toy_tok(i, t) for t in range(10)] for i in range(4)}


def test_fused_tick_off_for_disaggregated_placement():
    """A placement whose stages live on different submeshes must keep the
    composed chain (the enqueue IS the cross-submesh hop)."""
    fns = _toy_decode_fns(50)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    sched = ContinuousScheduler(fns, sc, n_slots=2, max_len=_TOY_S + 4,
                                clock=LogicalClock())
    sched.submit(Request(sample_id=0, prompt=np.zeros(_TOY_S, np.int32),
                         n_tokens=3))
    sched.run()
    assert sched._use_fused()              # single-device default: fused on
    with mock.patch.object(type(sched.placement), "disaggregated",
                           property(lambda self: True)):
        assert not sched._use_fused()


def test_fused_tick_falls_back_when_fns_resist_eval_shape():
    """Duck-typed stage fns that cannot be abstractly evaluated must keep
    the composed tick rather than fail at pool build."""
    fns = _toy_decode_fns(0)

    def opaque_s1(tok, c1, pos):
        raise TypeError("host-side stage fn: no abstract evaluation")

    hacked = SL.DecodeFns(fns.prefill, fns.split, fns.s1, fns.s2, opaque_s1)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    sched = ContinuousScheduler(hacked, sc, n_slots=2, max_len=_TOY_S + 4,
                                clock=LogicalClock())
    tok = jnp.zeros((2, 1), jnp.int32)
    c1 = {"first": [jnp.zeros((2, 1), jnp.int32)], "blocks": (), "rem": []}
    rows = {"sid": jnp.zeros((2, 1), jnp.int32)}
    sched._ensure_pool(c1, rows)
    assert sched._ring_row_spec is None
    assert not sched._use_fused()
