"""Distributed-path correctness: SP/batch-split shard_map attention,
vocab-parallel CE, flash custom-VJP — exercised on an 8-device host mesh in
a subprocess (the main test process must keep 1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat as _compat
from repro.models.layers import blocked_attention, flash_attention_diff

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# jax < 0.5 only ships jax.experimental.shard_map, whose transpose rule
# raises _SpecError on the grad-through-shard_map paths below (upstream
# limitation; the forward paths work through repro.compat.shard_map).
_xfail_old_shard_map = pytest.mark.xfail(
    _compat._CHECK_KW == "check_rep",
    reason="grad through jax.experimental.shard_map (jax<0.5) hits an "
    "upstream transpose _SpecError", strict=False)


def _run(src: str) -> str:
    code = ("import os\n"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent(src))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=_REPO_ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


# ---------------------------------------------------------------------------
# in-process: flash custom-VJP vs AD-through-blocked (1 device)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 48])
def test_flash_vjp_matches_ad(window):
    k = jax.random.PRNGKey(0)
    B, S, H, KH, D = 2, 160, 4, 2, 16
    q = jax.random.normal(k, (B, S, H, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, KH, D))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, KH, D))

    def f1(q, kk, v):
        return jnp.sum(jnp.sin(flash_attention_diff(q, kk, v, 0, True,
                                                    window, 64, 32)))

    def f2(q, kk, v):
        return jnp.sum(jnp.sin(blocked_attention(q, kk, v, causal=True,
                                                 window=window, q_block=64,
                                                 kv_block=32)))

    assert abs(float(f1(q, kk, v) - f2(q, kk, v))) < 1e-5
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, kk, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, kk, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_flash_vjp_q_offset_grad():
    k = jax.random.PRNGKey(1)
    q = jax.random.normal(k, (1, 128, 2, 16))
    kv = jax.random.normal(jax.random.fold_in(k, 1), (1, 128, 2, 16))

    def f1(q):
        return jnp.sum(flash_attention_diff(q[:, 64:], kv, kv, 64, True,
                                            None, 64, 32) ** 2)

    def f2(q):
        return jnp.sum(blocked_attention(q[:, 64:], kv, kv, causal=True,
                                         q_offset=64, q_block=64,
                                         kv_block=32) ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(f1)(q)),
                               np.asarray(jax.grad(f2)(q)), atol=5e-5)


# ---------------------------------------------------------------------------
# subprocess: shard_map paths on an 8-device mesh
# ---------------------------------------------------------------------------

def test_sp_attention_exact_on_mesh():
    out = _run("""
    import jax, jax.numpy as jnp
    from repro.models import hints
    from repro.models.layers import blocked_attention
    from repro.models.attention import attention_core
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    k = jax.random.PRNGKey(0)
    B, S, H, KH, D = 4, 256, 6, 2, 32
    q = jax.random.normal(k, (B, S, H, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, KH, D))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, KH, D))
    ref = blocked_attention(q, kk, v, causal=True)
    hints.set_mesh(mesh)
    with mesh:
        sp = jax.jit(lambda a, b, c: attention_core(
            a, b, c, causal=True, window=None, softcap=None))(q, kk, v)
        g = jax.jit(jax.grad(lambda a: jnp.sum(attention_core(
            a, kk, v, causal=True, window=None, softcap=None) ** 2)))(q)
    gr = jax.grad(lambda a: jnp.sum(blocked_attention(
        a, kk, v, causal=True) ** 2))(q)
    print("OUT", float(jnp.abs(sp - ref).max()))
    print("GRAD", float(jnp.abs(g - gr).max()))
    """)
    vals = dict(line.split() for line in out.strip().splitlines())
    assert float(vals["OUT"]) < 1e-5
    assert float(vals["GRAD"]) < 1e-4


@_xfail_old_shard_map
def test_vocab_parallel_ce_on_mesh():
    out = _run("""
    import jax, jax.numpy as jnp
    from repro.models import hints
    from repro.core import losses
    from repro.models.config import ArchConfig
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=32,
                     n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    k = jax.random.PRNGKey(0)
    bb = {"embed": {"table": jax.random.normal(k, (64, 32)) * 0.1}}
    hidden = jax.random.normal(jax.random.fold_in(k, 1), (4, 24, 32))
    labels = jax.random.randint(jax.random.fold_in(k, 2), (4, 24), 0, 64)
    ref = losses.chunked_ce(bb, cfg, hidden, labels, chunk=8)
    g_ref = jax.grad(lambda b: losses.chunked_ce(b, cfg, hidden, labels))(bb)
    hints.set_mesh(mesh)
    with mesh:
        got = jax.jit(lambda b: losses.vocab_parallel_ce(
            b, cfg, hidden, labels, chunk=8))(bb)
        g = jax.jit(jax.grad(lambda b: losses.vocab_parallel_ce(
            b, cfg, hidden, labels, chunk=8)))(bb)
    print("LOSS", abs(float(ref) - float(got)))
    print("GRAD", float(jnp.abs(g["embed"]["table"] -
                                g_ref["embed"]["table"]).max()))
    """)
    vals = dict(line.split() for line in out.strip().splitlines())
    assert float(vals["LOSS"]) < 1e-5
    assert float(vals["GRAD"]) < 1e-5


@_xfail_old_shard_map
def test_train_step_on_mesh_matches_single_device():
    """One EE train step on the 8-device mesh (SP attention + VP loss + TP
    shardings active) must match the same step on one device bit-for-bit
    within fp tolerance."""
    out = _run("""
    import jax, jax.numpy as jnp
    from repro.core import early_exit as ee, losses
    from repro.models import hints
    from repro.models.config import ArchConfig
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32")
    spec = ee.EarlyExitSpec(exit_layer=1)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 256), 0, 64)
    labels = jax.random.randint(jax.random.PRNGKey(2), (8, 256), 0, 64)

    def loss_fn(p):
        eh, fh, aux = ee.forward_train(p, cfg, spec, tokens)
        l, _ = losses.branchynet_joint_loss(p, cfg, eh, fh, labels,
                                            spec.loss_weights, aux=aux)
        return l

    l_single = float(loss_fn(params))
    g_single = jax.grad(loss_fn)(params)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    hints.set_mesh(mesh)
    with mesh:
        l_mesh = float(jax.jit(loss_fn)(params))
        g_mesh = jax.jit(jax.grad(loss_fn))(params)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(g_single),
                            jax.tree.leaves(g_mesh)))
    print("LOSS", abs(l_single - l_mesh))
    print("GRAD", d)
    """)
    vals = dict(line.split() for line in out.strip().splitlines())
    assert float(vals["LOSS"]) < 1e-4
    assert float(vals["GRAD"]) < 1e-3
