"""Units for the telemetry primitives the observability plane rides on:
``EventLog`` (bounded buffer + seq + subscriber contract + drop
accounting) and ``ControlWindow`` (visit-delta semantics), plus a
hypothesis property over the ``observe.Tracer``'s span assembly — random
interleavings of per-request event sequences must always assemble into
exactly one well-nested span tree per request.
"""
import pytest

from repro.runtime import observe
from repro.runtime.telemetry import ControlWindow, EventLog


# ---------------------------------------------------------------------------
# EventLog
# ---------------------------------------------------------------------------

def test_eventlog_cap_overflow_counts_drops():
    log = EventLog(cap=4)
    for i in range(7):
        log.emit("e", i=i)
    assert len(log) == 4
    assert log.n_dropped == 3
    # FIFO overwrite: the retained window is the newest events, seqs
    # continuous — seq identifies every event ever emitted, not a buffer
    # index
    assert [ev["seq"] for ev in log.as_list()] == [4, 5, 6, 7]
    assert [ev["i"] for ev in log.as_list()] == [3, 4, 5, 6]


def test_eventlog_seq_survives_clear():
    log = EventLog(cap=8)
    log.emit("a")
    log.emit("b")
    log.clear()
    assert len(log) == 0
    ev = log.emit("c")
    assert ev["seq"] == 3          # clear() never renumbers
    assert log.n_dropped == 0      # clear() is not a drop


def test_eventlog_cap_validation():
    with pytest.raises(ValueError):
        EventLog(cap=0)


def test_eventlog_subscribe_unsubscribe():
    log = EventLog(cap=8)
    seen = []
    cb = log.subscribe(lambda ev: seen.append(ev["event"]))
    log.emit("one")
    log.unsubscribe(cb)
    log.emit("two")
    assert seen == ["one"]
    with pytest.raises(ValueError):
        log.unsubscribe(cb)        # unknown callback is a loud error


def test_eventlog_subscriber_exception_propagates():
    """Subscribers must not raise; when one does anyway the emitter sees
    it (no swallow-and-continue — a silently dead feed is worse)."""
    log = EventLog(cap=8)
    log.subscribe(lambda ev: (_ for _ in ()).throw(RuntimeError("bad sub")))
    with pytest.raises(RuntimeError, match="bad sub"):
        log.emit("x")
    assert len(log) == 1           # buffered BEFORE subscribers ran


def test_eventlog_subscribe_during_emit_takes_effect_next_emit():
    log = EventLog(cap=8)
    late = []

    def cb(ev):
        if ev["event"] == "first":
            log.subscribe(lambda e: late.append(e["event"]))

    log.subscribe(cb)
    log.emit("first")              # registers `late` mid-emit
    assert late == []              # snapshot semantics: not for this event
    log.emit("second")
    assert late == ["second"]


# ---------------------------------------------------------------------------
# ControlWindow
# ---------------------------------------------------------------------------

def test_control_window_tick_aggregates():
    w = ControlWindow()
    w.observe(n_decisions=8, n_hard=2)
    w.observe(n_decisions=6, n_hard=3)
    assert w.ticks == 2
    assert w.decisions == 14
    assert w.q == pytest.approx(5 / 14)
    assert w.mean_active == pytest.approx(7.0)
    w.reset()
    assert w.ticks == 0 and w.q == 0.0 and w.mean_active == 0.0


def test_control_window_counter_deltas_across_reset():
    """observe_counters receives LIFETIME values; windows see deltas vs
    the previous visit, and the high-water marks survive reset() so a new
    window never re-counts old stalls."""
    w = ControlWindow()
    w.observe(4, 1)
    w.observe_counters(n_stalls=5, n_buckets=2, bucket_fill_sum=1.5)
    assert w.stalls == 5 and w.buckets == 2
    w.observe_counters(n_stalls=7, n_buckets=3, bucket_fill_sum=2.5)
    assert w.stalls == 7 and w.buckets == 3          # +2, +1
    w.reset()
    w.observe(4, 0)
    w.observe_counters(n_stalls=8, n_buckets=5, bucket_fill_sum=4.5)
    assert w.stalls == 1 and w.buckets == 2          # deltas vs 7/3, not 0
    assert w.mean_bucket_fill == pytest.approx(1.0)
    assert w.stall_rate == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# span assembly: random interleavings -> one well-nested tree per request
# ---------------------------------------------------------------------------

def _request_script(sid, n_parks, with_router_submit):
    """One request's event sequence as (tag, fields) steps, in its own
    causal order. Interleaving across requests is the property input."""
    steps = []
    if with_router_submit:               # router submit seeds the root
        steps.append(("submit", {"sid": sid, "tenant": "t"}))
    steps.append(("submit", {"sid": sid, "arrival": 0.0, "n_tokens": 4}))
    steps.append(("admit", {"sid": sid, "slot": sid % 3, "prompt_len": 4}))
    for _ in range(n_parks):
        steps.append(("park", {"sids": (sid,), "slots": (sid % 3,)}))
        steps.append(("bucket", {"sids": (sid,), "take": 1, "capacity": 2}))
    steps.append(("finish", {"sid": sid, "n_hard": n_parks,
                             "n_decisions": 4}))
    return steps


def _interleave(scripts, order):
    """Merge per-request scripts into one trace, preserving each script's
    internal order; ``order`` is a sequence of request indices."""
    idx = [0] * len(scripts)
    merged = []
    for r in order:
        r = r % len(scripts)
        # find the next script that still has steps, starting from r
        for off in range(len(scripts)):
            k = (r + off) % len(scripts)
            if idx[k] < len(scripts[k]):
                merged.append(scripts[k][idx[k]])
                idx[k] += 1
                break
    for k, script in enumerate(scripts):       # drain the stragglers
        merged.extend(script[idx[k]:])
    return merged


def test_tracer_assembles_simple_tree():
    log = EventLog(cap=256)
    tracer = observe.Tracer().attach(log)
    for tag, fields in _request_script(0, n_parks=2,
                                       with_router_submit=True):
        log.emit(tag, **fields)
    tracer.close()
    comp = tracer.completeness(expect_sids={0})
    assert comp["complete"], comp
    names = sorted(s["name"] for s in tracer.spans)
    assert names == ["decode", "queue_wait", "request",
                     "stage2_wait", "stage2_wait"]
    root = [s for s in tracer.spans if s["name"] == "request"][0]
    assert root["args"]["n_hard"] == 2
    assert root["args"]["tenant"] == "t"     # router submit won the root


def test_tracer_orphan_and_open_detection():
    log = EventLog(cap=256)
    tracer = observe.Tracer().attach(log)
    log.emit("admit", sid=7, slot=0, prompt_len=4)   # never submitted
    log.emit("submit", sid=1, arrival=0.0, n_tokens=2)
    tracer.close()
    comp = tracer.completeness()
    assert not comp["complete"]
    assert comp["orphans"] == ["7"]
    assert comp["open"] == ["1"]                     # submitted, no finish


try:
    from hypothesis import given, settings, strategies as st_h
    _HAVE_HYP = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYP = False


if _HAVE_HYP:
    @settings(max_examples=50, deadline=None)
    @given(
        n_parks=st_h.lists(st_h.integers(0, 3), min_size=1, max_size=6),
        order=st_h.lists(st_h.integers(0, 5), min_size=0, max_size=60),
        router=st_h.booleans(),
    )
    def test_tracer_random_interleavings(n_parks, order, router):
        """Any interleaving of per-request event sequences (each request's
        own causal order preserved) assembles into exactly one well-nested
        span tree per request: one root, children inside the root
        interval, park-episode count preserved, no orphans, nothing left
        open."""
        scripts = [_request_script(sid, k, router)
                   for sid, k in enumerate(n_parks)]
        log = EventLog(cap=4096)
        tracer = observe.Tracer().attach(log)
        for tag, fields in _interleave(scripts, order):
            log.emit(tag, **fields)
        tracer.close()
        comp = tracer.completeness(expect_sids=set(range(len(n_parks))))
        assert comp["complete"], comp
        spans = tracer.spans
        for sid, k in enumerate(n_parks):
            mine = [s for s in spans if s["sid"] == sid]
            assert sum(s["name"] == "request" for s in mine) == 1
            assert sum(s["name"] == "queue_wait" for s in mine) == 1
            assert sum(s["name"] == "decode" for s in mine) == 1
            assert sum(s["name"] == "stage2_wait" for s in mine) == k
            root = [s for s in mine if s["name"] == "request"][0]
            for s in mine:
                assert root["t0"] <= s["t0"] <= s["t1"] <= root["t1"]
