"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py forces the 512-device platform)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import early_exit as ee
from repro.models.config import ArchConfig


@pytest.fixture(scope="session")
def tiny_cfg() -> ArchConfig:
    """4-layer dense LM, small enough for CPU integration tests."""
    return ArchConfig(
        name="tiny-dense", family="dense", n_layers=4, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
        dtype="float32", param_dtype="float32", tie_embeddings=True,
    )


@pytest.fixture(scope="session")
def tiny_spec(tiny_cfg) -> ee.EarlyExitSpec:
    return ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg, tiny_spec):
    return ee.init_ee_params(jax.random.PRNGKey(0), tiny_cfg, tiny_spec)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(42)


def assert_finite(tree, name=""):
    for leaf in jax.tree.leaves(tree):
        arr = jnp.asarray(leaf)
        if jnp.issubdtype(arr.dtype, jnp.floating):
            assert bool(jnp.isfinite(arr.astype(jnp.float32)).all()), \
                f"non-finite values in {name}"
