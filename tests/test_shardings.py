"""Sharding planner: divisibility guarantees across every assigned arch on
the production mesh shape (pure logic — fake mesh, no devices)."""
from types import SimpleNamespace

import jax
import pytest

from repro.core import early_exit as ee
from repro.launch import shardings as sh
from repro.models.registry import get_arch, list_archs


class FakeMesh(SimpleNamespace):
    """Duck-typed mesh: .shape mapping + .axis_names (enough for the spec
    planner, which never touches devices)."""
    def __init__(self, shape: dict):
        super().__init__(shape=shape, axis_names=tuple(shape))


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16})]


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divide(arch, mesh, fsdp):
    """Every sharded dim must divide its mesh axis — for all 10 archs,
    both meshes, with and without FSDP."""
    cfg = get_arch(arch)
    spec = ee.default_spec(cfg)
    shapes = ee.ee_param_shapes(cfg, spec)

    def check(path, leaf):
        p = sh.param_spec(path, leaf.shape, mesh, fsdp=fsdp)
        for i, ax in enumerate(p):
            if ax is None:
                continue
            size = mesh.shape[ax]
            assert leaf.shape[i] % size == 0, (
                f"{arch} {jax.tree_util.keystr(path)} dim {i} "
                f"({leaf.shape[i]}) not divisible by {ax}={size}")
        return p

    jax.tree_util.tree_map_with_path(check, shapes)


@pytest.mark.parametrize("arch", ["grok-1-314b", "deepseek-v2-lite-16b"])
def test_moe_experts_sharded(arch):
    """MoE expert tensors must be sharded on SOME dim (they're the biggest
    params; replication would blow HBM)."""
    cfg = get_arch(arch)
    spec = ee.default_spec(cfg)
    shapes = ee.ee_param_shapes(cfg, spec)
    mesh = MESHES[0]
    found = []

    def check(path, leaf):
        name = sh._leaf_name(path)
        if name in ("e_gate", "e_up", "e_down"):
            p = sh.param_spec(path, leaf.shape, mesh)
            found.append(any(ax is not None for ax in p))

    jax.tree_util.tree_map_with_path(check, shapes)
    assert found and all(found), f"{arch}: unsharded expert tensors"


def test_embedding_replicated_when_vocab_odd():
    """mamba2's 50280 vocab is not divisible by 16 -> table replicates."""
    cfg = get_arch("mamba2-130m")
    mesh = MESHES[0]
    p = sh.param_spec(
        (jax.tree_util.DictKey("embed"), jax.tree_util.DictKey("table")),
        (50280, 768), mesh)
    assert all(ax is None for ax in p) or len(p) == 0


def test_qwen_embedding_sharded():
    """151936 = 16 * 9496 -> vocab-sharded table."""
    mesh = MESHES[0]
    p = sh.param_spec(
        (jax.tree_util.DictKey("embed"), jax.tree_util.DictKey("table")),
        (151936, 1536), mesh)
    assert tuple(p) == ("model",)


def test_batch_spec_multipod():
    assert sh.batch_spec(MESHES[1], 256) == ("pod", "data")
    assert sh.batch_spec(MESHES[0], 256) == ("data",)
    # indivisible batch falls back
    assert sh.batch_spec(MESHES[0], 7) in ((), None)
