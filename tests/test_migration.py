"""Live-migration state machine: zero-dropped-request stream equivalence
across capacity re-sizes and full chip re-splits, clean rollback from a
fault in any stage, quiesce bounding, device-loss degradation, and (under
hypothesis) random fault point x stage invariants.

Chaos-sweep compatibility: the CI chaos job re-runs this file with
``REPRO_FAULT_PLAN`` armed. Tests asserting an exact migration outcome
shadow the ambient plan via ``faults.installed``; the ambient-facing tests
assert only invariants that hold whether the sweep's fault fired here or
not (streams exact, no hang, admission re-opened).
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax

from test_scheduler import _toy_expected, _toy_requests, toy_decode_fns, _TOY_S
from repro.core.stage_mesh import StageMeshPlan
from repro.runtime import faults
from repro.runtime import scheduler as S
from repro.runtime.migration import (LiveMigrator, MigrationError,
                                     MigrationPlan, QuiesceTimeout,
                                     migrate_on_device_loss)
from repro.runtime.scheduler import ContinuousScheduler, LogicalClock
from repro.runtime.stage_executor import StagePlacement

_REPO_ROOT = str(Path(__file__).resolve().parent.parent)

_N_TOKS = [6, 3, 8, 5, 2, 7, 4, 6]


def _sched(fns, *, placement=None, capacity=2, fns_factory=None,
           mig_after=None, plan=None):
    """Toy-fns scheduler with all requests submitted; ``mig_after`` arms
    ``plan`` from the controller hook after that many pool ticks — the
    migration then applies at the next discrete re-plan point."""
    sc = S.ServeConfig(capacity=capacity, queue_depth=2, c_thr=0.5)
    sched = ContinuousScheduler(fns, sc, n_slots=4, max_len=_TOY_S + 8,
                                clock=LogicalClock(), placement=placement,
                                fns_factory=fns_factory)
    if mig_after is not None:
        class _Trig:
            ticks = 0

            def on_tick(self, s, nd, nh, conf):
                self.ticks += 1
                if self.ticks == mig_after:
                    s.request_migration(plan)
        sched.controller = _Trig()
    for r in _toy_requests(_N_TOKS):
        sched.submit(r)
    return sched


# ---------------------------------------------------------------------------
# the contract: migrated streams bitwise-equal to an unmigrated run
# ---------------------------------------------------------------------------

def test_capacity_migration_stream_equivalence():
    fns = toy_decode_fns(q_pct=40)
    with faults.installed(None):
        sched = _sched(fns, mig_after=3,
                       plan=MigrationPlan(capacity=3, reason="test"))
        res = sched.run()
    assert res == _toy_expected(_N_TOKS)            # zero dropped/duplicated
    st = sched.stats
    assert st.n_migrations == 1 and st.n_migration_rollbacks == 0
    assert sched.sc.capacity == 3
    assert 0.0 < st.migration_pause_p50_ms == st.migration_pause_p99_ms
    assert sched._admission_open


def test_migration_before_first_admission():
    """A plan armed before the pool warms up migrates the cold scheduler
    (no device state to re-place) and still serves correctly."""
    fns = toy_decode_fns(q_pct=40)
    with faults.installed(None):
        sched = _sched(fns)
        sched.request_migration(MigrationPlan(capacity=3, reason="cold"))
        res = sched.run()
    assert res == _toy_expected(_N_TOKS)
    assert sched.stats.n_migrations == 1 and sched.sc.capacity == 3


def test_migration_plan_validation():
    with pytest.raises(ValueError, match="placement"):
        MigrationPlan(fns=object())                 # fns without placement
    with pytest.raises(ValueError, match="quiesce_timeout_s"):
        MigrationPlan(quiesce_timeout_s=0.0)


# ---------------------------------------------------------------------------
# rollback: a fault in ANY stage restores the old plan, streams stay exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("point", ["migrate:quiesce", "migrate:snapshot",
                                   "migrate:replace", "migrate:resume"])
def test_rollback_from_each_stage_preserves_streams(point):
    fns = toy_decode_fns(q_pct=40)
    with faults.installed(faults.FaultPlan.parse(f"{point}@1")):
        sched = _sched(fns, mig_after=3,
                       plan=MigrationPlan(capacity=3, reason="test"))
        res = sched.run()
    assert res == _toy_expected(_N_TOKS)
    st = sched.stats
    assert st.n_migration_rollbacks == 1 and st.n_migrations == 0
    assert sched.sc.capacity == 2                   # old plan restored
    assert sched._admission_open


def test_rollback_restores_byte_identical_state():
    """Direct LiveMigrator rollback on a warm, drained pool: every device
    lane, the host metadata, and the plan objects come back exactly."""
    fns = toy_decode_fns(q_pct=40)
    with faults.installed(None):
        sched = _sched(fns)
        sched.run()                                 # warm + drained
    lanes = ("_tok", "_pos", "_active_lane", "_start_lane", "_budget_lane")
    before_dev = {a: np.asarray(getattr(sched, a)) for a in lanes}
    before_host = {a: list(getattr(sched, a))
                   for a in ("_sid", "_emitted", "_budget", "_state",
                             "_free")}
    before_refs = {a: getattr(sched, a)
                   for a in ("fns", "placement", "ex1", "ex2", "sc",
                             "ring")}
    with faults.installed(faults.FaultPlan.parse("migrate:replace@1")):
        with pytest.raises(MigrationError):
            LiveMigrator(sched, MigrationPlan(capacity=3,
                                              reason="test")).run()
    for a in lanes:
        assert np.array_equal(np.asarray(getattr(sched, a)),
                              before_dev[a]), a
    for a, want in before_host.items():
        assert list(getattr(sched, a)) == want, a
    for a, want in before_refs.items():
        assert getattr(sched, a) is want, a         # same objects restored
    assert sched._admission_open
    assert sched.stats.n_migration_rollbacks == 1


def test_quiesce_timeout_bounded_and_rolled_back():
    """A ring that cannot drain never reaches a shape-change-safe point:
    QUIESCE raises within its bounded wait instead of hanging, and the
    rollback re-opens admission."""
    fns = toy_decode_fns(q_pct=40)
    with faults.installed(None):
        sched = _sched(fns)
        sched.run()
        sched.ring.count = 1                        # wedge: claims a row
        sched._dispatch_bucket = lambda: None       # ...that never drains
        with pytest.raises(MigrationError) as ei:
            LiveMigrator(sched, MigrationPlan(
                capacity=3, quiesce_timeout_s=0.05,
                reason="test")).run()
    assert isinstance(ei.value, QuiesceTimeout)
    assert sched._admission_open
    assert sched.stats.n_migration_rollbacks == 1


# ---------------------------------------------------------------------------
# transient runtime faults: retried, stream never notices
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "dispatch@2#transient",
    "enqueue@1#transient",
    "dispatch@1#transient;enqueue@2#transient;dispatch@4#transient",
])
def test_transient_faults_survive(spec):
    fns = toy_decode_fns(q_pct=40)
    with faults.installed(faults.FaultPlan.parse(spec)):
        res = _sched(fns).run()
    assert res == _toy_expected(_N_TOKS)


def test_streams_exact_under_ambient_plan():
    """The chaos-sweep-facing test: runs with whatever REPRO_FAULT_PLAN the
    environment armed (none locally). Every survivable ambient fault —
    transient runtime faults, fatal migration-stage faults — must leave
    the streams exact and the server admitting."""
    fns = toy_decode_fns(q_pct=40)
    sched = _sched(fns, mig_after=3,
                   plan=MigrationPlan(capacity=3, reason="ambient"))
    res = sched.run()
    assert res == _toy_expected(_N_TOKS)
    st = sched.stats
    assert st.n_migrations + st.n_migration_rollbacks == 1
    assert sched._admission_open


# ---------------------------------------------------------------------------
# device loss
# ---------------------------------------------------------------------------

def test_device_loss_requires_factory_and_chips():
    fns = toy_decode_fns(q_pct=40)
    sched = _sched(fns)                             # single-device, no factory
    with pytest.raises(MigrationError, match="fns_factory"):
        migrate_on_device_loss(sched, [0])
    sched = _sched(fns, fns_factory=lambda pl: fns)
    with pytest.raises(MigrationError, match="no chips"):
        migrate_on_device_loss(sched, [0])


# ---------------------------------------------------------------------------
# hypothesis: random fault point x kind -> invariants
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_h
    _HAVE_HYP = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYP = False

_SURVIVABLE_POINTS = ["dispatch", "enqueue", "migrate:quiesce",
                      "migrate:snapshot", "migrate:replace",
                      "migrate:resume"]

if _HAVE_HYP:
    @settings(max_examples=12, deadline=None)
    @given(point=st_h.sampled_from(_SURVIVABLE_POINTS),
           nth=st_h.integers(min_value=1, max_value=6),
           transient=st_h.booleans(),
           mig_after=st_h.integers(min_value=1, max_value=6),
           q_pct=st_h.sampled_from([20, 40, 70]))
    def test_migration_invariants_random_fault(point, nth, transient,
                                               mig_after, q_pct):
        """Any survivable injected fault x any migration trigger point:
        no dropped or duplicated token (streams exact), the server ends
        admitting with a drained pool, exactly one migration attempt is
        accounted (done or rolled back), and a completed migration's pause
        is recorded under the (generous) budget."""
        if not transient and point in ("dispatch", "enqueue"):
            transient = True                        # fatal hot-loop faults
                                                    # are expected to kill
                                                    # the server, not be
                                                    # survived — tested in
                                                    # test_faults
        kind = "#transient" if transient else ""
        plan = MigrationPlan(capacity=3, pause_budget_ms=60_000.0,
                             reason="hyp")
        fns = toy_decode_fns(q_pct=q_pct)
        with faults.installed(faults.FaultPlan.parse(f"{point}@{nth}{kind}")):
            sched = _sched(fns, mig_after=mig_after, plan=plan)
            res = sched.run()
        assert res == _toy_expected(_N_TOKS)
        st = sched.stats
        assert st.n_migrations + st.n_migration_rollbacks == 1
        assert sched._admission_open
        if st.n_migrations:
            assert st.migration_pause_p99_ms < plan.pause_budget_ms
else:                                                 # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_migration_invariants_random_fault():
        pass


# ---------------------------------------------------------------------------
# disaggregated: full chip re-split on 8 host devices
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 devices")
def test_chip_resplit_migration_8dev():
    """The tentpole acceptance bar (toy fns): a running disaggregated
    scheduler re-splits 4+4 -> 6+2 mid-serve; streams exact, placement
    swapped, one migration recorded."""
    fns = toy_decode_fns(q_pct=40)
    pl_a = StagePlacement.from_plan(StageMeshPlan.proportional(0.5, 8))
    pl_b = StagePlacement.from_plan(StageMeshPlan.proportional(0.25, 8))
    with faults.installed(None):
        sched = _sched(fns, placement=pl_a, fns_factory=lambda pl: fns,
                       mig_after=3,
                       plan=MigrationPlan(placement=pl_b, fns=fns,
                                          capacity=3, reason="resplit"))
        res = sched.run()
    assert res == _toy_expected(_N_TOKS)
    st = sched.stats
    assert st.n_migrations == 1
    assert (st.stage1_chips, st.stage2_chips) == (6, 2)
    assert sched.placement is not pl_a


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 devices")
def test_chip_resplit_rollback_8dev():
    fns = toy_decode_fns(q_pct=40)
    pl_a = StagePlacement.from_plan(StageMeshPlan.proportional(0.5, 8))
    pl_b = StagePlacement.from_plan(StageMeshPlan.proportional(0.25, 8))
    with faults.installed(faults.FaultPlan.parse("migrate:replace@1")):
        sched = _sched(fns, placement=pl_a, fns_factory=lambda pl: fns,
                       mig_after=3,
                       plan=MigrationPlan(placement=pl_b, fns=fns,
                                          reason="resplit"))
        res = sched.run()
    assert res == _toy_expected(_N_TOKS)
    st = sched.stats
    assert st.n_migration_rollbacks == 1
    assert (st.stage1_chips, st.stage2_chips) == (4, 4)
    assert sched.placement is pl_a


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 devices")
def test_device_loss_degrades_8dev():
    """Losing a stage-2 chip mid-serve degrades to a 7-chip split through
    the live migrator — streams exact, server alive."""
    fns = toy_decode_fns(q_pct=40)
    pl_a = StagePlacement.from_plan(StageMeshPlan.proportional(0.5, 8))
    with faults.installed(None):
        sched = _sched(fns, placement=pl_a, fns_factory=lambda pl: fns)

        class _Loss:
            ticks = 0

            def on_tick(self, s, nd, nh, conf):
                self.ticks += 1
                if self.ticks == 3:
                    migrate_on_device_loss(s, [s.ex2.devices[-1]],
                                           q=0.4)
        sched.controller = _Loss()
        res = sched.run()
    assert res == _toy_expected(_N_TOKS)
    st = sched.stats
    assert st.n_migrations == 1
    assert st.stage1_chips + st.stage2_chips == 7


def test_real_model_resplit_subprocess():
    """The full acceptance criterion: a REAL tiny EE model on an 8-device
    disaggregated ContinuousScheduler live-migrates through a full chip
    re-split (param re-slice via the attached fns_factory) and its streams
    stay bitwise-equal to the host-loop oracle."""
    code = ("import os\n"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=8'\n"
            "os.environ.pop('REPRO_FAULT_PLAN', None)\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import early_exit as ee
    from repro.core.stage_mesh import StageMeshPlan
    from repro.models.config import ArchConfig
    from repro.runtime import serve_loop as SL
    from repro.runtime.migration import MigrationPlan
    from repro.runtime.scheduler import LogicalClock, Request
    from repro.runtime.stage_executor import StagePlacement

    cfg = ArchConfig(name="t", family="dense", n_layers=4, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32",
                     tie_embeddings=True)
    spec0 = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(21), (6, 8),
                                           0, cfg.vocab))
    n_toks = [5, 3, 5, 1, 4, 2]
    conf = SL.decode_step0_confidences(params, cfg, spec0, prompt,
                                       max_len=13)
    c_thr = float(jnp.quantile(conf, 0.5))
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=c_thr)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=c_thr)
    oracle = SL.build_host_decoder(params, cfg, spec, sc).generate(prompt, 5)
    want = {i: [int(x) for x in oracle["tokens"][i][:n_toks[i]]]
            for i in range(6)}
    pl_a = StagePlacement.from_plan(StageMeshPlan.proportional(0.5, 8))
    pl_b = StagePlacement.from_plan(StageMeshPlan.proportional(0.25, 8))
    s = SL.build_continuous_scheduler(params, cfg, spec, sc, n_slots=3,
                                      max_len=13, placement=pl_a,
                                      clock=LogicalClock())
    plan = MigrationPlan(placement=pl_b, fns=s.fns_factory(pl_b),
                         capacity=3, reason="resplit")
    class Trig:
        ticks = 0
        def on_tick(self, sch, nd, nh, c):
            self.ticks += 1
            if self.ticks == 2:
                sch.request_migration(plan)
    s.controller = Trig()
    for i in range(6):
        s.submit(Request(i, prompt[i], n_toks[i]))
    res = s.run()
    assert res == want, "migrated streams != oracle"
    assert s.stats.n_migrations == 1
    assert (s.stats.stage1_chips, s.stats.stage2_chips) == (6, 2)
    assert s.stats.migration_pause_p99_ms > 0.0
    print("RESPLIT_OK pause_ms", s.stats.migration_pause_p99_ms)
    """))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=_REPO_ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RESPLIT_OK" in r.stdout
