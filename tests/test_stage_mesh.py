"""Stage-mesh planning: ShardPlan recovery from TAP design points (both
meta layouts, loud failure otherwise), device carving invariants (disjoint
coverage — hypothesis property), and stage2_capacity edge cases."""
import numpy as np
import pytest

from repro.core import stage_mesh as sm
from repro.core.perf_model import ShardPlan
from repro.core.tap import CombinedDesign, DesignPoint


def _design(meta1, meta2, chips1=4, chips2=2):
    return CombinedDesign(
        stage1=DesignPoint(resources=(chips1,), throughput=100.0, meta=meta1),
        stage2=DesignPoint(resources=(chips2,), throughput=40.0, meta=meta2),
        p=0.25, design_throughput=100.0)


# ---------------------------------------------------------------------------
# StageMeshPlan.from_design: plan extraction must validate both lookups
# ---------------------------------------------------------------------------

def test_from_design_direct_plan():
    p1, p2 = ShardPlan(dp=2, tp=2), ShardPlan(dp=2, tp=1)
    plan = sm.StageMeshPlan.from_design(
        _design({"plan": p1}, {"plan": p2}))
    assert (plan.chips1, plan.chips2) == (4, 2)
    assert plan.plan1 is p1 and plan.plan2 is p2


def test_from_design_roofline_nested_plan():
    p1, p2 = ShardPlan(dp=4, tp=1), ShardPlan(dp=1, tp=2)
    plan = sm.StageMeshPlan.from_design(
        _design({"roofline": {"plan": p1}}, {"roofline": {"plan": p2}}))
    assert plan.plan1 is p1 and plan.plan2 is p2


@pytest.mark.parametrize("meta", [
    {},                                   # nothing to recover
    {"roofline": 3.14},                   # roofline not a dict (the old
                                          # .get chain crashed on this)
    {"roofline": {}},                     # dict but no plan
    {"plan": "dp2tp2"},                   # plan of the wrong type
    {"roofline": {"plan": None}},
])
def test_from_design_unrecoverable_plan_raises(meta):
    ok = {"plan": ShardPlan(dp=2, tp=1)}
    with pytest.raises(ValueError, match="no ShardPlan recoverable"):
        sm.StageMeshPlan.from_design(_design(meta, ok, chips1=2))
    with pytest.raises(ValueError, match="no ShardPlan recoverable"):
        sm.StageMeshPlan.from_design(_design(ok, meta, chips1=2))


def test_plan_chip_mismatch_raises():
    with pytest.raises(ValueError, match="!= chips1"):
        sm.StageMeshPlan(chips1=4, chips2=2, plan1=ShardPlan(dp=3, tp=1),
                         plan2=ShardPlan(dp=2, tp=1))
    with pytest.raises(ValueError, match=">= 1"):
        sm.StageMeshPlan(chips1=0, chips2=2, plan1=ShardPlan(dp=1, tp=1),
                         plan2=ShardPlan(dp=2, tp=1))


def test_resolve_explicit_zero_rejected():
    """resolve must not absorb an explicit chips=0 via truthiness — it
    reaches the >= 1 validation; a missing count is the complement."""
    plan = sm.StageMeshPlan.resolve(0.25, 8, chips1=None, chips2=None)
    assert (plan.chips1, plan.chips2) == (6, 2)      # p-proportional
    plan = sm.StageMeshPlan.resolve(0.25, 8, chips1=5, chips2=None)
    assert (plan.chips1, plan.chips2) == (5, 3)      # complement
    plan = sm.StageMeshPlan.resolve(0.25, 8, chips1=None, chips2=3)
    assert (plan.chips1, plan.chips2) == (5, 3)
    with pytest.raises(ValueError, match=">= 1"):
        sm.StageMeshPlan.resolve(0.25, 8, chips1=0, chips2=2)
    with pytest.raises(ValueError, match=">= 1"):
        sm.StageMeshPlan.resolve(0.25, 8, chips1=0, chips2=None)


def test_proportional_apportionment():
    plan = sm.StageMeshPlan.proportional(0.25, 8)
    assert (plan.chips1, plan.chips2) == (6, 2)
    # extremes keep both stages resident (>= 1 chip each)
    assert sm.StageMeshPlan.proportional(0.0, 8).chips2 == 1
    assert sm.StageMeshPlan.proportional(1.0, 8).chips1 == 1
    with pytest.raises(ValueError):
        sm.StageMeshPlan.proportional(0.5, 1)


# ---------------------------------------------------------------------------
# device carving: disjointness + exact coverage
# ---------------------------------------------------------------------------

def test_carve_insufficient_devices():
    plan = sm.StageMeshPlan.from_chips(4, 4)
    with pytest.raises(ValueError, match="8 chips required"):
        sm.carve_stage_devices(list(range(6)), plan)


def test_carve_shapes_follow_shard_plans():
    plan = sm.StageMeshPlan(chips1=4, chips2=2, plan1=ShardPlan(dp=2, tp=2),
                            plan2=ShardPlan(dp=1, tp=2))
    d1, d2 = sm.carve_stage_devices(list(range(8)), plan)
    assert d1.shape == (2, 2) and d2.shape == (1, 2)
    assert sorted(d1.flat) == [0, 1, 2, 3] and sorted(d2.flat) == [4, 5]


def test_make_stage_meshes_on_real_devices():
    """Mesh construction over the actual local device list (degenerate
    1+... splits skip when the host exposes a single device)."""
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (CI disaggregated job)")
    n = jax.device_count()
    plan = sm.StageMeshPlan.from_chips(n - 1, 1)
    m1, m2 = sm.make_stage_meshes(jax.devices(), plan)
    ids1 = {d.id for d in m1.devices.flat}
    ids2 = {d.id for d in m2.devices.flat}
    assert not ids1 & ids2
    assert len(ids1) == n - 1 and len(ids2) == 1
    assert m1.axis_names == ("data", "model")


def test_carve_property_disjoint_exact_cover():
    """Hypothesis property: for any shard-plan pair, the carved stage
    device sets are disjoint and cover exactly the first chips1+chips2
    devices (order preserved within each grid)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    small = st.integers(min_value=1, max_value=4)

    @hyp.given(dp1=small, tp1=small, dp2=small, tp2=small,
               extra=st.integers(min_value=0, max_value=3))
    @hyp.settings(deadline=None, max_examples=60)
    def prop(dp1, tp1, dp2, tp2, extra):
        c1, c2 = dp1 * tp1, dp2 * tp2
        plan = sm.StageMeshPlan(chips1=c1, chips2=c2,
                                plan1=ShardPlan(dp=dp1, tp=tp1),
                                plan2=ShardPlan(dp=dp2, tp=tp2))
        devices = [f"dev{i}" for i in range(c1 + c2 + extra)]
        d1, d2 = sm.carve_stage_devices(devices, plan)
        s1, s2 = set(d1.flat), set(d2.flat)
        assert d1.shape == (dp1, tp1) and d2.shape == (dp2, tp2)
        assert len(s1) == c1 and len(s2) == c2        # no duplicates
        assert not s1 & s2                            # disjoint
        assert s1 | s2 == set(devices[:c1 + c2])      # exact cover
        assert list(d1.flat) == devices[:c1]          # order preserved
        assert list(d2.flat) == devices[c1:c1 + c2]

    prop()


# ---------------------------------------------------------------------------
# stage2_capacity edge cases
# ---------------------------------------------------------------------------

def test_stage2_capacity_p_zero():
    """p=0 still provisions one multiple-sized bucket (slack floor)."""
    assert sm.stage2_capacity(64, 0.0) == 8
    assert sm.stage2_capacity(64, 0.0, slack=0.0) == 8


def test_stage2_capacity_p_one():
    """p=1 (+slack) caps at the full batch."""
    assert sm.stage2_capacity(64, 1.0) == 64
    assert sm.stage2_capacity(128, 1.0, slack=0.5) == 128


def test_stage2_capacity_batch_below_multiple():
    """A batch smaller than the sharding multiple caps at the batch."""
    assert sm.stage2_capacity(4, 0.5) == 4
    assert sm.stage2_capacity(1, 1.0) == 1
    assert sm.stage2_capacity(7, 0.0, multiple=8) == 7


@pytest.mark.parametrize("batch", [1, 4, 8, 33, 128])
@pytest.mark.parametrize("p", [0.0, 0.1, 0.25, 0.5, 0.99, 1.0])
def test_stage2_capacity_invariants(batch, p):
    cap = sm.stage2_capacity(batch, p)
    assert 1 <= cap <= batch
    # rounded to the multiple unless clamped by the batch itself
    assert cap == batch or cap % 8 == 0
    # never under-provisions the design point's expected hard count
    assert cap >= min(batch, int(np.ceil(p * batch)))
