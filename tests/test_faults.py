"""Fault-injection layer: plan parsing, fault-point semantics, retry with
backoff, the bounded-wait harvest, and the ring's no-progress guard."""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.runtime import faults
from repro.runtime import scheduler as S
from repro.runtime.scheduler import (HarvestTimeout, RingQueue, ServeConfig,
                                     ServeStats, bounded_wait)
from repro.runtime.stage_executor import StageExecutor
from repro.runtime.telemetry import EventLog


# ---------------------------------------------------------------------------
# plan parsing / round-trip
# ---------------------------------------------------------------------------

def test_plan_parse_roundtrip():
    spec = "dispatch@3;migrate:replace@1#transient,transfer@2"
    p = faults.FaultPlan.parse(spec)
    assert p.triggers == {"dispatch": [(3, "fatal")],
                          "migrate:replace": [(1, "transient")],
                          "transfer": [(2, "fatal")]}
    assert faults.FaultPlan.parse(p.spec()).triggers == p.triggers


@pytest.mark.parametrize("bad", ["dispatch", "dispatch@x", "dispatch@0",
                                 "dispatch@2#bogus", "@3"])
def test_plan_parse_rejects(bad):
    with pytest.raises(ValueError):
        faults.FaultPlan.parse(bad)


def test_plan_parse_empty_and_whitespace():
    assert faults.FaultPlan.parse("").triggers == {}
    assert faults.FaultPlan.parse(" ; , ").triggers == {}


def test_seeded_plan_deterministic():
    a = faults.FaultPlan.seeded(7, n_faults=3)
    b = faults.FaultPlan.seeded(7, n_faults=3)
    assert a.triggers == b.triggers
    assert all(pt in faults.POINTS for pt in a.triggers)


# ---------------------------------------------------------------------------
# fault-point firing semantics
# ---------------------------------------------------------------------------

def test_fault_point_fires_on_nth_visit_once():
    with faults.installed(faults.FaultPlan.parse("x@2#transient")):
        faults.fault_point("x")                     # visit 1: armed, silent
        with pytest.raises(faults.InjectedFault) as ei:
            faults.fault_point("x")                 # visit 2: fires
        assert ei.value.point == "x" and ei.value.transient
        faults.fault_point("x")                     # visit 3: consumed


def test_installed_none_muffles_and_restores():
    outer = faults.FaultPlan.parse("y@1")
    with faults.installed(outer):
        with faults.installed(None):
            faults.fault_point("y")                 # muffled
        with pytest.raises(faults.InjectedFault):
            faults.fault_point("y")                 # outer plan restored


def test_fatal_default_kind():
    with faults.installed(faults.FaultPlan.parse("z@1")):
        with pytest.raises(faults.InjectedFault) as ei:
            faults.fault_point("z")
        assert not ei.value.transient
        assert not faults.is_transient(ei.value)
        assert not faults.is_transient(ValueError("no"))
        assert faults.is_transient(
            faults.InjectedFault("z", transient=True))


# ---------------------------------------------------------------------------
# retry with backoff
# ---------------------------------------------------------------------------

def test_retry_absorbs_transient_within_budget():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise faults.InjectedFault("t", transient=True)
        return "ok"

    assert faults.retry(flaky) == "ok"
    assert len(calls) == 3


def test_retry_exhausts_then_raises():
    def always():
        raise faults.InjectedFault("t", transient=True)

    with pytest.raises(faults.InjectedFault):
        faults.retry(always, retries=2, base_delay=1e-4)


def test_retry_never_masks_fatal():
    calls = []

    def fatal():
        calls.append(1)
        raise ValueError("real bug")

    with pytest.raises(ValueError):
        faults.retry(fatal)
    assert len(calls) == 1                          # no retry on non-transient


def test_event_log_bounded_and_sequenced():
    log = EventLog(cap=4)
    for i in range(10):
        log.emit("e", i=i)
    evs = log.as_list()
    assert len(evs) == 4
    assert [e["seq"] for e in evs] == [7, 8, 9, 10]
    log.clear()
    assert log.emit("f")["seq"] == 11               # seq survives clear


def test_flush_log_writes_jsonl(tmp_path):
    with faults.installed(faults.FaultPlan.parse("w@1")):
        with pytest.raises(faults.InjectedFault):
            faults.fault_point("w")
        path = tmp_path / "fault_log.jsonl"
        out = faults.flush_log(str(path))
    assert out == str(path)
    lines = path.read_text().strip().splitlines()
    assert lines and '"inject"' in lines[-1] and '"w@1"' in lines[-1]


# ---------------------------------------------------------------------------
# bounded-wait harvest (satellite 1)
# ---------------------------------------------------------------------------

class _NeverReady:
    """A device-result stand-in whose transfer never completes."""

    def is_ready(self):
        return False


def test_bounded_wait_passes_ready_results():
    x = jnp.arange(4)
    jax.block_until_ready(x)
    assert bounded_wait(x, 0.5, what="x") is x
    # numpy / scalar leaves are trivially ready
    assert bounded_wait({"a": np.zeros(3), "b": 1.0}, 0.01) is not None


def test_bounded_wait_raises_on_stuck_result():
    t0 = time.perf_counter()
    with pytest.raises(HarvestTimeout, match="stuck-bucket"):
        bounded_wait(_NeverReady(), 0.05, what="stuck-bucket")
    assert time.perf_counter() - t0 < 5.0           # bounded, not a hang


def test_bounded_wait_none_timeout_is_native():
    assert bounded_wait(jnp.zeros(2), None) is not None


def test_harvest_timeout_surfaces_and_preserves_pending():
    """A stuck pending bucket raises HarvestTimeout out of the hot loop and
    leaves the entry on the pending deque (nothing silently dropped)."""
    sched = object.__new__(S.ContinuousScheduler)
    sched.sc = ServeConfig(capacity=2, harvest_timeout_s=0.05)
    sched._pending = S.deque([(([1, 0],), _NeverReady())])
    sched.results = {}
    with pytest.raises(HarvestTimeout):
        sched._harvest_one()
    assert len(sched._pending) == 1                 # restored, not dropped


# ---------------------------------------------------------------------------
# ring backpressure: retried drain + no-progress guard
# ---------------------------------------------------------------------------

def _full_ring():
    sc = ServeConfig(capacity=2, queue_depth=1)     # ring size 2
    rq = RingQueue(sc, StageExecutor(), ServeStats())
    slab = {"h": jnp.arange(4.0).reshape(2, 2)}
    ids = jnp.asarray([0, 1], jnp.int32)
    rq.enqueue(slab, ids, 2, lambda: None)          # fills the ring exactly
    return rq, slab, ids


def test_ring_stall_drain_no_progress_raises():
    with faults.installed(None):
        rq, slab, ids = _full_ring()
        with pytest.raises(RuntimeError, match="no progress"):
            rq.enqueue(slab, ids, 2, lambda: None)  # drain frees nothing


def test_ring_stall_drain_transient_fault_survives():
    with faults.installed(faults.FaultPlan.parse("drainpt@1#transient")):
        rq, slab, ids = _full_ring()
        drains = []

        def drain_one():
            faults.fault_point("drainpt")           # 1st call: transient
            popped = rq.pop()
            assert popped is not None
            drains.append(popped[2])

        rq.enqueue(slab, ids, 2, drain_one)
        assert drains == [2]                        # retried, then drained
        assert rq.count == 2
