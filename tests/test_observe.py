"""The observability plane (``runtime/observe.py``): frozen metrics
schema, Prometheus exposition render/parse, the zero-dependency HTTP
endpoint, span assembly + Chrome trace export against a REAL continuous
scheduler run (toy stage fns), the stats sampler's counters, and the
profiler hooks' inert-by-default contract.
"""
import json
import urllib.request

import pytest

from repro.kernels import dispatch
from repro.runtime import observe
from repro.runtime import serve_loop as SL
from repro.runtime.scheduler import LogicalClock
from repro.runtime.telemetry import EventLog

from test_scheduler import (_TOY_S, _toy_expected, _toy_requests,
                            toy_decode_fns)

# ---------------------------------------------------------------------------
# the FROZEN metrics schema — adding/renaming/relabeling a metric must be a
# deliberate, reviewed act (dashboards and alerts key on these), exactly
# like the ServeStats v3 key set in test_serve_api.py
# ---------------------------------------------------------------------------

_SCHEMA_V1 = {
    ("repro_requests_submitted_total", "c", ("replica",)),
    ("repro_requests_finished_total", "c", ("replica",)),
    ("repro_decisions_total", "c", ("replica",)),
    ("repro_exited_total", "c", ("replica",)),
    ("repro_stage2_total", "c", ("replica",)),
    ("repro_stalls_total", "c", ("replica",)),
    ("repro_buckets_total", "c", ("replica",)),
    ("repro_ring_bytes_moved_total", "c", ("replica",)),
    ("repro_migrations_total", "c", ("replica",)),
    ("repro_migration_rollbacks_total", "c", ("replica",)),
    ("repro_realized_q", "g", ("replica",)),
    ("repro_realized_q_ewma", "g", ("replica",)),
    ("repro_q_drift", "g", ("replica",)),
    ("repro_stage1_occupancy", "g", ("replica",)),
    ("repro_stage2_occupancy", "g", ("replica",)),
    ("repro_mean_bucket_fill", "g", ("replica",)),
    ("repro_slots_busy", "g", ("replica",)),
    ("repro_queue_depth", "g", ("replica",)),
    ("repro_cache_pages_total", "g", ("replica",)),
    ("repro_cache_pages_in_use", "g", ("replica",)),
    ("repro_cache_pages_in_use_peak", "g", ("replica",)),
    ("repro_cache_hbm_bytes", "g", ("replica",)),
    ("repro_page_fragmentation", "g", ("replica",)),
    ("repro_events_dropped_total", "c", ("feed",)),
    ("repro_routed_total", "c", ("policy",)),
    ("repro_preemptions_total", "c", ()),
    ("repro_fleet_pending", "g", ()),
    ("repro_backend_resolutions_total", "c", ()),
    ("repro_jit_cache_entries", "g", ()),
    ("repro_scrapes_total", "c", ()),
    ("repro_request_latency_seconds", "h", ("replica",)),
}


def test_metrics_schema_is_frozen():
    got = {(n, k, labels) for n, k, labels, _ in observe.METRICS_SCHEMA}
    assert got == _SCHEMA_V1, (
        "METRICS_SCHEMA changed — dashboards/alerts key on metric names "
        "and labels; update _SCHEMA_V1 here only as a deliberate schema "
        f"bump. diff: {got.symmetric_difference(_SCHEMA_V1)}")
    helps = [h for *_x, h in observe.METRICS_SCHEMA]
    assert all(helps), "every metric needs HELP text"


# ---------------------------------------------------------------------------
# registry + exposition
# ---------------------------------------------------------------------------

def test_registry_is_closed():
    reg = observe.MetricsRegistry()
    with pytest.raises(KeyError):
        reg.get("repro_made_up_total")


def test_metric_label_validation():
    reg = observe.MetricsRegistry()
    with pytest.raises(ValueError):
        reg.get("repro_requests_finished_total").inc(1, shard="x")


def test_exposition_round_trip():
    reg = observe.MetricsRegistry()
    reg.get("repro_requests_finished_total").inc(3, replica=0)
    reg.get("repro_realized_q").set(0.25, replica=0)
    reg.get("repro_fleet_pending").set(7)
    text = reg.exposition()
    assert "# HELP repro_requests_finished_total" in text
    assert "# TYPE repro_requests_finished_total counter" in text
    got = observe.parse_exposition(text)
    assert got['repro_requests_finished_total{replica="0"}'] == 3.0
    assert got['repro_realized_q{replica="0"}'] == 0.25
    assert got["repro_fleet_pending"] == 7.0
    assert got["repro_scrapes_total"] == 1.0        # the render counted


def test_counter_set_total_is_monotone_max():
    reg = observe.MetricsRegistry()
    m = reg.get("repro_decisions_total")
    m.set_total(10, replica=0)
    m.set_total(7, replica=0)          # stale sample never regresses it
    m.set_total(12, replica=0)
    assert m.value(replica=0) == 12.0


def test_histogram_exposition_cumulative():
    reg = observe.MetricsRegistry()
    h = reg.get("repro_request_latency_seconds")
    for v in (0.003, 0.003, 0.3, 20.0):
        h.observe(v, replica=0)
    got = observe.parse_exposition(reg.exposition())
    k = 'repro_request_latency_seconds_bucket{replica="0",le="%s"}'
    assert got[k % "0.005"] == 2.0
    assert got[k % "0.5"] == 3.0
    assert got[k % "+Inf"] == 4.0                    # cumulative
    assert got['repro_request_latency_seconds_count{replica="0"}'] == 4.0
    assert got['repro_request_latency_seconds_sum{replica="0"}'] == \
        pytest.approx(20.306)


def test_parse_exposition_rejects_garbage():
    with pytest.raises(ValueError):
        observe.parse_exposition("")
    with pytest.raises(ValueError):
        observe.parse_exposition("this is not prometheus text\n")


def test_metrics_server_scrape(tmp_path):
    reg = observe.MetricsRegistry()
    reg.get("repro_fleet_pending").set(3)
    with observe.MetricsServer(reg, port=0) as srv:
        assert srv.port > 0
        with urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/metrics", timeout=10) as r:
            assert r.status == 200
            assert "text/plain" in r.headers["Content-Type"]
            body = r.read().decode()
    got = observe.parse_exposition(body)
    assert got["repro_fleet_pending"] == 3.0
    # dump path shares the renderer
    out = tmp_path / "m.prom"
    observe.dump_metrics(reg, str(out))
    assert observe.parse_exposition(out.read_text())["repro_fleet_pending"] \
        == 3.0


# ---------------------------------------------------------------------------
# tracer + sampler against a real continuous-scheduler run
# ---------------------------------------------------------------------------

def _observed_toy_run(n_toks=(5, 1, 3, 6, 2), q_pct=40):
    events = EventLog(cap=4096)
    tracer = observe.Tracer()
    reg = observe.MetricsRegistry()
    sampler = observe.StatsSampler(reg, cadence_s=0.0)   # sample every event
    fns = toy_decode_fns(q_pct=q_pct)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    sched = SL.ContinuousScheduler(fns, sc, n_slots=3, max_len=_TOY_S + 6,
                                   clock=LogicalClock(), events=events)
    tracer.attach_scheduler(sched)
    sampler.attach_scheduler(sched)
    for r in _toy_requests(list(n_toks)):
        sched.submit(r)
    res = sched.run()
    sampler.sample()
    sampler.close()
    tracer.close()
    return res, tracer, reg, sched


def test_tracer_on_real_scheduler_run():
    n_toks = (5, 1, 3, 6, 2)
    res, tracer, _reg, _sched = _observed_toy_run(n_toks)
    assert res == _toy_expected(list(n_toks))        # tracing never perturbs
    comp = tracer.completeness(expect_sids=set(range(len(n_toks))))
    assert comp["complete"], comp
    assert comp["n_finished"] == len(n_toks)


def test_sampler_feeds_registry_from_real_run():
    n_toks = (5, 1, 3, 6, 2)
    _res, _tracer, reg, sched = _observed_toy_run(n_toks)
    got = observe.parse_exposition(reg.exposition())
    assert got['repro_requests_finished_total{replica="0"}'] == len(n_toks)
    assert got['repro_requests_submitted_total{replica="0"}'] == len(n_toks)
    assert got['repro_decisions_total{replica="0"}'] == \
        sched.stats.n_decisions
    assert got['repro_stage2_total{replica="0"}'] == sched.stats.n_stage2
    assert got['repro_request_latency_seconds_count{replica="0"}'] == \
        len(n_toks)
    assert got["repro_jit_cache_entries"] >= 0
    assert got["repro_backend_resolutions_total"] >= 1


def test_chrome_trace_structure():
    n_toks = (3, 2)
    _res, tracer, _reg, _sched = _observed_toy_run(n_toks)
    trace = tracer.chrome_trace()
    evs = trace["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert phases <= {"X", "i", "M"}
    assert any(e["ph"] == "X" and e["name"] == "request" for e in evs)
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and "pid" in e and "tid" in e
    meta = {(e["name"], e["args"]["name"]) for e in evs if e["ph"] == "M"}
    assert ("process_name", "replica0") in meta
    # round-trips through json (Perfetto loads files, not objects)
    json.loads(json.dumps(trace))


def test_span_jsonl_export(tmp_path):
    _res, tracer, _reg, _sched = _observed_toy_run((3, 2))
    p = tmp_path / "spans.jsonl"
    n = tracer.export_jsonl(str(p))
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert len(lines) == n > 0
    kinds = {ln["kind"] for ln in lines}
    assert kinds == {"span", "instant"}


def test_export_events_jsonl_appends_with_extra(tmp_path):
    log = EventLog(cap=16)
    log.emit("a", x=1)
    log.emit("b", y=2)
    p = tmp_path / "ev.jsonl"
    assert observe.export_events_jsonl(str(p), log, pid=123) == 2
    assert observe.export_events_jsonl(str(p), log, pid=123) == 2  # append
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert len(lines) == 4
    assert all(ln["pid"] == 123 for ln in lines)
    assert lines[0]["event"] == "a" and lines[0]["x"] == 1


def test_sampler_tracks_dropped_events():
    reg = observe.MetricsRegistry()
    sampler = observe.StatsSampler(reg, cadence_s=0.0)
    log = EventLog(cap=2)
    sampler.attach_log("tiny", log)
    for i in range(5):
        log.emit("e", i=i)
    sampler.sample()
    sampler.close()
    assert reg.get("repro_events_dropped_total").value(feed="tiny") == 3.0


# ---------------------------------------------------------------------------
# profiler hooks + backend-resolution counter
# ---------------------------------------------------------------------------

def test_annotate_is_inert_by_default():
    assert not observe.profiling_active()
    with observe.annotate("anything"):
        pass                          # nullcontext: no profiler dependency
    assert observe.annotate("a") is observe.annotate("b")  # shared, no alloc


def test_backend_resolution_counter_memoized():
    n0 = dispatch.n_backend_resolutions()
    b1 = dispatch.kernel_backend()
    n1 = dispatch.n_backend_resolutions()
    b2 = dispatch.kernel_backend()    # memo hit: same args
    n2 = dispatch.n_backend_resolutions()
    assert b1 == b2
    assert n1 >= n0
    assert n2 == n1                   # a hit never counts as a resolution


def test_jit_cache_entries_counts():
    assert isinstance(observe.jit_cache_entries(), int)
    assert observe.jit_cache_entries() >= 0
