"""Loss + optimizer correctness: chunked CE vs naive, AdamW vs a numpy
reference, int8 gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; skip, not error, when absent
from hypothesis import given, settings, strategies as st

from repro.core import losses
from repro.optim import adamw

SET = settings(max_examples=25, deadline=None)


@SET
@given(st.integers(1, 4), st.integers(3, 40), st.integers(0, 2**31 - 1))
def test_chunked_ce_equals_naive(batch, seq, seed):
    cfg = _tiny()
    k = jax.random.PRNGKey(seed)
    params = {"embed": {"table": jax.random.normal(
        k, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02}}
    hidden = jax.random.normal(jax.random.fold_in(k, 1),
                               (batch, seq, cfg.d_model), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(k, 2), (batch, seq), 0,
                                cfg.vocab)
    got = losses.chunked_ce(params, cfg, hidden, labels, chunk=7)
    logits = hidden @ params["embed"]["table"].T
    logp = jax.nn.log_softmax(logits, axis=-1)
    want = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))
    np.testing.assert_allclose(float(got), float(want), rtol=2e-5)


def _tiny():
    from repro.models.config import ArchConfig
    return ArchConfig(name="t", family="dense", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=50,
                      dtype="float32", param_dtype="float32")


def test_chunked_ce_respects_mask():
    cfg = _tiny()
    k = jax.random.PRNGKey(0)
    params = {"embed": {"table": jax.random.normal(k, (cfg.vocab,
                                                       cfg.d_model))}}
    hidden = jax.random.normal(jax.random.fold_in(k, 1), (2, 10, cfg.d_model))
    labels = jax.random.randint(jax.random.fold_in(k, 2), (2, 10), 0, 50)
    mask = jnp.zeros((2, 10)).at[:, :5].set(1.0)
    got = losses.chunked_ce(params, cfg, hidden, labels, mask=mask, chunk=4)
    want = losses.chunked_ce(params, cfg, hidden[:, :5], labels[:, :5],
                             chunk=5)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# AdamW vs numpy reference
# ---------------------------------------------------------------------------

def _np_adamw(cfg, params, grads, steps):
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v = {k: np.zeros_like(x) for k, x in params.items()}
    p = {k: x.copy() for k, x in params.items()}
    import math
    for t in range(1, steps + 1):
        # mirror adamw.schedule: lr * warmup_frac * cosine(min_lr_frac)
        warm = min(t / max(cfg.warmup_steps, 1), 1.0)
        frac = min(max((t - cfg.warmup_steps) /
                       max(cfg.total_steps - cfg.warmup_steps, 1), 0.0), 1.0)
        cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + math.cos(math.pi * frac))
        lr = cfg.lr * warm * cos
        gn = np.sqrt(sum((g ** 2).sum() for g in grads.values()))
        scale = min(1.0, cfg.clip_norm / max(gn, 1e-12))
        for k in p:
            g = grads[k] * scale
            m[k] = cfg.b1 * m[k] + (1 - cfg.b1) * g
            v[k] = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
            mh = m[k] / (1 - cfg.b1 ** t)
            vh = v[k] / (1 - cfg.b2 ** t)
            p[k] = p[k] - lr * (mh / (np.sqrt(vh) + cfg.eps) +
                                cfg.weight_decay * p[k])
    return p


def test_adamw_matches_numpy_reference():
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=10,
                            clip_norm=1.0)
    k = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(k, (5, 3)),
              "b": jax.random.normal(jax.random.fold_in(k, 1), (4,))}
    grads = {"a": jax.random.normal(jax.random.fold_in(k, 2), (5, 3)),
             "b": jax.random.normal(jax.random.fold_in(k, 3), (4,))}
    state = adamw.init(cfg, params)
    p = params
    for _ in range(3):
        p, state, _ = adamw.update(cfg, state, p, grads)
    want = _np_adamw(cfg, {k: np.asarray(v) for k, v in params.items()},
                     {k: np.asarray(v) for k, v in grads.items()}, 3)
    for key in p:
        np.testing.assert_allclose(np.asarray(p[key]), want[key],
                                   rtol=2e-5, atol=2e-6)


def test_adamw_decreases_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, clip_norm=1e9)
    params = {"x": jnp.array([3.0, -2.0])}
    state = adamw.init(cfg, params)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw.update(cfg, state, params, grads)
    assert float(jnp.abs(params["x"]).max()) < 0.15


# ---------------------------------------------------------------------------
# int8 gradient compression + error feedback
# ---------------------------------------------------------------------------

@SET
@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_compress_roundtrip_bounded_error(n, seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 3.0
    q, scale = adamw.compress_int8(g)
    back = adamw.decompress_int8(q, scale)
    assert q.dtype == jnp.int8
    err = float(jnp.abs(back - g).max())
    assert err <= float(scale) * 0.51 + 1e-9          # half a quantum


def test_error_feedback_accumulates():
    """With error feedback, the *sum* of decompressed grads converges to the
    sum of true grads (bias-free compression)."""
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100,
                            compress_grads=True)
    g = {"w": jnp.full((64,), 0.001)}                # tiny grads, brutal quant
    params = {"w": jnp.zeros((64,))}
    state = adamw.init(cfg, params)
    moved = 0.0
    for _ in range(50):
        params, state, _ = adamw.update(cfg, state, params, g)
    # without error feedback 0.001 would quantize to 0 forever
    assert float(jnp.abs(params["w"]).mean()) > 1e-4
