"""Profiler (§III-B.1) + DSE (fpgaConvNet optimizer analogue) + the
ATHEENA optimize flow on the paper's CNNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; skip, not error, when absent
from hypothesis import given, settings, strategies as st

from repro.core import dse, perf_model as pm, profiler as prof
from repro.core.stage_mesh import stage2_capacity
from repro.models.cnn import b_lenet, b_alexnet, triple_wins_lenet

SET = settings(max_examples=30, deadline=None)


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------

def _synthetic_logits(n, n_classes, frac_confident, seed=0):
    """First frac*n rows are confidently correct at exit 1."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, n)
    exit_logits = rng.normal(0, 0.1, (n, n_classes)).astype(np.float32)
    n_conf = int(frac_confident * n)
    exit_logits[np.arange(n_conf), y[:n_conf]] += 12.0
    final_logits = rng.normal(0, 0.1, (n, n_classes)).astype(np.float32)
    final_logits[np.arange(n), y] += 12.0          # final head always right
    return jnp.asarray(exit_logits), jnp.asarray(final_logits), jnp.asarray(y)


def test_profile_recovers_p():
    e, f, y = _synthetic_logits(1000, 10, frac_confident=0.75)
    p = prof.profile_early_exit(e, f, y, c_thr=0.9)
    assert abs(p.p_hard - 0.25) < 0.02
    assert p.exit_accuracy > 0.99
    assert p.cumulative_accuracy > 0.99
    assert len(p.p_hard_splits) == 5
    assert abs(np.mean(p.p_hard_splits) - p.p_hard) < 1e-6


def test_sweep_thresholds_monotone_p():
    e, f, y = _synthetic_logits(800, 10, frac_confident=0.6)
    profs = prof.sweep_thresholds(e, f, y, [0.2, 0.5, 0.9, 0.99])
    ps = [pr.p_hard for pr in profs]
    assert all(a <= b + 1e-9 for a, b in zip(ps, ps[1:]))   # higher thr, more hard


def test_make_test_set_with_q_exact():
    e, f, y = _synthetic_logits(2000, 10, frac_confident=0.5)
    for q in (0.2, 0.25, 0.3):
        idx = prof.make_test_set_with_q(e, y, c_thr=0.9, q=q, n=400, seed=1)
        from repro.core import exit_decision as ed
        mask = np.asarray(ed.exit_decision(e, 0.9))
        realized = float((~mask[idx]).mean())
        assert abs(realized - q) < 0.005


# ---------------------------------------------------------------------------
# folding / pipeline model
# ---------------------------------------------------------------------------

@SET
@given(st.lists(st.floats(10, 1e5), min_size=2, max_size=8),
       st.integers(4, 512))
def test_optimal_folding_within_budget(workloads, budget):
    alloc = pm.optimal_folding(workloads, budget)
    assert sum(alloc) <= max(budget, len(workloads))
    assert all(a >= 1 for a in alloc)


def test_pipeline_rate_bottleneck():
    # rate is set by the worst (workload/parallelism) stage
    r = pm.pipeline_rate([100.0, 400.0], [1, 2], clock=1000.0)
    assert abs(r - 1000.0 / 200.0) < 1e-9


def test_cnn_stage_workloads_positive():
    for cfg in (b_lenet(), b_alexnet(), triple_wins_lenet()):
        for si in range(len(cfg.stages)):
            w = pm.cnn_stage_workloads(cfg, si)
            assert w and all(x > 0 for x in w)
        w = pm.cnn_exit_workloads(cfg, 0)
        assert w and all(x > 0 for x in w)


def test_folding_dse_beats_or_matches_waterfill():
    w = pm.cnn_stage_workloads(b_lenet(), 0) + pm.cnn_exit_workloads(
        b_lenet(), 0)
    base = pm.pipeline_rate(w, pm.optimal_folding(w, 64))
    alloc, thr = dse.cnn_folding_dse(w, 64, iters=400, seed=0)
    assert sum(alloc) <= 64
    assert thr >= base * 0.999


# ---------------------------------------------------------------------------
# the ATHEENA optimizer on the paper's own networks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk,p", [(b_lenet, 0.25), (triple_wins_lenet, 0.25),
                                  (b_alexnet, 0.34)])
def test_atheena_gain_band(mk, p):
    """Paper Table IV: ATHEENA combined design achieves >1.3x the baseline
    throughput at matched resources (paper: 2.00-2.78x; the analytic model
    is conservative at small budgets)."""
    des = dse.atheena_optimize_cnn(mk(), p=p, budget=256, n_seeds=3)
    gain = des.gain_vs_baseline()
    assert gain > 1.3, f"{mk().name}: gain {gain:.2f}"
    # combined design stays within budget
    assert des.combined.resources[0] <= 256 + 1e-9


def test_atheena_q_robustness_ordering():
    des = dse.atheena_optimize_cnn(b_lenet(), p=0.25, budget=128, n_seeds=2)
    d = des.combined
    t_low = d.throughput_at(0.20)
    t_eq = d.throughput_at(0.25)
    t_high = d.throughput_at(0.30)
    assert t_low >= t_eq >= t_high


# ---------------------------------------------------------------------------
# LM sharding DSE
# ---------------------------------------------------------------------------

def test_lm_dse_matches_exhaustive():
    from repro.configs.archs import QWEN2_1_5B
    cfg = QWEN2_1_5B
    got = dse.lm_sharding_dse(cfg, 0, cfg.n_layers, kind="prefill",
                              seq_len=4096, batch=32, chips=16, iters=200)
    assert got is not None
    best = None
    for tp in (1, 2, 4, 8, 16):
        for fsdp in (False, True):
            plan = pm.ShardPlan(dp=16 // tp, tp=tp, fsdp=fsdp)
            r = pm.stage_roofline(cfg, 0, cfg.n_layers, kind="prefill",
                                  seq_len=4096, batch=32, plan=plan)
            if r["feasible"] and (best is None or
                                  r["throughput"] > best["throughput"]):
                best = r
    assert abs(got["roofline"]["throughput"] - best["throughput"]) < \
        best["throughput"] * 0.05


@SET
@given(st.integers(1, 512), st.floats(0.01, 1.0))
def test_stage2_capacity_properties(batch, p):
    c = stage2_capacity(batch, p)
    assert c <= batch or c == 8            # min multiple for tiny batches
    if batch >= 8:
        assert c % 8 == 0 or c == batch
        assert c >= min(int(np.ceil(p * batch)), batch)
