"""End-to-end toolflow test — the paper's §IV study in miniature:
train B-LeNet on MNIST-like data -> profile p -> ATHEENA optimize (TAP ⊕)
-> verify throughput gain vs baseline and Fig. 4 q-robustness ordering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dse, exit_decision as ed, losses, profiler as prof
from repro.core.conditional import simulate_two_stage_queue
from repro.data.pipeline import mnist_like
from repro.models import cnn as C


@pytest.fixture(scope="module")
def trained_blenet():
    """A few hundred SGD steps on synthetic MNIST-like data: enough for
    confident easy-sample exits, cheap enough for CI."""
    cfg = C.b_lenet()
    data = mnist_like(2048, seed=0, hard_frac=0.3)
    params = C.init_cnn(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def step(p, x, y, lr):
        def loss_fn(p):
            outs = C.forward_all_exits(p, cfg, x)
            return losses.cnn_joint_loss(outs, y, (0.3, 1.0))[0]
        g = jax.grad(loss_fn)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])
    for i in range(120):
        lo = (i * 128) % 1920
        params = step(params, x[lo:lo + 128], y[lo:lo + 128], 0.05)
    return cfg, params, data


def test_toolflow_end_to_end(trained_blenet):
    cfg, params, data = trained_blenet
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])

    # --- profile (§III-B.1): exit probability + accuracies ---
    outs = C.forward_all_exits(params, cfg, x)
    exit_logits, final_logits = outs[0], outs[-1]
    c_thr = ed.calibrate_threshold(ed.softmax_confidence(exit_logits),
                                   target_exit_rate=0.75)
    profile = prof.profile_early_exit(exit_logits, final_logits, y, c_thr)
    assert 0.15 < profile.p_hard < 0.35
    # EE accuracy within 3 points of the full network (paper: ~match)
    assert profile.cumulative_accuracy > profile.baseline_accuracy - 0.03

    # --- ATHEENA optimize (Fig. 5): TAP curves + Eq. (1) ---
    des = dse.atheena_optimize_cnn(cfg, p=max(profile.p_hard, 0.05),
                                   budget=256, n_seeds=2)
    gain = des.gain_vs_baseline()
    assert gain > 1.3, f"combined design only {gain:.2f}x baseline"

    # --- Fig. 4 robustness: queue-simulated runtime throughput ---
    d = des.combined
    rng = np.random.default_rng(0)
    thr = {}
    for q in (0.20, 0.25, 0.30):
        n_test = 1024
        seq = (rng.random(n_test) < q).astype(int)
        r = simulate_two_stage_queue(
            seq, stage1_rate=d.stage1.throughput,
            stage2_rate=d.stage2.throughput,
            buffer_depth=max(8, int(0.15 * n_test)))
        thr[q] = r["throughput"]
    assert thr[0.20] >= thr[0.25] * 0.98
    assert thr[0.25] >= thr[0.30] * 0.98
    # queue sim approximates the Eq. (1) design point at q == p
    assert thr[0.25] > 0.75 * d.throughput_at(0.25)


def test_ee_serving_accuracy_matches_profile(trained_blenet):
    """Hardware-style EE serving (mask + merge) reproduces the profiler's
    cumulative accuracy exactly (same decisions, vectorized path)."""
    cfg, params, data = trained_blenet
    x, y = jnp.asarray(data["x"][:512]), np.asarray(data["y"][:512])
    outs = C.forward_all_exits(params, cfg, x)
    exit_logits, final_logits = outs[0], outs[-1]
    c_thr = 0.9
    mask = np.asarray(ed.exit_decision(exit_logits, c_thr))
    pred = np.where(mask, np.asarray(jnp.argmax(exit_logits, -1)),
                    np.asarray(jnp.argmax(final_logits, -1)))
    acc_serve = float((pred == y).mean())
    profile = prof.profile_early_exit(exit_logits, final_logits,
                                      jnp.asarray(y), c_thr)
    assert abs(acc_serve - profile.cumulative_accuracy) < 1e-9


def test_baseline_vs_ee_compute_saving(trained_blenet):
    """Average per-sample MACs with early exit < backbone MACs (the whole
    point): expected MACs = stage1 + exit + p * stage2."""
    from repro.core import perf_model as pm
    cfg, params, data = trained_blenet
    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])
    outs = C.forward_all_exits(params, cfg, x)
    c_thr = ed.calibrate_threshold(ed.softmax_confidence(outs[0]), 0.75)
    p_hard = float((~np.asarray(ed.exit_decision(outs[0], c_thr))).mean())
    w1 = sum(pm.cnn_stage_workloads(cfg, 0)) + sum(pm.cnn_exit_workloads(cfg, 0))
    w2 = sum(pm.cnn_stage_workloads(cfg, 1))
    ee_macs = w1 + p_hard * w2
    base_macs = sum(pm.cnn_stage_workloads(cfg, 0)) + w2
    assert ee_macs < base_macs
