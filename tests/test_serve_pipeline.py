"""Device-resident serving pipeline: kernel dispatch parity on the edge
cases the runtime actually hits, ring-buffer semantics, and end-to-end
equivalence of the device server against the seed host-loop path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import early_exit as ee
from repro.kernels import dispatch
from repro.kernels.exit_decision.kernel import exit_decision_pallas
from repro.kernels.exit_decision.ref import exit_decision_ref
from repro.kernels.gather_compact.ref import gather_compact_ref
from repro.runtime import serve_loop as SL


# ---------------------------------------------------------------------------
# dispatch layer
# ---------------------------------------------------------------------------

@pytest.fixture(autouse=True)
def _hermetic_backend_env(monkeypatch):
    """Keep the suite hermetic to a stray REPRO_KERNEL_BACKEND left in the
    env — EXCEPT the CI interpret job's explicit opt-in, which must reach
    the dispatch layer so the e2e server tests execute the Pallas kernel
    bodies rather than the jnp refs."""
    import os
    if os.environ.get("REPRO_KERNEL_BACKEND") != "interpret":
        monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)


def test_backend_resolution_off_tpu(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    assert dispatch.kernel_backend() == "ref"        # auto on CPU
    assert dispatch.kernel_backend("pallas") == "interpret"
    assert dispatch.kernel_backend("ref") == "ref"
    with pytest.raises(ValueError):
        dispatch.kernel_backend("vulkan")


def test_set_backend_override(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    dispatch.set_backend("interpret")
    try:
        assert dispatch.kernel_backend() == "interpret"
    finally:
        dispatch.set_backend(None)
    assert dispatch.kernel_backend() == "ref"
    with pytest.raises(ValueError):
        dispatch.set_backend("nope")


def test_dispatch_backends_agree():
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 520)) * 4.0
    mask = jax.random.bernoulli(jax.random.PRNGKey(1), 0.4, (6,))
    for backend in ("interpret", "ref"):
        e, p, c = dispatch.exit_decision_op(x, 0.7, backend=backend)
        er, pr, cr = exit_decision_ref(x, 0.7)
        np.testing.assert_array_equal(np.asarray(e), np.asarray(er))
        np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))
        np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                                   rtol=1e-5, atol=1e-6)
        s, i, n = dispatch.gather_compact_op(x, mask, 4, backend=backend)
        sr, ir, nr = gather_compact_ref(x, mask, 4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
        assert int(n) == int(nr)


# ---------------------------------------------------------------------------
# kernel parity on runtime edge cases (interpret-mode kernel body vs oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("capacity", [2, 4])
def test_gather_compact_overflow(capacity):
    """n_hard > capacity: slab keeps the first ``capacity`` hard rows in
    order, ids report them, n_hard reports the true (overflowing) count."""
    x = jnp.arange(16, dtype=jnp.float32).reshape(8, 2)
    mask = jnp.array([1, 1, 0, 1, 1, 1, 0, 1], bool)       # 6 hard rows
    for backend in ("interpret", "ref"):
        s, ids, n = dispatch.gather_compact_op(x, mask, capacity,
                                               backend=backend)
        assert int(n) == 6
        hard_rows = [0, 1, 3, 4, 5, 7][:capacity]
        np.testing.assert_array_equal(np.asarray(ids), hard_rows)
        np.testing.assert_allclose(np.asarray(s), np.asarray(x)[hard_rows])


@pytest.mark.parametrize("backend", ["interpret", "ref"])
def test_gather_compact_all_and_none_exit(backend):
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 3))
    none_hard = jnp.zeros((5,), bool)                      # everyone exits
    s, ids, n = dispatch.gather_compact_op(x, none_hard, 5, backend=backend)
    assert int(n) == 0
    np.testing.assert_array_equal(np.asarray(ids), [-1] * 5)
    all_hard = jnp.ones((5,), bool)                        # nobody exits
    s, ids, n = dispatch.gather_compact_op(x, all_hard, 5, backend=backend)
    assert int(n) == 5
    np.testing.assert_array_equal(np.asarray(ids), np.arange(5))
    np.testing.assert_allclose(np.asarray(s), np.asarray(x))


@pytest.mark.parametrize("vocab,block_v", [(300, 128), (520, 256), (97, 128)])
def test_exit_decision_vocab_not_block_multiple(vocab, block_v):
    """Vocab padding in the last tile must not perturb (m, l, argmax)."""
    x = (jax.random.normal(jax.random.PRNGKey(vocab), (9, vocab)) * 5.0
         ).astype(jnp.float32)
    ek, pk, ck = exit_decision_pallas(x, 0.6, block_v=block_v,
                                      interpret=True)
    er, pr, cr = exit_decision_ref(x, 0.6)
    np.testing.assert_array_equal(np.asarray(ek), np.asarray(er))
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# device ring buffer
# ---------------------------------------------------------------------------

def _enq(buf, rows, ids, pad_to=None):
    """Helper: enqueue a compacted slab (valid prefix + -1 flush slots)."""
    rows = jnp.asarray(rows, jnp.float32)
    ids = jnp.asarray(ids, jnp.int32)
    if pad_to and rows.shape[0] < pad_to:
        k = pad_to - rows.shape[0]
        rows = jnp.concatenate([rows, jnp.zeros((k,) + rows.shape[1:],
                                                rows.dtype)])
        ids = jnp.concatenate([ids, jnp.full((k,), -1, jnp.int32)])
    return SL.ring_enqueue(buf, rows, ids)


def test_ring_enqueue_drain_basic():
    buf = SL.ring_init(8, (2,), jnp.float32)
    buf = _enq(buf, [[0, 0], [1, 1], [2, 2]], [10, 11, 12], pad_to=4)
    assert int(buf["count"]) == 3
    buf, bucket, ids = SL.ring_drain(buf, 2)
    np.testing.assert_array_equal(np.asarray(ids), [10, 11])
    np.testing.assert_allclose(np.asarray(bucket)[:2], [[0, 0], [1, 1]])
    assert int(buf["count"]) == 1 and int(buf["head"]) == 2
    buf, bucket, ids = SL.ring_drain(buf, 2)          # partial drain
    np.testing.assert_array_equal(np.asarray(ids), [12, -1])
    assert int(buf["count"]) == 0


def test_ring_pytree_payload():
    """The generalized ring carries arbitrary pytrees: every leaf keeps its
    own (size, *row) slab under the shared cursors/id lane, and enqueue/
    drain preserve per-leaf row association by sample id."""
    row = {"h": jax.ShapeDtypeStruct((2,), jnp.float32),
           "cache": {"k": jax.ShapeDtypeStruct((3, 2), jnp.float32),
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}}
    buf = SL.ring_init(4, row)
    slab = {"h": jnp.arange(6, dtype=jnp.float32).reshape(3, 2),
            "cache": {"k": jnp.arange(18, dtype=jnp.float32).reshape(3, 3, 2),
                      "step": jnp.array([7, 8, 9], jnp.int32)}}
    buf = SL.ring_enqueue(buf, slab, jnp.array([10, 11, 12], jnp.int32))
    assert int(buf["count"]) == 3
    buf, bucket, ids = SL.ring_drain(buf, 2)
    np.testing.assert_array_equal(np.asarray(ids), [10, 11])
    np.testing.assert_allclose(np.asarray(bucket["h"]),
                               np.asarray(slab["h"][:2]))
    np.testing.assert_allclose(np.asarray(bucket["cache"]["k"]),
                               np.asarray(slab["cache"]["k"][:2]))
    np.testing.assert_array_equal(np.asarray(bucket["cache"]["step"]), [7, 8])
    assert int(buf["count"]) == 1


def test_ring_wraparound():
    """Writes and reads must wrap modulo the ring size without clobbering
    undrained samples."""
    buf = SL.ring_init(4, (1,), jnp.float32)
    buf = _enq(buf, [[0.0], [1.0], [2.0]], [0, 1, 2])
    buf, _, ids = SL.ring_drain(buf, 2)               # head -> 2
    np.testing.assert_array_equal(np.asarray(ids), [0, 1])
    buf = _enq(buf, [[3.0], [4.0], [5.0]], [3, 4, 5]) # wraps to slots 0,1
    assert int(buf["count"]) == 4
    buf, bucket, ids = SL.ring_drain(buf, 4)
    np.testing.assert_array_equal(np.asarray(ids), [2, 3, 4, 5])
    np.testing.assert_allclose(np.asarray(bucket)[:, 0], [2, 3, 4, 5])


def test_ring_flush_slots_dropped():
    """-1 (flush) slots in the incoming slab must not consume ring space."""
    buf = SL.ring_init(4, (1,), jnp.float32)
    buf = _enq(buf, [[7.0]], [42], pad_to=4)
    assert int(buf["count"]) == 1
    buf, _, ids = SL.ring_drain(buf, 4)
    np.testing.assert_array_equal(np.asarray(ids), [42, -1, -1, -1])


# ---------------------------------------------------------------------------
# end-to-end: device-resident server vs the seed host-loop path
# ---------------------------------------------------------------------------

def _serve_both(params, cfg, spec, sc, toks, batch):
    s1, s2 = SL._stage_fns(params, cfg, spec)
    dev = SL.TwoStageServer(s1, s2, sc)
    host = SL.HostLoopServer(s1, s2, sc)
    return (SL.serve_dataset(dev, toks, batch=batch), dev,
            SL.serve_dataset(host, toks, batch=batch), host)


def test_device_server_matches_host_loop_exactly(tiny_cfg, tiny_params):
    """The tentpole parity bar: merged logits identical (bitwise) between
    the new device-resident path and the seed host loop."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=0.3)
    N, B = 24, 8
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (N, 8), 0,
                                         tiny_cfg.vocab))
    sc = SL.ServeConfig(capacity=4, queue_depth=4, c_thr=spec.c_thr)
    rd, dev, rh, host = _serve_both(tiny_params, tiny_cfg, spec, sc, toks, B)
    assert set(rd) == set(rh) == set(range(N))
    for sid in range(N):
        np.testing.assert_array_equal(rd[sid], rh[sid])
    assert dev.stats.n_samples == host.stats.n_samples == N
    assert dev.stats.n_exited == host.stats.n_exited
    assert dev.stats.n_stage2 == host.stats.n_stage2


def test_device_server_backpressure_stall(tiny_cfg, tiny_params):
    """All-hard traffic through a ring barely one batch deep: stage 1 must
    stall (full-bucket drains first), never deadlock, never drop."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=1.0)   # nothing exits
    N, B = 15, 3
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (N, 8), 0,
                                         tiny_cfg.vocab))
    # ring = one bucket of 4: a second all-hard batch of 3 cannot fit behind
    # the 3 residents, so stage 1 must stall and drain a partial bucket
    sc = SL.ServeConfig(capacity=4, queue_depth=1, c_thr=spec.c_thr)
    rd, dev, rh, host = _serve_both(tiny_params, tiny_cfg, spec, sc, toks, B)
    assert set(rd) == set(range(N))
    assert dev.stats.n_stage2 == N and dev.stats.n_exited == 0
    assert dev.stats.n_stalls > 0
    for sid in range(N):
        np.testing.assert_array_equal(rd[sid], rh[sid])


def test_device_server_batch_larger_than_ring(tiny_cfg, tiny_params):
    """An all-hard batch twice the ring size must still serve correctly:
    the enqueue chunks, stalling stage 1 while full buckets drain."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=1.0)   # nothing exits
    N, B = 16, 8
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (N, 8), 0,
                                         tiny_cfg.vocab))
    sc = SL.ServeConfig(capacity=2, queue_depth=2,     # ring of 4 < B of 8
                        c_thr=spec.c_thr)
    rd, dev, rh, host = _serve_both(tiny_params, tiny_cfg, spec, sc, toks, B)
    assert set(rd) == set(range(N))
    assert dev.stats.n_stage2 == N and dev.stats.n_stalls > 0
    for sid in range(N):
        np.testing.assert_array_equal(rd[sid], rh[sid])


def test_device_server_matches_serve_batch(tiny_cfg, tiny_params):
    """New path vs the one-shot fused pipeline (different jit partitions,
    so allclose rather than bitwise)."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    N = 16
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (N, 8), 0,
                                         tiny_cfg.vocab))
    server = SL.build_server(tiny_params, tiny_cfg, spec,
                             SL.ServeConfig(capacity=4, c_thr=spec.c_thr))
    results = SL.serve_dataset(server, toks, batch=8)
    one = ee.serve_batch(tiny_params, tiny_cfg, spec, jnp.asarray(toks),
                         capacity=N)
    merged = np.asarray(one["logits"])
    for sid in range(N):
        np.testing.assert_allclose(results[sid], merged[sid], rtol=2e-4,
                                   atol=2e-4)


def test_device_server_bounded_pending(tiny_cfg, tiny_params):
    """With a tiny max_pending, long streams harvest results incrementally
    during submit (bounded device memory) and still match the host loop."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=0.3)
    N, B = 32, 4
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(11), (N, 8), 0,
                                         tiny_cfg.vocab))
    sc = SL.ServeConfig(capacity=2, queue_depth=4, c_thr=spec.c_thr,
                        max_pending=2)
    rd, dev, rh, host = _serve_both(tiny_params, tiny_cfg, spec, sc, toks, B)
    assert set(rd) == set(range(N))
    assert len(dev._easy) == 0 and len(dev._buckets) == 0
    for sid in range(N):
        np.testing.assert_array_equal(rd[sid], rh[sid])
    # backlog stayed bounded: results already present before the final flush
    s1, s2 = SL._stage_fns(tiny_params, tiny_cfg, spec)
    srv = SL.TwoStageServer(s1, s2, sc)
    partial: dict = {}
    for lo in range(0, N, B):
        srv.submit(toks[lo:lo + B], np.arange(lo, lo + B), partial)
        assert len(srv._easy) + len(srv._buckets) <= sc.max_pending
    assert partial                      # harvested incrementally
    srv.flush(partial)
    assert set(partial) == set(range(N))


def test_serve_stats_running_aggregate():
    """bucket_fill is an O(1) running aggregate, not an unbounded list."""
    st = SL.ServeStats()
    assert st.mean_bucket_fill == 0.0
    for f in (1.0, 0.5, 0.75):
        st.record_bucket(f)
    assert st.n_buckets == 3
    np.testing.assert_allclose(st.mean_bucket_fill, 0.75)
    assert "mean_bucket_fill" in st.as_dict()
    assert not any(isinstance(v, list) for v in vars(st).values())
