"""FIFO property tests of the generalized (pytree) device ring buffer:
any interleaving of chunked enqueues and drains preserves per-leaf rows
and sample-id association, across wraparound and overflow clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, not error, when absent
from hypothesis import given, settings, strategies as st

from repro.runtime import serve_loop as SL

_ROW_WIDTH = 4          # fixed slab width -> one enqueue compilation per size


def _row_of(i: int):
    """Deterministic per-id row pytree, so id association is checkable."""
    return {"a": np.array([i, i + 0.5], np.float32),
            "b": {"c": np.array([i, 2 * i, 3 * i], np.int32)}}


def _slab_of(ids):
    """Compacted slab: valid prefix + flush (-1) padding to _ROW_WIDTH."""
    rows = [_row_of(i) for i in ids] + [_row_of(0)] * (_ROW_WIDTH - len(ids))
    slab = jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                        *rows)
    sid = jnp.asarray(np.array(list(ids) + [-1] * (_ROW_WIDTH - len(ids)),
                               np.int32))
    return slab, sid


@settings(deadline=None, max_examples=20)
@given(data=st.data())
def test_ring_pytree_fifo_property(data):
    """Against a reference FIFO: enqueue/drain of nested pytrees keeps every
    leaf's rows associated with their sample id, across wraparound (head
    cycles the slab many times) and overflow (enqueues clipped to free
    space, exactly like the server's chunked backpressure loop)."""
    size = data.draw(st.integers(3, 6), label="ring_size")
    row_spec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            _row_of(0))
    buf = SL.ring_init(size, row_spec)
    model, next_id = [], 0
    for _ in range(data.draw(st.integers(2, 10), label="n_ops")):
        if data.draw(st.booleans(), label="op_is_enqueue"):
            want = data.draw(st.integers(1, _ROW_WIDTH), label="enq_n")
            take = min(want, size - len(model))      # overflow clip (chunk)
            ids = list(range(next_id, next_id + take))
            next_id += take
            if take:
                slab, sid = _slab_of(ids)
                buf = SL.ring_enqueue(buf, slab, sid)
                model.extend(ids)
        else:
            cap = data.draw(st.integers(1, 3), label="drain_cap")
            buf, bucket, bids = SL.ring_drain(buf, cap)
            popped, model = model[:cap], model[cap:]
            np.testing.assert_array_equal(
                np.asarray(bids), popped + [-1] * (cap - len(popped)))
            for k, i in enumerate(popped):
                want_row = _row_of(i)
                np.testing.assert_allclose(
                    np.asarray(bucket["a"][k]), want_row["a"])
                np.testing.assert_array_equal(
                    np.asarray(bucket["b"]["c"][k]), want_row["b"]["c"])
        assert int(buf["count"]) == len(model)
    # final drain-everything: ids come out in exact arrival order
    leftovers = []
    while int(buf["count"]):
        buf, _, bids = SL.ring_drain(buf, 3)
        leftovers += [int(x) for x in np.asarray(bids) if x >= 0]
    assert leftovers == model
