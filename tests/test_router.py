"""Fleet router (`runtime/router.py`): per-sample token-stream equivalence
across N replicas under every routing policy, SLO priority admission with
requeue-never-drop preemption, replica degrade redistribution, the tenant
difficulty feed, the FleetStats/event ops surface — and a hypothesis
property test driving random fleets (policy, tenant mix, arrivals,
preemption pressure, one mid-trace degrade) against the analytic oracle."""
import numpy as np
import pytest

from repro.runtime import serve_loop as SL
from repro.runtime.router import (DEFAULT_SLO_CLASSES, DEGRADED, HEALTHY,
                                  ROUTING_POLICIES, FleetRouter, SLOClass,
                                  TenantState)
from repro.runtime.scheduler import (ContinuousScheduler, LogicalClock,
                                     Request)
from repro.runtime.telemetry import EventLog
from test_scheduler import _TOY_S, _toy_tok, toy_decode_fns

_MAX_LEN = _TOY_S + 6


def _req(sid, n_tokens=3, tenant="default", slo="standard", arrival=0.0):
    return Request(sample_id=sid, prompt=np.full((_TOY_S,), sid, np.int32),
                   n_tokens=n_tokens, tenant=tenant, slo_class=slo,
                   arrival_time=arrival)


def _expected(n_tokens_list):
    return {i: [_toy_tok(i, t) for t in range(n)]
            for i, n in enumerate(n_tokens_list)}


def _fleet(n_replicas=2, policy="drift_aware", q_pcts=None, n_slots=3,
           capacity=2, **kw):
    """N continuous replicas over toy DecodeFns sharing ONE LogicalClock.
    Different per-replica q_pct changes only the exit path, never the
    greedy tokens — streams stay placement-independent by construction."""
    q_pcts = q_pcts if q_pcts is not None else [50] * n_replicas
    clock = LogicalClock()
    sc = SL.ServeConfig(capacity=capacity, queue_depth=2, c_thr=0.5)
    reps = [ContinuousScheduler(toy_decode_fns(q), sc, n_slots=n_slots,
                                max_len=_MAX_LEN, clock=clock)
            for q in q_pcts]
    return FleetRouter(reps, policy=policy, **kw)


# ---------------------------------------------------------------------------
# the fleet contract: streams equal the single-scheduler oracle, no policy
# exceptions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ROUTING_POLICIES)
def test_fleet_stream_equivalence(policy):
    n_toks = [4, 1, 3, 6, 2, 5, 3, 4]
    router = _fleet(n_replicas=3, policy=policy, q_pcts=[0, 50, 100])
    for i, n in enumerate(n_toks):
        router.submit(_req(i, n, tenant=f"t{i % 2}"))
    assert router.run() == _expected(n_toks)
    d = router.stats.as_dict()
    assert d["n_dropped"] == 0
    assert d["n_finished"] == len(n_toks)
    assert d["n_submitted"] == d["n_routed"] == len(n_toks)
    # traffic actually spread: more than one replica served something
    assert sum(1 for r in d["replicas"] if r["n_samples"] > 0) >= 2


def test_fleet_requires_shared_clock():
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    reps = [ContinuousScheduler(toy_decode_fns(50), sc, n_slots=2,
                                max_len=_MAX_LEN, clock=LogicalClock())
            for _ in range(2)]                        # two DIFFERENT clocks
    with pytest.raises(ValueError, match="share ONE clock"):
        FleetRouter(reps)
    with pytest.raises(ValueError, match="at least one replica"):
        FleetRouter([])
    with pytest.raises(ValueError, match="policy must be one of"):
        _fleet(policy="random")


def test_fleet_rejects_duplicates_and_unknown_slo():
    router = _fleet()
    router.submit(_req(0))
    with pytest.raises(ValueError, match="duplicate sample id 0"):
        router.submit(_req(0))
    with pytest.raises(ValueError, match="unknown slo_class"):
        router.submit(_req(1, slo="platinum"))
    router.run()
    with pytest.raises(ValueError, match="duplicate sample id 0"):
        router.submit(_req(0))                        # finished sids too


def test_least_loaded_balances():
    router = _fleet(policy="least_loaded", max_queue_per_replica=4)
    for i in range(4):
        router.submit(_req(i))
    router._route()                                   # one admission pass
    loads = [r.n_busy + r.queue_len for r in router.replicas]
    assert loads == [2, 2]


def test_drift_aware_matches_difficulty_to_provisioning():
    router = _fleet(provisioned_p=[0.1, 0.9], max_queue_per_replica=4)
    # prior before any finish: the fleet's mean provisioned p
    assert router._tenant_difficulty("nobody") == pytest.approx(0.5)
    router.tenants["easy"] = TenantState(difficulty_ewma=0.05)
    router.tenants["hard"] = TenantState(difficulty_ewma=0.95)
    assert router._place(_req(0, tenant="easy"), [0, 1]) == 0
    assert router._place(_req(1, tenant="hard"), [0, 1]) == 1


def test_tenant_difficulty_learned_from_finish_feed():
    """All-hard traffic teaches difficulty 1.0, all-easy teaches 0.0 —
    the replica finish feed -> TenantState EWMA plumbing."""
    for q_pct, want in ((100, 1.0), (0, 0.0)):
        router = _fleet(n_replicas=1, q_pcts=[q_pct])
        for i in range(4):
            router.submit(_req(i, n_tokens=4, tenant="t"))
        router.run()
        t = router.tenants["t"]
        assert t.n_finished == 4
        assert t.difficulty_ewma == pytest.approx(want)


def test_tenant_state_ewma_alpha():
    t = TenantState()
    t.observe_finish(3, 3)                            # first finish: q
    assert t.difficulty_ewma == pytest.approx(1.0)
    t.observe_finish(0, 3)                            # alpha=0.3 fold
    assert t.difficulty_ewma == pytest.approx(0.7)
    t.observe_finish(0, 0)                            # no decisions: no-op
    assert t.difficulty_ewma == pytest.approx(0.7)


# ---------------------------------------------------------------------------
# SLO classes: priority admission, budgets, preemption (requeue, not drop)
# ---------------------------------------------------------------------------

def test_gold_preempts_queued_batch_and_nothing_drops():
    n_toks = [3] * 8
    router = _fleet(n_slots=2, max_queue_per_replica=1)
    for i in range(6):
        router.submit(_req(i, n_toks[i], tenant="bulk", slo="batch"))
    # fill slots (2+2) with batch, then one route-only pass so the replica
    # queues hold UNADMITTED batch victims when gold arrives
    for _ in range(2):
        router.step()
    router._route()
    assert sum(r.queue_len for r in router.replicas) > 0
    for i in (6, 7):
        router.submit(_req(i, n_toks[i], tenant="vip", slo="gold"))
    router.step()
    assert router.stats.n_preemptions >= 1
    assert router.stats.n_requeued >= 1
    assert router.tenants["bulk"].n_preempted >= 1
    assert router.run() == _expected(n_toks)          # preempted finished
    assert router.stats.as_dict()["n_dropped"] == 0


def test_max_inflight_budget_respected():
    slos = dict(DEFAULT_SLO_CLASSES)
    slos["batch"] = SLOClass("batch", 2, max_inflight=1)
    n_toks = [3, 3, 3]
    router = _fleet(n_replicas=1, slo_classes=slos)
    for i, n in enumerate(n_toks):
        router.submit(_req(i, n, tenant="t", slo="batch"))
    while router.step() != "idle":
        assert router.tenants["t"].inflight <= 1      # the budget
    assert router.run() == _expected(n_toks)


def test_preemption_never_touches_admitted_requests():
    """A victim admitted between the scan and the revoke yields an empty
    revoke — the router moves on instead of perturbing its stream."""
    router = _fleet(n_slots=2, max_queue_per_replica=1)
    router.submit(_req(0, 3, slo="batch"))
    router.step()                                     # sid 0 is ADMITTED
    assert router.replicas[0].n_busy + router.replicas[1].n_busy == 1
    assert router._try_preempt(_req(9, slo="gold"),
                               router.slo_classes["gold"]) is None
    assert router.stats.n_preemptions == 0


# ---------------------------------------------------------------------------
# health: degrade/restore, redistribution, the no-healthy-replica fence
# ---------------------------------------------------------------------------

def test_degrade_redistributes_and_streams_survive():
    n_toks = [3] * 8
    router = _fleet(n_slots=2, max_queue_per_replica=2)
    for i, n in enumerate(n_toks):
        router.submit(_req(i, n))
    router.step()                                     # some queued on r0
    n_redis = router.degrade_replica(0)
    assert router.health == [DEGRADED, HEALTHY]
    assert router.replicas[0].queue_len == 0          # queue revoked
    assert router.stats.n_degraded == 1
    assert router.stats.n_requeued == n_redis
    assert router.degrade_replica(0) == 0             # idempotent
    assert router.run() == _expected(n_toks)          # in-flight drained,
    assert router.stats.as_dict()["n_dropped"] == 0   # rest redistributed
    router.restore_replica(0)
    assert router.health == [HEALTHY, HEALTHY]


def test_no_healthy_replica_raises():
    router = _fleet()
    router.submit(_req(0))
    for i in range(2):
        router.degrade_replica(i)
    with pytest.raises(RuntimeError, match="no healthy replica"):
        router.step()


# ---------------------------------------------------------------------------
# ops surface: the event feed and the versioned fleet schema
# ---------------------------------------------------------------------------

def test_event_feed_streams_per_request_lifecycle():
    log = EventLog(cap=512)
    seen = []
    log.subscribe(lambda ev: seen.append(ev))
    n_toks = [3, 2, 4]
    router = _fleet(events=log)
    for i, n in enumerate(n_toks):
        router.submit(_req(i, n, tenant="t"))
    router.run()
    kinds = [ev["event"] for ev in seen]
    assert kinds.count("submit") == 3
    assert kinds.count("route") == 3
    assert kinds.count("finish") == 3
    assert [ev["seq"] for ev in seen] == sorted(ev["seq"] for ev in seen)
    fin = [ev for ev in seen if ev["event"] == "finish"]
    assert sorted(ev["sid"] for ev in fin) == [0, 1, 2]
    assert all(ev["tenant"] == "t" for ev in fin)


_FLEET_V2_KEYS = frozenset({
    "schema_version", "policy", "n_replicas", "n_pending", "n_submitted",
    "n_routed", "n_finished", "n_preemptions", "n_requeued", "n_degraded",
    "n_dropped", "fleet_realized_q", "fleet_cache_pages_total",
    "fleet_cache_pages_in_use", "fleet_cache_hbm_bytes",
    "fleet_ring_bytes_moved", "health", "tenants", "replicas",
})


def test_fleet_stats_schema():
    router = _fleet(provisioned_p=[0.2, 0.8])
    n_toks = [3, 2]
    for i, n in enumerate(n_toks):
        router.submit(_req(i, n, tenant="t"))
    router.run()
    d = router.stats.as_dict()
    assert set(d) == _FLEET_V2_KEYS
    assert d["schema_version"] == router.stats.SCHEMA_VERSION == 2
    assert d["policy"] == "drift_aware" and d["n_replicas"] == 2
    assert d["health"] == [HEALTHY, HEALTHY]
    assert d["tenants"]["t"]["n_finished"] == 2
    # each replica entry is itself the versioned ServeStats schema, with
    # the provisioned p the router stamped
    assert [r["schema_version"] for r in d["replicas"]] == [3, 3]
    assert [r["provisioned_p"] for r in d["replicas"]] == [0.2, 0.8]


# ---------------------------------------------------------------------------
# the property test: random fleets vs the analytic oracle
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_h
    _HAVE_HYP = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYP = False


if _HAVE_HYP:
    @settings(max_examples=20, deadline=None)
    @given(
        n_tokens_list=st_h.lists(st_h.integers(1, 6), min_size=2,
                                 max_size=12),
        policy=st_h.sampled_from(ROUTING_POLICIES),
        n_replicas=st_h.integers(1, 3),
        n_slots=st_h.integers(1, 3),
        max_queue=st_h.integers(1, 3),
        q_pcts=st_h.lists(st_h.integers(0, 100), min_size=3, max_size=3),
        tenant_picks=st_h.lists(st_h.integers(0, 2), min_size=12,
                                max_size=12),
        slo_picks=st_h.lists(
            st_h.sampled_from(["gold", "standard", "batch"]),
            min_size=12, max_size=12),
        arrival_gaps=st_h.lists(st_h.floats(0.0, 2.0), min_size=12,
                                max_size=12),
        pre_steps=st_h.integers(0, 4),
        degrade=st_h.booleans(),
    )
    def test_fleet_invariants_random(n_tokens_list, policy, n_replicas,
                                     n_slots, max_queue, q_pcts,
                                     tenant_picks, slo_picks, arrival_gaps,
                                     pre_steps, degrade):
        """Random fleet geometry x routing policy x tenant/SLO mix x
        arrival trace, with preemption pressure (bounded queues, mixed
        priorities) and one mid-trace replica degrade: no sample dropped
        or duplicated, every per-sample token stream exactly equal to the
        analytic oracle, all slots drained, nothing left pending."""
        router = _fleet(n_replicas=n_replicas, policy=policy,
                        q_pcts=q_pcts[:n_replicas], n_slots=n_slots,
                        max_queue_per_replica=max_queue)
        t = 0.0
        for i, n in enumerate(n_tokens_list):
            t += arrival_gaps[i]
            router.submit(_req(i, n, tenant=f"t{tenant_picks[i]}",
                               slo=slo_picks[i], arrival=t))
        for _ in range(pre_steps):
            if router.step() == "waiting":
                router.advance_clock()
        if degrade and n_replicas > 1:
            router.degrade_replica(0)                 # mid-trace loss
        res = router.run()
        expect = _expected(n_tokens_list)
        assert set(res) == set(expect)                # no drop, no phantom
        assert res == expect                          # order + no dup
        d = router.stats.as_dict()
        assert d["n_dropped"] == 0
        assert d["n_finished"] == len(n_tokens_list)
        assert d["n_pending"] == 0
        assert all(t["inflight"] == 0 for t in d["tenants"].values())
        for r in router.replicas:
            assert r.n_busy == 0 and r.queue_len == 0
