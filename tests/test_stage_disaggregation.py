"""Stage-disaggregated serving: StageExecutor placement semantics (the
degenerate single-device executor must be a strict no-op), per-stage param
splitting (bitwise vs full-tree stage fns), and end-to-end bitwise parity
of the disaggregated TwoStageServer / DecodeServer against the
single-device servers on an 8-device host platform — in-process when the
suite already runs multi-device (CI disaggregated job), and always via a
subprocess so the tier-1 single-device run covers the path too."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import early_exit as ee
from repro.runtime import serve_loop as SL
from repro.runtime.stage_executor import StageExecutor, StagePlacement

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_multi_device = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices (CI disaggregated job sets XLA_FLAGS)")


# ---------------------------------------------------------------------------
# degenerate executor: placement must be the identity
# ---------------------------------------------------------------------------

def test_degenerate_executor_is_identity():
    ex = StageExecutor()
    assert ex.mesh is None and ex.n_devices == 1 and ex.devices == ()
    x = jnp.arange(6.0).reshape(3, 2)
    tree = {"a": x, "b": {"c": jnp.ones((4,))}}
    assert ex.place(tree) is tree                 # no copy, no commitment
    assert ex.place_io(x) is x
    assert ex.sharding() is None


def test_default_placement_degenerate():
    pl = StagePlacement.single_device()
    assert not pl.disaggregated
    assert pl.ex1.mesh is None and pl.ex2.mesh is None
    # servers built with no placement get the degenerate one
    srv = SL._RingedServer(SL.ServeConfig(capacity=2))
    assert srv.ex1.mesh is None and srv.ex2.mesh is None
    assert srv.stats.stage1_chips == 1 and srv.stats.stage2_chips == 1


# ---------------------------------------------------------------------------
# split_params: per-stage residency slices, bitwise-identical programs
# ---------------------------------------------------------------------------

def test_split_params_residency(tiny_cfg, tiny_spec, tiny_params):
    p1, p2 = ee.split_params(tiny_cfg, tiny_spec, tiny_params)
    k_super = (tiny_spec.exit_layer - tiny_cfg.first_k_dense) \
        // tiny_cfg.pattern_len
    n_sb = tiny_cfg.n_superblocks
    for leaf1, leaf2, full in zip(
            jax.tree.leaves(p1["backbone"]["blocks"]),
            jax.tree.leaves(p2["backbone"]["blocks"]),
            jax.tree.leaves(tiny_params["backbone"]["blocks"])):
        assert leaf1.shape[0] == k_super
        assert leaf2.shape[0] == n_sb - k_super
        np.testing.assert_array_equal(np.asarray(full),
                                      np.concatenate([leaf1, leaf2]))
    assert "exit_head" in p1 and "exit_head" not in p2
    assert "final_norm" not in p1["backbone"]
    assert "final_norm" in p2["backbone"]
    assert p2["backbone"]["first"] == [] and p1["backbone"]["rem"] == []
    # tied: the table is the shared unembedding, resident on both
    assert "embed" in p1["backbone"] and "embed" in p2["backbone"]


def test_split_params_untied_embed_stage1_only():
    """Untied models share the 'head' matrix between the two heads; the
    embed table is only read by stage 1's embed_tokens and must not be
    resident on stage 2."""
    from repro.models.config import ArchConfig
    cfg = ArchConfig(name="untied", family="dense", n_layers=4, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32",
                     tie_embeddings=False)
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    assert "head" in params["backbone"]
    p1, p2 = ee.split_params(cfg, spec, params)
    assert "embed" in p1["backbone"] and "head" in p1["backbone"]
    assert "embed" not in p2["backbone"] and "head" in p2["backbone"]
    # and the sliced programs still run: stage 2 on its slice, bitwise
    toks = jnp.asarray(jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0,
                                          cfg.vocab))
    h, _, _, _ = ee.stage1_prefill(params, cfg, spec, toks)
    ref, _ = ee.stage2_prefill(params, cfg, spec, h)
    got, _ = ee.stage2_prefill(p2, cfg, spec, h, presliced_params=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_split_params_stage_fns_bitwise(tiny_cfg, tiny_spec, tiny_params):
    """The placement-aware _stage_fns (split + presliced params) must equal
    the pre-refactor full-tree jitted stage programs bit for bit — the
    invariant the whole disaggregated path rests on."""
    toks = jnp.asarray(jax.random.randint(jax.random.PRNGKey(0), (6, 8), 0,
                                          tiny_cfg.vocab))

    @jax.jit
    def s1_ref(tokens):          # the pre-split builder's stage-1 program
        h, _, logits, _ = ee.stage1_prefill(tiny_params, tiny_cfg,
                                            tiny_spec, tokens)
        return h, logits

    @jax.jit
    def s2_ref(slab):            # the pre-split builder's stage-2 program
        logits, _ = ee.stage2_prefill(tiny_params, tiny_cfg, tiny_spec,
                                      slab)
        return logits

    s1, s2 = SL._stage_fns(tiny_params, tiny_cfg, tiny_spec)
    h, logits = s1(toks)
    h_ref, logits_ref = s1_ref(toks)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h_ref))
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits_ref))
    np.testing.assert_array_equal(np.asarray(s2(h)),
                                  np.asarray(s2_ref(h_ref)))


def test_split_params_decode_bitwise(tiny_cfg, tiny_spec, tiny_params):
    """stage2_decode over the stage-2 param slice (param_base_sb path) must
    match the full-tree call bit for bit."""
    from repro.models import transformer as T
    prompt = jnp.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0,
                                            tiny_cfg.vocab))
    _, caches, _ = T.prefill(tiny_params["backbone"], tiny_cfg, prompt,
                             max_len=8)
    _, c2 = ee.split_caches(tiny_cfg, tiny_spec, caches)
    h = jax.random.normal(jax.random.PRNGKey(2), (3, 1, tiny_cfg.d_model))
    step = jnp.int32(6)
    ref_logits, ref_caches = ee.stage2_decode(tiny_params, tiny_cfg,
                                              tiny_spec, h, c2, step)
    _, p2 = ee.split_params(tiny_cfg, tiny_spec, tiny_params)
    got_logits, got_caches = ee.stage2_decode(p2, tiny_cfg, tiny_spec, h, c2,
                                              step, presliced_params=True)
    np.testing.assert_array_equal(np.asarray(got_logits),
                                  np.asarray(ref_logits))
    for a, b in zip(jax.tree.leaves(got_caches), jax.tree.leaves(ref_caches)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# multi-device placement semantics (in-process; CI disaggregated job)
# ---------------------------------------------------------------------------

def _placement_5_3():
    from repro.core.stage_mesh import StageMeshPlan
    return StagePlacement.from_plan(StageMeshPlan.from_chips(5, 3))


@_multi_device
def test_executor_residency_disjoint():
    pl = _placement_5_3()
    assert pl.disaggregated
    ids1 = {d.id for d in pl.ex1.devices}
    ids2 = {d.id for d in pl.ex2.devices}
    assert not ids1 & ids2 and len(ids1) == 5 and len(ids2) == 3
    x = jnp.ones((6, 4))
    on1 = pl.ex1.place(x)
    assert {d.id for d in on1.sharding.device_set} == ids1
    # cross-executor place IS the stage-boundary device-to-device transfer
    on2 = pl.ex2.place(on1)
    assert {d.id for d in on2.sharding.device_set} == ids2
    np.testing.assert_array_equal(np.asarray(on2), np.asarray(x))


@_multi_device
def test_place_io_shards_when_divisible():
    pl = _placement_5_3()
    batch = jnp.ones((10, 4))        # 10 % dp1(5) == 0 -> sharded
    sharded = pl.ex1.place_io(batch)
    assert not sharded.sharding.is_fully_replicated
    odd = jnp.ones((7, 4))           # 7 % 5 != 0 -> replicated fallback
    repl = pl.ex1.place_io(odd)
    assert repl.sharding.is_fully_replicated


@_multi_device
def test_disagg_server_params_and_ring_resident():
    """Stage-2 params and the ring live on submesh 2; stage-1 params on
    submesh 1."""
    cfg, spec, params, toks = _tiny_setup()
    pl = _placement_5_3()
    sc = SL.ServeConfig(capacity=4, queue_depth=2, c_thr=1.1)  # all hard
    srv = SL.build_server(params, cfg, spec, sc, pl)
    SL.serve_dataset(srv, toks, batch=8)
    ids2 = {d.id for d in pl.ex2.devices}
    assert {d.id
            for d in srv.ring._buf["ids"].sharding.device_set} <= ids2


@_multi_device
def test_disagg_prefill_server_bitwise():
    cfg, spec, params, toks = _tiny_setup()
    sc = SL.ServeConfig(capacity=4, queue_depth=4, c_thr=spec.c_thr)
    r_one = SL.serve_dataset(SL.build_server(params, cfg, spec, sc), toks,
                             batch=8)
    dis = SL.build_server(params, cfg, spec, sc, _placement_5_3())
    r_dis = SL.serve_dataset(dis, toks, batch=8)
    assert set(r_dis) == set(r_one)
    for sid in r_one:
        np.testing.assert_array_equal(r_dis[sid], r_one[sid])
    assert dis.stats.stage1_chips == 5 and dis.stats.stage2_chips == 3


@_multi_device
def test_disagg_decode_server_bitwise():
    cfg, spec, params, toks = _tiny_setup()
    prompt = toks[:6]
    sc = SL.ServeConfig(capacity=3, queue_depth=2, c_thr=spec.c_thr)
    o_one = SL.build_decode_server(params, cfg, spec, sc).generate(prompt, 5)
    o_dis = SL.build_decode_server(params, cfg, spec, sc,
                                   _placement_5_3()).generate(prompt, 5)
    np.testing.assert_array_equal(o_dis["tokens"], o_one["tokens"])
    np.testing.assert_array_equal(o_dis["logits"], o_one["logits"])


def _tiny_setup():
    from repro.models.config import ArchConfig
    cfg = ArchConfig(name="tiny-dense", family="dense", n_layers=4,
                     d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32",
                     tie_embeddings=True)
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=0.3)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (24, 8), 0,
                                         cfg.vocab))
    return cfg, spec, params, toks


# ---------------------------------------------------------------------------
# subprocess: the acceptance bar on every tier-1 run, q in {0.1, 0.3, 0.5}
# (the main test process must keep 1 device — conftest contract)
# ---------------------------------------------------------------------------

def test_disaggregated_parity_subprocess():
    """Disaggregated TwoStageServer AND DecodeServer bitwise-identical to
    the single-device servers at q ∈ {0.1, 0.3, 0.5} under
    --xla_force_host_platform_device_count=8, q-proportional chip splits."""
    code = ("import os\n"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import early_exit as ee
    from repro.core import exit_decision as ed
    from repro.core.stage_mesh import StageMeshPlan
    from repro.models.config import ArchConfig
    from repro.runtime import serve_loop as SL
    from repro.runtime.stage_executor import StagePlacement

    cfg = ArchConfig(name="t", family="dense", n_layers=4, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32",
                     tie_embeddings=True)
    spec0 = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (32, 8), 0,
                                         cfg.vocab))
    _, _, exit_logits, _ = ee.stage1_prefill(params, cfg, spec0,
                                             jnp.asarray(toks))
    conf = ed.softmax_confidence(exit_logits)
    dconf = SL.decode_step0_confidences(params, cfg, spec0, toks[:8],
                                        max_len=8 + 5)
    for q in (0.1, 0.3, 0.5):
        pl = StagePlacement.from_plan(
            StageMeshPlan.proportional(q, jax.device_count()))
        c_thr = float(jnp.quantile(conf, q))
        spec = ee.EarlyExitSpec(exit_layer=2, c_thr=c_thr)
        sc = SL.ServeConfig(capacity=4, queue_depth=2, c_thr=c_thr)
        r1 = SL.serve_dataset(SL.build_server(params, cfg, spec, sc),
                              toks, batch=8)
        r2 = SL.serve_dataset(SL.build_server(params, cfg, spec, sc, pl),
                              toks, batch=8)
        assert set(r1) == set(r2)
        assert all(np.array_equal(r1[i], r2[i]) for i in r1), q
        cd = float(jnp.quantile(dconf, q))
        dspec = ee.EarlyExitSpec(exit_layer=2, c_thr=cd)
        dsc = SL.ServeConfig(capacity=3, queue_depth=2, c_thr=cd)
        o1 = SL.build_decode_server(params, cfg, dspec,
                                    dsc).generate(toks[:8], 5)
        o2 = SL.build_decode_server(params, cfg, dspec, dsc,
                                    pl).generate(toks[:8], 5)
        assert np.array_equal(o1["tokens"], o2["tokens"]), q
        assert np.array_equal(o1["logits"], o2["logits"]), q
        print("q", q, "OK")
    print("PARITY_ALL_OK")
    """))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=_REPO_ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PARITY_ALL_OK" in r.stdout
