"""Hypothesis property tests on the paper's core invariants:
Eq. (2) == Eq. (4), TAP monotonicity + the ⊕ operator (Eq. 1), and the
conditional-buffer / exit-merge round trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, not error, when absent
from hypothesis import given, settings, strategies as st

from repro.core import conditional as cond
from repro.core import exit_decision as ed
from repro.core.tap import (CombinedDesign, DesignPoint, TAPFunction, combine,
                            combine_multistage, robustness_band)

SET = settings(max_examples=60, deadline=None)


# ---------------------------------------------------------------------------
# Eq. (2)  max softmax > C_thr   ==   Eq. (4) division-free (+ max shift)
# ---------------------------------------------------------------------------

@SET
@given(st.integers(2, 40), st.integers(1, 16),
       st.floats(0.05, 0.99), st.integers(0, 2**31 - 1))
def test_eq2_equals_eq4(n_classes, batch, c_thr, seed):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (batch, n_classes), jnp.float32) * 10.0
    # Eq. (2): literal softmax comparison
    sm = jax.nn.softmax(x, axis=-1)
    eq2 = jnp.max(sm, axis=-1) > c_thr
    # Eq. (4) as implemented (division-free, max-shifted)
    eq4 = ed.exit_decision(x, c_thr)
    np.testing.assert_array_equal(np.asarray(eq2), np.asarray(eq4))


@SET
@given(st.integers(2, 30), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_confidence_is_max_softmax(n_classes, batch, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (batch, n_classes)) * 5
    conf = ed.softmax_confidence(x)
    np.testing.assert_allclose(np.asarray(conf),
                               np.asarray(jnp.max(jax.nn.softmax(x, -1), -1)),
                               rtol=1e-5)


@SET
@given(st.floats(0.05, 0.95), st.integers(0, 2**31 - 1))
def test_calibrate_threshold_hits_rate(target_rate, seed):
    conf = jax.random.uniform(jax.random.PRNGKey(seed), (4000,))
    thr = ed.calibrate_threshold(conf, target_rate)
    realized = float((conf > thr).mean())
    assert abs(realized - target_rate) < 0.02


def test_entropy_confidence_bounds():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 10)) * 3
    e = ed.entropy_confidence(x)
    assert float(e.min()) >= 0.0 and float(e.max()) <= 1.0 + 1e-6
    one_hot = jnp.full((1, 10), -100.0).at[0, 3].set(100.0)
    assert float(ed.entropy_confidence(one_hot)[0]) < 1e-3


# ---------------------------------------------------------------------------
# TAP functions + Eq. (1)
# ---------------------------------------------------------------------------

def _points(draw_resources, draw_thr):
    return [DesignPoint(resources=(float(r),), throughput=float(t))
            for r, t in zip(draw_resources, draw_thr)]


tap_strategy = st.lists(
    st.tuples(st.floats(1, 100), st.floats(1, 1000)), min_size=1, max_size=12)


@SET
@given(tap_strategy)
def test_tap_pareto_and_monotone(pts):
    tap = TAPFunction([DesignPoint(resources=(r,), throughput=t)
                       for r, t in pts])
    assert tap.is_monotone()
    # pareto: no kept point dominated by another kept point
    for a in tap.points:
        for b in tap.points:
            if a is b:
                continue
            dominated = (b.throughput >= a.throughput and
                         b.resources[0] <= a.resources[0])
            assert not dominated or b.throughput == a.throughput
    # query never exceeds budget
    for budget in (0.5, 10.0, 200.0):
        got = tap.query((budget,))
        if got is not None:
            assert got.resources[0] <= budget + 1e-9


@SET
@given(tap_strategy, tap_strategy, st.floats(0.05, 1.0))
def test_combine_eq1_invariants(pts1, pts2, p):
    f = TAPFunction([DesignPoint(resources=(r,), throughput=t)
                     for r, t in pts1], "f")
    g = TAPFunction([DesignPoint(resources=(r,), throughput=t)
                     for r, t in pts2], "g")
    budget = (150.0,)
    d = combine(f, g, p, budget)
    if d is None:
        return
    # (1) resources within budget
    assert d.resources[0] <= budget[0] + 1e-9
    # (2) design throughput = min(f(x1), g(x2)/p)
    expect = min(d.stage1.throughput, d.stage2.throughput / p)
    assert abs(d.design_throughput - expect) < 1e-9
    # (3) the argmax is optimal: no other feasible pair beats it
    for a in f.points:
        for b in g.points:
            if a.resources[0] + b.resources[0] <= budget[0] + 1e-9:
                assert min(a.throughput, b.throughput / p) <= \
                    d.design_throughput + 1e-9
    # (4) Fig. 4 robustness ordering: q < p cannot hurt, q > p cannot help
    band = robustness_band(d, [max(p - 0.05, 1e-3), p, min(p + 0.05, 1.0)])
    vals = list(band.values())
    assert vals[0] >= vals[1] - 1e-9 >= vals[2] - 2e-9
    # (5) throughput at q never exceeds the stage-1 rate (hard ceiling)
    for q in (0.01, p, 1.0):
        assert d.throughput_at(q) <= d.stage1.throughput + 1e-9


@SET
@given(tap_strategy, st.floats(0.1, 1.0))
def test_combine_multistage_reduces_to_pairwise(pts, p):
    f = TAPFunction([DesignPoint(resources=(r,), throughput=t)
                     for r, t in pts], "f")
    g = TAPFunction([DesignPoint(resources=(r * 0.7,), throughput=t * 1.1)
                     for r, t in pts], "g")
    budget = (120.0,)
    two = combine(f, g, p, budget)
    multi = combine_multistage([f, g], [1.0, p], budget)
    if two is None:
        assert multi is None
        return
    assert multi is not None
    assert abs(multi["design_throughput"] - two.design_throughput) < 1e-9


def test_combine_prefers_small_stage2_when_p_small():
    """The paper's core claim: as p shrinks, stage 2 needs fewer resources
    for the same combined throughput."""
    mk = lambda s: TAPFunction([DesignPoint(resources=(float(r),),
                                            throughput=float(r) * s)
                                for r in (1, 2, 4, 8, 16, 32, 64)])
    f, g = mk(10.0), mk(10.0)
    d_small = combine(f, g, 0.1, (64.0,))
    d_big = combine(f, g, 0.9, (64.0,))
    assert d_small.design_throughput >= d_big.design_throughput
    assert d_small.stage2.resources[0] < d_big.stage2.resources[0]


# ---------------------------------------------------------------------------
# conditional buffer + exit merge round trip
# ---------------------------------------------------------------------------

@SET
@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_compact_indices_is_stable_partition(batch, seed):
    mask = np.asarray(
        jax.random.bernoulli(jax.random.PRNGKey(seed), 0.4, (batch,)))
    perm, n_hard = cond.compact_indices(jnp.asarray(mask))
    perm = np.asarray(perm)
    assert sorted(perm.tolist()) == list(range(batch))        # permutation
    nh = int(n_hard)
    assert nh == int(mask.sum())
    hard_idx = np.flatnonzero(mask)
    easy_idx = np.flatnonzero(~mask)
    np.testing.assert_array_equal(perm[:nh], hard_idx)        # stable order
    np.testing.assert_array_equal(perm[nh:], easy_idx)


@SET
@given(st.integers(1, 48), st.floats(0.0, 1.0), st.integers(0, 2**31 - 1))
def test_merge_round_trip(batch, p_hard, seed):
    """serve-style: exit decision -> buffer -> merge reconstructs each
    sample's value from the correct stream."""
    k = jax.random.PRNGKey(seed)
    mask_hard = jax.random.bernoulli(k, p_hard, (batch,))
    vals = jnp.arange(batch, dtype=jnp.float32) + 1.0         # payload = id+1
    ids = jnp.arange(batch, dtype=jnp.int32)
    cap = batch                                               # lossless run
    slab, slab_ids, n_hard, overflow = cond.conditional_buffer(
        vals, ids, mask_hard, cap)
    assert int(overflow) == 0
    easy_ids = jnp.where(~mask_hard, ids, -1)
    merged = cond.exit_merge(batch, easy_ids, vals * 10.0, slab_ids,
                             slab * 100.0)
    expect = np.where(np.asarray(mask_hard),
                      (np.arange(batch) + 1.0) * 100.0,
                      (np.arange(batch) + 1.0) * 10.0)
    np.testing.assert_allclose(np.asarray(merged), expect)


@SET
@given(st.integers(2, 32), st.integers(1, 31), st.integers(0, 2**31 - 1))
def test_buffer_overflow_counts(batch, cap, seed):
    cap = min(cap, batch)
    mask = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.7, (batch,))
    vals = jnp.zeros((batch, 3))
    _, slab_ids, n_hard, overflow = cond.conditional_buffer(
        vals, jnp.arange(batch, dtype=jnp.int32), mask, cap)
    assert int(overflow) == max(int(mask.sum()) - cap, 0)
    n_valid = int((np.asarray(slab_ids) >= 0).sum())
    assert n_valid == min(int(mask.sum()), cap)


def test_queue_simulator_matches_eq1_regions():
    """Fig. 4: with stage-2 provisioned for p, running q < p keeps design
    throughput; q > p degrades toward stage2_rate/q."""
    rng = np.random.default_rng(0)
    p = 0.25
    s1_rate, s2_rate = 100.0, 100.0 * p * 1.05    # stage 2 sized for p
    for q, expect_close_to_design in ((0.15, True), (0.25, True),
                                      (0.45, False)):
        seq = (rng.random(4000) < q).astype(int)
        r = cond.simulate_two_stage_queue(
            seq, stage1_rate=s1_rate, stage2_rate=s2_rate, buffer_depth=64)
        if expect_close_to_design:
            assert r["throughput"] > 0.9 * s1_rate
        else:
            assert r["throughput"] < 0.75 * s1_rate
            assert r["throughput"] > 0.9 * s2_rate / q
