"""Continuous-batching decode scheduler: per-sample token-stream
equivalence against the host-loop oracle (the continuous correctness
contract — same greedy tokens per sample id, any interleaving), scheduler
invariants under random traces (hypothesis over toy stage callables),
latency / realized-q statistics, the per-metric tolerance machinery in
benchmarks/compare.py, and the disaggregated equivalence bar (in-process
when the host exposes 8 devices, subprocess on every tier-1 run)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import early_exit as ee
from repro.runtime import serve_loop as SL
from repro.runtime.scheduler import (ContinuousScheduler, LogicalClock,
                                     Request, ServeStats, SyncScheduler,
                                     poisson_arrivals)

_REPO_ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def prompt(tiny_cfg):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(21), (6, 8), 0,
                                         tiny_cfg.vocab))


@pytest.fixture(scope="module")
def fns(tiny_cfg, tiny_params, tiny_spec):
    return SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec)


def _decode_conf(tiny_cfg, tiny_params, tiny_spec, prompt, max_len):
    return np.asarray(SL.decode_step0_confidences(
        tiny_params, tiny_cfg, tiny_spec, prompt, max_len=max_len))


def _expect_streams(oracle_tokens, n_tokens):
    """Per-sample expected streams from a HostLoopDecoder (B, T) output."""
    return {i: [int(x) for x in oracle_tokens[i][:n_tokens[i]]]
            for i in range(len(n_tokens))}


N_TOKS = [7, 3, 5, 1, 7, 2]          # variable lengths incl. a prefill-only


def _run_continuous(fns, sc, prompt, n_tokens, n_slots, max_len,
                    arrivals=None, **kw):
    sched = ContinuousScheduler(fns, sc, n_slots=n_slots, max_len=max_len,
                                clock=LogicalClock(), **kw)
    for i in range(len(n_tokens)):
        t = 0.0 if arrivals is None else float(arrivals[i])
        sched.submit(Request(sample_id=i, prompt=prompt[i],
                             n_tokens=n_tokens[i], arrival_time=t))
    return sched.run(), sched


# ---------------------------------------------------------------------------
# the tentpole contract: per-sample greedy token streams identical to the
# host-loop oracle — all-exit, none-exit, mixed, and the calibrated q grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c_thr", [0.0, 1.1, None])
def test_continuous_token_stream_equivalence(tiny_cfg, tiny_params,
                                             tiny_spec, prompt, fns, c_thr):
    """A pool smaller than the request count (backfill), variable lengths
    (incl. a one-token request), every sample's stream equal to the
    host-loop decode — for all-exit, none-exit, and mixed traffic."""
    max_tok = max(N_TOKS)
    if c_thr is None:
        conf = _decode_conf(tiny_cfg, tiny_params, tiny_spec, prompt,
                            prompt.shape[1] + max_tok)
        c_thr = float(np.median(conf))
    sc = SL.ServeConfig(capacity=3, queue_depth=2, c_thr=c_thr)
    oracle = SL.HostLoopDecoder(fns, sc).generate(prompt, max_tok)
    res, sched = _run_continuous(fns, sc, prompt, N_TOKS, n_slots=4,
                                 max_len=prompt.shape[1] + max_tok)
    assert res == _expect_streams(oracle["tokens"], N_TOKS)
    assert sched.stats.n_samples == len(N_TOKS)
    assert sched.stats.n_finished == len(N_TOKS)


def test_continuous_equivalence_q_grid(tiny_cfg, tiny_params, tiny_spec,
                                       prompt, fns):
    """The acceptance bar: identical per-sample streams at calibrated
    q ∈ {0.1, 0.3, 0.5} (single-device; the disaggregated half runs in the
    subprocess test below and in the 8-device CI job)."""
    max_tok = max(N_TOKS)
    conf = _decode_conf(tiny_cfg, tiny_params, tiny_spec, prompt,
                        prompt.shape[1] + max_tok)
    for q in (0.1, 0.3, 0.5):
        c_thr = float(np.quantile(conf, q))
        sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=c_thr)
        oracle = SL.HostLoopDecoder(fns, sc).generate(prompt, max_tok)
        res, _ = _run_continuous(fns, sc, prompt, N_TOKS, n_slots=3,
                                 max_len=prompt.shape[1] + max_tok)
        assert res == _expect_streams(oracle["tokens"], N_TOKS), q


def test_continuous_backpressure_ring_smaller_than_pool(tiny_cfg,
                                                        tiny_params, prompt,
                                                        fns):
    """All-hard traffic through a ring smaller than the pool: the chunked
    enqueue must stall (full buckets drain first), never deadlock, never
    drop — and streams stay equivalent."""
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=1.1)
    n_toks = [5] * prompt.shape[0]
    assert sc.queue_depth * sc.capacity < prompt.shape[0]
    oracle = SL.HostLoopDecoder(fns, sc).generate(prompt, 5)
    res, sched = _run_continuous(fns, sc, prompt, n_toks,
                                 n_slots=prompt.shape[0],
                                 max_len=prompt.shape[1] + 5)
    assert sched.stats.n_stalls > 0
    assert res == _expect_streams(oracle["tokens"], n_toks)


def test_continuous_eager_drain_off(tiny_cfg, tiny_params, tiny_spec,
                                    prompt, fns):
    """eager_drain_below=0 recovers pure full-bucket dispatch (maximum
    bucket fill) and still drains correctly via the all-parked path."""
    conf = _decode_conf(tiny_cfg, tiny_params, tiny_spec, prompt, 15)
    c_thr = float(np.median(conf))
    sc = SL.ServeConfig(capacity=3, queue_depth=2, c_thr=c_thr)
    oracle = SL.HostLoopDecoder(fns, sc).generate(prompt, 7)
    n_toks = [7] * prompt.shape[0]
    res, _ = _run_continuous(fns, sc, prompt, n_toks, n_slots=4,
                             max_len=15, eager_drain_below=0)
    assert res == _expect_streams(oracle["tokens"], n_toks)


def test_sync_scheduler_matches_oracle(tiny_cfg, tiny_params, prompt, fns):
    """The degenerate sync policy (batch formation over DecodeServer,
    incl. a smaller partial tail batch) yields the same truncated streams,
    records per-request latency, and counts only real traffic."""
    sc = SL.ServeConfig(capacity=3, queue_depth=2, c_thr=0.9)
    oracle = SL.HostLoopDecoder(fns, sc).generate(prompt, max(N_TOKS))
    sched = SyncScheduler(SL.DecodeServer(fns, sc), n_slots=4,
                          clock=LogicalClock())
    for i in range(len(N_TOKS)):
        sched.submit(Request(sample_id=i, prompt=prompt[i],
                             n_tokens=N_TOKS[i]))
    res = sched.run()
    assert res == _expect_streams(oracle["tokens"], N_TOKS)
    assert sched.stats.n_finished == len(N_TOKS)
    assert sched.stats.n_samples == len(N_TOKS)     # padding isn't traffic


def test_continuous_admission_gating(tiny_cfg, tiny_params, prompt, fns):
    """A request whose arrival_time is in the future is not admitted until
    the clock reaches it (the scheduler fast-forwards when idle)."""
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.0)
    n_toks = [3] * 4
    arrivals = [0.0, 0.0, 5.0, 9.0]
    res, sched = _run_continuous(fns, sc, prompt[:4], n_toks, n_slots=4,
                                 max_len=prompt.shape[1] + 3,
                                 arrivals=arrivals)
    assert sorted(res) == [0, 1, 2, 3]
    assert sched.clock.now() >= 9.0                  # fast-forwarded
    assert sched.stats.n_finished == 4
    # the late arrivals can't have finished before they arrived
    assert all(lat >= 0.0 for lat in sched.stats.latencies)


def test_continuous_rejects_overlong_and_duplicate(tiny_cfg, tiny_params,
                                                   prompt, fns):
    """Malformed requests are rejected at submit() — before they can be
    popped into a chunk and damage in-flight state."""
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.0)
    sched = ContinuousScheduler(fns, sc, n_slots=2, max_len=10,
                                clock=LogicalClock())
    with pytest.raises(ValueError, match="exceeds pool max_len"):
        sched.submit(Request(0, prompt[0], n_tokens=99))
    with pytest.raises(ValueError, match="n_tokens must be >= 1"):
        sched.submit(Request(1, prompt[0], n_tokens=0))
    sched = ContinuousScheduler(fns, sc, n_slots=2, max_len=12,
                                clock=LogicalClock())
    sched.submit(Request(0, prompt[0], n_tokens=2))
    with pytest.raises(ValueError, match="duplicate sample id"):
        sched.submit(Request(0, prompt[1], n_tokens=2))
    # an already-ADMITTED sid is also rejected on a later submit
    sched.submit(Request(1, prompt[1], n_tokens=2))
    sched.run()
    with pytest.raises(ValueError, match="duplicate sample id"):
        sched.submit(Request(1, prompt[2], n_tokens=2))


# ---------------------------------------------------------------------------
# ServeStats: per-request latency + per-dispatch realized-q series
# ---------------------------------------------------------------------------

def test_serve_stats_latency_percentiles():
    st = ServeStats()
    for i, dt in enumerate([0.1, 0.2, 0.3, 0.4, 1.0]):
        st.record_submit(i, 10.0)
        st.record_finish(i, 10.0 + dt)
    assert st.n_finished == 5
    np.testing.assert_allclose(st.latency_p50, 0.3)
    np.testing.assert_allclose(st.latency_p90, 0.76)
    np.testing.assert_allclose(st.latency_p99, 0.976)
    d = st.as_dict()
    for k in ("latency_p50", "latency_p90", "latency_p99", "n_finished"):
        assert k in d
    # unmatched finish is ignored, empty percentiles are 0.0
    st2 = ServeStats()
    st2.record_finish(7, 1.0)
    assert st2.n_finished == 0 and st2.latency_p99 == 0.0


def test_serve_stats_realized_q_series():
    st = ServeStats()
    st.record_decisions(10, 3)
    st.record_decisions(10, 7)
    st.record_decisions(0, 0)
    assert list(st.realized_q_series) == [0.3, 0.7, 0.0]
    assert st.as_dict()["realized_q_series"] == [0.3, 0.7, 0.0]


def test_scheduler_stats_latency_recorded(tiny_cfg, tiny_params, prompt,
                                          fns):
    """The continuous scheduler stamps submit->finish per request and the
    q series grows one entry per pool tick."""
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=1.1)
    n_toks = [4] * 4
    res, sched = _run_continuous(fns, sc, prompt[:4], n_toks, n_slots=4,
                                 max_len=prompt.shape[1] + 4)
    st = sched.stats
    assert st.n_finished == 4
    assert len(st.realized_q_series) == st.n_stage1_batches
    assert all(v == 1.0 for v in st.realized_q_series)   # all-hard
    assert not st.submit_times                           # all matched
    del res


# ---------------------------------------------------------------------------
# scheduler invariants under random traces: hypothesis over TOY stage fns
# (the policy machinery — slots, ring, buckets, backfill — with an
# analytically known token stream, so no model compute in the loop)
# ---------------------------------------------------------------------------

_TOY_VOCAB = 32
_TOY_S = 4


def _toy_tok(sid, t):
    return (3 + sid * 31 + t * 7) % _TOY_VOCAB


def _toy_hard(sid, t, q_pct):
    return ((sid * 131 + t * 17) % 100) < q_pct


def toy_decode_fns(q_pct: int):
    """DecodeFns whose exit decisions and greedy tokens are pure functions
    of (sample id, decode index): hard iff hash(sid, t) < q_pct; token =
    _toy_tok(sid, t). The sample id rides the stage-1 cache / stage-2 row
    payload, so the scheduler's plumbing is exactly what's under test."""

    def _logits(sid, t):
        tok = _toy_tok(sid, t)
        hard = _toy_hard(sid, t, q_pct)
        oh = jax.nn.one_hot(tok, _TOY_VOCAB)
        return jnp.where(hard[:, None], oh * 1e-3, oh * 50.0)

    def prefill(prompts, max_len):
        sid = prompts[:, 0].astype(jnp.int32)
        caches = {"first": [sid[:, None]], "blocks": (), "rem": []}
        return _logits(sid, jnp.zeros_like(sid)), caches

    def split(caches):
        return caches, {"sid": caches["first"][0]}

    def s1_raw(tok, c1, pos):
        sid = c1["first"][0][:, 0]
        t = pos - _TOY_S + 1                 # decode index being produced
        h = jnp.stack([sid, pos], 1).astype(jnp.float32)
        return h, c1, _logits(sid, t)

    def s2(h_rows, cache_rows, step):
        sid = cache_rows["sid"][:, 0]
        return _logits(sid, step - _TOY_S + 1), cache_rows

    return SL.DecodeFns(prefill, split, jax.jit(s1_raw), s2, s1_raw)


def _toy_requests(n_tokens_list):
    return [Request(sample_id=i,
                    prompt=np.full((_TOY_S,), i, np.int32),
                    n_tokens=n)
            for i, n in enumerate(n_tokens_list)]


def _toy_expected(n_tokens_list):
    return {i: [_toy_tok(i, t) for t in range(n)]
            for i, n in enumerate(n_tokens_list)}


def test_toy_fns_mixed_trace_smoke():
    """Deterministic smoke of the toy harness itself (hypothesis-free, so
    the property tests' failures can be attributed to the scheduler)."""
    fns = toy_decode_fns(q_pct=40)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    n_toks = [5, 1, 3, 6, 2]
    sched = ContinuousScheduler(fns, sc, n_slots=3, max_len=_TOY_S + 6,
                                clock=LogicalClock())
    for r in _toy_requests(n_toks):
        sched.submit(r)
    assert sched.run() == _toy_expected(n_toks)


try:
    from hypothesis import given, settings, strategies as st_h
    _HAVE_HYP = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYP = False


if _HAVE_HYP:
    @settings(max_examples=25, deadline=None)
    @given(
        n_tokens_list=st_h.lists(st_h.integers(1, 6), min_size=1,
                                 max_size=10),
        n_slots=st_h.integers(1, 5),
        capacity=st_h.integers(1, 4),
        queue_depth=st_h.integers(1, 3),
        q_pct=st_h.integers(0, 100),
        eager=st_h.integers(0, 3),
        arrival_gaps=st_h.lists(st_h.floats(0.0, 2.0), min_size=10,
                                max_size=10),
    )
    def test_scheduler_invariants_random_traces(n_tokens_list, n_slots,
                                                capacity, queue_depth,
                                                q_pct, eager, arrival_gaps):
        """Under random q / arrival traces and pool/ring geometries: no
        sample id dropped or duplicated, per-sample token order preserved
        (streams equal the analytic oracle exactly), slot occupancy never
        exceeds the pool, and the pool fully drains."""
        fns = toy_decode_fns(q_pct=q_pct)
        sc = SL.ServeConfig(capacity=capacity, queue_depth=queue_depth,
                            c_thr=0.5, max_pending=2)
        sched = ContinuousScheduler(fns, sc, n_slots=n_slots,
                                    max_len=_TOY_S + 6,
                                    clock=LogicalClock(),
                                    eager_drain_below=eager)
        t = 0.0
        for r, gap in zip(_toy_requests(n_tokens_list), arrival_gaps):
            t += gap
            r.arrival_time = t
            sched.submit(r)
        res = sched.run()
        expect = _toy_expected(n_tokens_list)
        assert set(res) == set(expect)               # no drop, no phantom
        assert res == expect                         # order + no dup
        assert sched.peak_busy <= n_slots
        assert len(sched._free) == n_slots           # fully drained
        assert sched.stats.n_samples == len(n_tokens_list)
        assert sched.stats.n_finished == len(n_tokens_list)
        total_decode = sum(n - 1 for n in n_tokens_list)
        assert sched.stats.n_decisions == total_decode
        assert sched.stats.n_exited + sched.stats.n_stage2 == total_decode


# ---------------------------------------------------------------------------
# benchmarks/compare.py: per-metric tolerance overrides
# ---------------------------------------------------------------------------

def _gate(value, spec, got):
    from benchmarks.compare import compare
    current = {"schema_version": 1, "benches": {"b": {"m": got}}}
    baseline = {"schema_version": 1,
                "metrics": {"b.m": {"value": value, **spec}}}
    return compare(current, baseline)


def test_compare_relative_tolerance_default():
    assert _gate(2.0, {}, 1.6)["ok"]                 # -20% within 25%
    assert not _gate(2.0, {}, 1.4)["ok"]             # -30% beyond 25%


def test_compare_abs_tolerance_composition():
    """Band = max(rel * |baseline|, abs): absolute slack keeps near-zero
    baselines from flapping; relative slack rules large ones."""
    spec = {"tolerance": 0.1, "abs_tolerance": 0.5}
    assert _gate(0.2, spec, -0.25)["ok"]             # |drop| 0.45 < abs 0.5
    assert not _gate(0.2, spec, -0.35)["ok"]
    assert _gate(100.0, spec, 91.0)["ok"]            # rel 10% = 10 > abs
    assert not _gate(100.0, spec, 89.0)["ok"]


def test_compare_hard_min_bound():
    """`min` is a contract floor enforced regardless of tolerance — the
    serve_continuous >=1.3x goodput gate."""
    spec = {"tolerance": 0.25, "min": 1.3}
    assert _gate(1.45, spec, 1.31)["ok"]
    r = _gate(1.45, spec, 1.25)                      # tolerance would allow
    assert not r["ok"]
    assert r["metrics"]["b.m"]["bound_low"] == 1.3


def test_compare_hard_max_bound_lower_is_better():
    spec = {"direction": "lower", "tolerance": 1.0, "max": 2.0}
    assert _gate(1.0, spec, 1.9)["ok"]
    assert not _gate(1.0, spec, 2.1)["ok"]           # cap wins over rel 2.0


def test_compare_bounds_clamp_both_directions():
    """A `max` sanity cap on a higher-is-better metric (and a `min` on a
    lower-is-better one) is honored too — 'regardless of tolerances' means
    both directions, e.g. catching an absurd ratio from a clock bug."""
    spec = {"tolerance": 0.25, "max": 5.0}
    assert _gate(1.45, spec, 2.0)["ok"]
    assert not _gate(1.45, spec, 50.0)["ok"]
    spec = {"direction": "lower", "tolerance": 1.0, "min": 0.1}
    assert _gate(1.0, spec, 0.5)["ok"]
    assert not _gate(1.0, spec, 0.01)["ok"]


def test_compare_nan_fails():
    assert not _gate(1.45, {"min": 1.3}, float("nan"))["ok"]


# ---------------------------------------------------------------------------
# disaggregated equivalence: in-process on an 8-device host (CI job), and a
# subprocess bar on every tier-1 run — single-device AND disaggregated
# continuous streams vs the host-loop oracle at q ∈ {0.1, 0.3, 0.5}
# ---------------------------------------------------------------------------

@pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
def test_continuous_disaggregated_equivalence_8dev(tiny_cfg, tiny_params,
                                                   tiny_spec, prompt):
    from repro.core.stage_mesh import StageMeshPlan
    from repro.runtime.stage_executor import StagePlacement
    conf = _decode_conf(tiny_cfg, tiny_params, tiny_spec, prompt, 13)
    c_thr = float(np.median(conf))
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=c_thr)
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=c_thr)
    oracle = SL.build_host_decoder(tiny_params, tiny_cfg, spec,
                                   sc).generate(prompt, 5)
    pl = StagePlacement.from_plan(
        StageMeshPlan.proportional(0.5, jax.device_count()))
    sched = SL.build_continuous_scheduler(tiny_params, tiny_cfg, spec, sc,
                                          n_slots=4, max_len=13,
                                          placement=pl,
                                          clock=LogicalClock())
    n_toks = [5] * prompt.shape[0]
    for r in [Request(i, prompt[i], 5) for i in range(prompt.shape[0])]:
        sched.submit(r)
    assert sched.run() == _expect_streams(oracle["tokens"], n_toks)
    assert sched.stats.stage1_chips + sched.stats.stage2_chips == 8


def test_continuous_equivalence_subprocess():
    """The acceptance bar on every tier-1 run: continuous streams equal the
    host-loop oracle at q ∈ {0.1, 0.3, 0.5}, single-device AND
    stage-disaggregated, under --xla_force_host_platform_device_count=8."""
    code = ("import os\n"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import early_exit as ee
    from repro.core.stage_mesh import StageMeshPlan
    from repro.models.config import ArchConfig
    from repro.runtime import serve_loop as SL
    from repro.runtime.scheduler import LogicalClock, Request
    from repro.runtime.stage_executor import StagePlacement

    cfg = ArchConfig(name="t", family="dense", n_layers=4, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32",
                     tie_embeddings=True)
    spec0 = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(21), (6, 8),
                                           0, cfg.vocab))
    n_toks = [5, 3, 5, 1, 4, 2]
    conf = SL.decode_step0_confidences(params, cfg, spec0, prompt,
                                       max_len=13)
    def run_sched(spec, sc, placement):
        s = SL.build_continuous_scheduler(params, cfg, spec, sc, n_slots=3,
                                          max_len=13, placement=placement,
                                          clock=LogicalClock())
        for i in range(6):
            s.submit(Request(i, prompt[i], n_toks[i]))
        return s.run()
    for q in (0.1, 0.3, 0.5):
        c_thr = float(jnp.quantile(conf, q))
        spec = ee.EarlyExitSpec(exit_layer=2, c_thr=c_thr)
        sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=c_thr)
        oracle = SL.build_host_decoder(params, cfg, spec,
                                       sc).generate(prompt, 5)
        want = {i: [int(x) for x in oracle["tokens"][i][:n_toks[i]]]
                for i in range(6)}
        assert run_sched(spec, sc, None) == want, ("single", q)
        pl = StagePlacement.from_plan(
            StageMeshPlan.proportional(q, jax.device_count()))
        assert run_sched(spec, sc, pl) == want, ("disagg", q)
        print("q", q, "OK")
    print("EQUIV_ALL_OK")
    """))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=_REPO_ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EQUIV_ALL_OK" in r.stdout
