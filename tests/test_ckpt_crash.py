"""Checkpoint crash safety: the commit-marker protocol under simulated
crashes (via the ckpt fault points), keep-retention GC, and rejection of
partial/uncommitted checkpoints on restore."""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.runtime import faults


def _tree(step=0):
    return {"w": jnp.arange(6.0).reshape(2, 3) + step,
            "b": {"x": jnp.ones(4) * step}}


def test_crash_between_rename_and_commit(tmp_path):
    """A crash after the rename but before the marker leaves a fully
    written yet UNCOMMITTED directory: restore refuses it, latest_step
    ignores it, and a re-save recovers cleanly."""
    d = str(tmp_path)
    with faults.installed(faults.FaultPlan.parse("ckpt:precommit@1")):
        with pytest.raises(faults.InjectedFault):
            ckpt.save(d, 3, _tree())
        # the directory exists with every leaf on disk — but no marker
        assert os.path.isdir(os.path.join(d, "step_3"))
        assert os.path.exists(os.path.join(d, "step_3", "manifest.json"))
        assert not os.path.exists(os.path.join(d, "step_3", "COMMITTED"))
        with pytest.raises(FileNotFoundError, match="no committed"):
            ckpt.restore(d, 3, _tree())
        assert ckpt.latest_step(d) is None
        # retry (the fault was consumed): commit lands, restore round-trips
        ckpt.save(d, 3, _tree())
    assert ckpt.latest_step(d) == 3
    out = ckpt.restore(d, 3, _tree())
    assert np.array_equal(out["w"], np.asarray(_tree()["w"]))


def test_crash_mid_leaf_write_leaves_only_tmp(tmp_path):
    """A writer dying mid-leaf leaves only the .tmp staging dir — nothing
    restorable, and gc_old sweeps the debris."""
    d = str(tmp_path)
    with faults.installed(faults.FaultPlan.parse("ckpt:leaf@2")):
        with pytest.raises(faults.InjectedFault):
            ckpt.save(d, 5, _tree())
    assert os.path.isdir(os.path.join(d, "step_5.tmp"))
    assert not os.path.isdir(os.path.join(d, "step_5"))
    assert ckpt.latest_step(d) is None
    ckpt.gc_old(d, keep=3)
    assert not os.path.isdir(os.path.join(d, "step_5.tmp"))


def test_keep_retention_gc(tmp_path):
    d = str(tmp_path)
    with faults.installed(None):
        for s in range(5):
            ckpt.save(d, s, _tree(s))
    ckpt.gc_old(d, keep=2)
    kept = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                  if not n.endswith(".tmp"))
    assert kept == [3, 4]
    assert ckpt.latest_step(d) == 4
    out = ckpt.restore(d, 3, _tree())
    assert np.array_equal(out["w"], np.asarray(_tree(3)["w"]))


def test_restore_from_partial_rejected(tmp_path):
    """A committed checkpoint with a leaf deleted out from under it (torn
    storage) fails loudly on the missing file, never silently zero-fills."""
    d = str(tmp_path)
    with faults.installed(None):
        ckpt.save(d, 1, _tree())
    victim = next(f for f in os.listdir(os.path.join(d, "step_1"))
                  if f.endswith(".npy"))
    os.remove(os.path.join(d, "step_1", victim))
    with pytest.raises(FileNotFoundError):
        ckpt.restore(d, 1, _tree())


def test_async_checkpointer_surfaces_crash(tmp_path):
    """A fault on the background writer thread resurfaces on wait() —
    a crashed async save is never silent."""
    d = str(tmp_path)
    cp = ckpt.AsyncCheckpointer(d, keep=2)
    with faults.installed(faults.FaultPlan.parse("ckpt:precommit@1")):
        cp.save_async(7, _tree())
        with pytest.raises(faults.InjectedFault):
            cp.wait()
    assert ckpt.latest_step(d) is None
