"""Elastic re-meshing (the tests runtime/elastic.py's docstring promises):
replan degradation monotonicity over shrinking chip budgets, the
infeasible-budget raise, the drift-side ``replan_rate`` recovery, and the
``degrade_mesh`` survivor carve."""
import numpy as np
import pytest

from repro.core.stage_mesh import StageMeshPlan
from repro.core.tap import DesignPoint, TAPFunction
from repro.runtime.elastic import (ElasticPlan, degrade_mesh, replan,
                                   replan_rate)


def _tap(scale: float, max_chips: int = 16) -> TAPFunction:
    """Linear-throughput TAP over (chips, hbm_gb) budgets — monotone by
    construction, one point per chip count."""
    return TAPFunction([
        DesignPoint(resources=(float(c), c * 8.0), throughput=scale * c)
        for c in range(1, max_chips + 1)])


def test_replan_degradation_monotone():
    """Shrinking the chip budget never increases the re-planned
    throughput, and the degradation ratio stays in (0, 1]."""
    t1, t2 = _tap(100.0), _tap(60.0)
    prev = None
    for after in (16, 12, 8, 4, 2):
        ep = replan(t1, t2, p=0.25, chips_before=16, chips_after=after)
        assert isinstance(ep, ElasticPlan)
        assert 0.0 < ep.degradation <= 1.0 + 1e-9
        if prev is not None:
            assert ep.throughput_after <= prev + 1e-9
        prev = ep.throughput_after
    full = replan(t1, t2, p=0.25, chips_before=16, chips_after=16)
    assert full.degradation == pytest.approx(1.0)


def test_replan_infeasible_budget_raises():
    """A budget below every design point's footprint must fail loudly, not
    yield a silent None plan."""
    t1, t2 = _tap(100.0), _tap(60.0)
    with pytest.raises(RuntimeError, match="no feasible design"):
        replan(t1, t2, p=0.25, chips_before=16, chips_after=1)
    # chips_after=1 is infeasible because BOTH stages need >= 1 chip each


def test_replan_rate_recovers_throughput_at_observed_q():
    """The drift re-plan: at q > p the p-provisioned design under-serves
    stage 2; re-combining at q must do at least as well at q (degradation
    ratio >= 1 reads as recovered throughput), and re-planning at q = p is
    a no-op."""
    t1, t2 = _tap(100.0), _tap(60.0)
    ep = replan_rate(t1, t2, p=0.1, q=0.6, chips=12)
    assert ep.chips_before == ep.chips_after == 12
    assert ep.throughput_after >= ep.throughput_before - 1e-9
    same = replan_rate(t1, t2, p=0.25, q=0.25, chips=12)
    assert same.throughput_after == pytest.approx(same.throughput_before)
    # the q-matched design is the Eq. (1) argmax at q: its design
    # throughput evaluated at q equals its runtime throughput there
    assert ep.design.throughput_at(0.6) == pytest.approx(
        ep.throughput_after)


def test_replan_rate_infeasible_raises():
    t1, t2 = _tap(100.0), _tap(60.0)
    with pytest.raises(RuntimeError, match="no feasible design"):
        replan_rate(t1, t2, p=0.25, q=0.9, chips=1)


def test_degrade_mesh_survivor_carve():
    """Failed device indices drop; the surviving carve is order-preserving,
    disjoint between stages, exactly plan-sized, and contains no failed
    device."""
    devices = [f"dev{i}" for i in range(10)]
    plan = StageMeshPlan.from_chips(4, 3)
    m1, m2 = degrade_mesh(devices, failed=[1, 5, 8], plan=plan)
    d1 = [d for d in np.asarray(m1.devices).flat]
    d2 = [d for d in np.asarray(m2.devices).flat]
    assert d1 == ["dev0", "dev2", "dev3", "dev4"]
    assert d2 == ["dev6", "dev7", "dev9"]
    assert not (set(d1) & set(d2))
    for failed in ("dev1", "dev5", "dev8"):
        assert failed not in d1 + d2


def test_degrade_mesh_insufficient_survivors_raises():
    devices = [f"dev{i}" for i in range(6)]
    plan = StageMeshPlan.from_chips(4, 2)
    with pytest.raises(ValueError, match="available"):
        degrade_mesh(devices, failed=[0, 1], plan=plan)
