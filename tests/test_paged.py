"""Paged KV cache: the block-table decode path end to end.

Layers under test, bottom up: the ``paged_attention`` kernel family
(gather + tail-page append, interpret kernel vs jnp ref, bitwise), the
model decode paths (``attention_decode``/``mla_decode`` paged vs dense —
bitwise, because both route the gathered cache through ONE masked decode
core), the step-synchronous ``DecodeServer`` (paged tokens AND logits
bitwise-equal to dense and to the host oracle), the continuous scheduler
(paged streams == dense streams == host oracle across the calibrated q
grid, incl. ring wraparound/overflow and a page-constrained pool that
exercises admission backpressure), the ``PageAllocator`` invariants
(deterministic sweep always; hypothesis when available), live migration
(paged pool re-placed, rollback restores allocator state exactly), and an
8-device disaggregated subprocess bar.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import early_exit as ee
from repro.kernels import dispatch
from repro.kernels.paged_attention import (paged_gather_append_pallas,
                                           paged_gather_append_ref)
from repro.models import attention as A
from repro.models import mla as M
from repro.models.config import ArchConfig, MLAConfig
from repro.runtime import faults
from repro.runtime import serve_loop as SL
from repro.runtime.migration import MigrationPlan
from repro.runtime.scheduler import (ContinuousScheduler, LogicalClock,
                                     PageAllocator, Request, ServeConfig,
                                     _alloc_row, _free_row)

_REPO_ROOT = str(Path(__file__).resolve().parent.parent)


# ---------------------------------------------------------------------------
# kernel family: interpret kernel vs jnp ref, bitwise
# ---------------------------------------------------------------------------

def _rand_case(key, B, M_pages, page, n_pages, fa, fb):
    ka, kb, kc, kd, ke = jax.random.split(key, 5)
    a_pool = jax.random.normal(ka, (n_pages, page) + fa, jnp.float32)
    b_pool = jax.random.normal(kb, (n_pages, page) + fb, jnp.float32)
    # page 0 is NULL: all zeros by contract
    a_pool = a_pool.at[0].set(0.0)
    b_pool = b_pool.at[0].set(0.0)
    a_new = jax.random.normal(kc, (B,) + fa, jnp.float32)
    b_new = jax.random.normal(kd, (B,) + fb, jnp.float32)
    # each row owns a disjoint page run, null-padded to a random prefix
    perm = 1 + jax.random.permutation(ke, n_pages - 1)[:B * M_pages]
    bt = perm.reshape(B, M_pages).astype(jnp.int32)
    owned = jax.random.randint(ke, (B,), 1, M_pages + 1)
    bt = jnp.where(jnp.arange(M_pages)[None, :] < owned[:, None], bt, 0)
    pos = jax.random.randint(kc, (B,), 0, owned * page).astype(jnp.int32)
    return a_pool, b_pool, a_new, b_new, bt, pos


@pytest.mark.parametrize("B,M_pages,page,fa,fb", [
    (4, 3, 4, (16,), (16,)),         # flattened GQA-shaped K/V (KH*hd)
    (2, 2, 8, (16,), (4,)),          # MLA-shaped (latent, rope)
    (6, 4, 2, (4,), (4,)),
])
def test_kernel_matches_ref_bitwise(B, M_pages, page, fa, fb):
    n_pages = 1 + B * M_pages + 3                # +3 unowned pages
    args = _rand_case(jax.random.PRNGKey(B * 7 + page), B, M_pages, page,
                      n_pages, fa, fb)
    ref = paged_gather_append_ref(*args)
    got = paged_gather_append_pallas(*args, interpret=True)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g))
    # the NULL page is never written
    assert not np.asarray(got[2][0]).any()
    assert not np.asarray(got[3][0]).any()


def test_sentinel_pos_drops_append():
    """Rows at pos >= M*page (parked/flush sentinels) must gather without
    appending — the pools come back byte-identical."""
    B, M_pages, page, n_pages = 3, 2, 4, 1 + 6
    a_pool, b_pool, a_new, b_new, bt, _ = _rand_case(
        jax.random.PRNGKey(0), B, M_pages, page, n_pages, (8,), (8,))
    pos = jnp.full((B,), M_pages * page, jnp.int32)
    ga, gb, ap, bp = paged_gather_append_ref(a_pool, b_pool, a_new, b_new,
                                             bt, pos)
    np.testing.assert_array_equal(np.asarray(ap), np.asarray(a_pool))
    np.testing.assert_array_equal(np.asarray(bp), np.asarray(b_pool))
    got = paged_gather_append_pallas(a_pool, b_pool, a_new, b_new, bt, pos,
                                     interpret=True)
    for r, g in zip((ga, gb, ap, bp), got):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g))


def test_dispatch_op_routes_backends():
    """The dispatch layer flattens multi-axis feature dims for the kernel
    and restores them — every backend bitwise-identical on GQA shapes."""
    B, M_pages, page, n_pages = 2, 2, 4, 1 + 4
    args = _rand_case(jax.random.PRNGKey(3), B, M_pages, page, n_pages,
                      (2, 4), (2, 4))
    a = dispatch.paged_gather_append_op(*args, donate=False)
    b = dispatch.paged_gather_append_op(*args, backend="interpret",
                                        donate=False)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# model decode: paged vs dense, bitwise (one masked core, same cache bytes)
# ---------------------------------------------------------------------------

def _decode_parity(init_dense, init_paged, decode, params, cfg, B, d,
                   max_len, page, n_steps, S0):
    key = jax.random.PRNGKey(9)
    dense = init_dense(cfg, B, max_len)
    paged = init_paged(cfg, B, max_len, page, 1 + B * (max_len // page))
    Mp = max_len // page
    bt = 1 + jnp.arange(B * Mp, dtype=jnp.int32).reshape(B, Mp)
    paged = dict(paged, bt=bt)
    pos = jnp.full((B,), S0, jnp.int32)
    for t in range(n_steps):
        x = jax.random.normal(jax.random.fold_in(key, t), (B, 1, d),
                              jnp.float32)
        out_d, dense = decode(params, cfg, x, dense, pos)
        out_p, paged = decode(params, cfg, x, paged, pos)
        np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_p))
        pos = pos + 1


def test_attention_decode_paged_bitwise():
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32")
    params = A.init_attention(jax.random.PRNGKey(0), cfg)
    _decode_parity(A.init_kv_cache, A.init_paged_kv_cache,
                   A.attention_decode, params, cfg, B=3, d=32, max_len=16,
                   page=4, n_steps=10, S0=2)


def test_mla_decode_paged_bitwise():
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=4, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32",
                     mla=MLAConfig(kv_lora_rank=8, qk_nope_head_dim=4,
                                   qk_rope_head_dim=4, v_head_dim=4))
    params = M.init_mla(jax.random.PRNGKey(0), cfg)
    _decode_parity(M.init_mla_cache, M.init_paged_mla_cache, M.mla_decode,
                   params, cfg, B=3, d=32, max_len=16, page=4, n_steps=10,
                   S0=2)


# ---------------------------------------------------------------------------
# step-synchronous server: paged generate bitwise-equal to dense + oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prompt(tiny_cfg):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(77), (6, 6), 0,
                                         tiny_cfg.vocab))


def test_sync_server_paged_bitwise(tiny_cfg, tiny_params, tiny_spec,
                                   prompt):
    S, n_tok, page = prompt.shape[1], 10, 4
    assert (S + n_tok) % page == 0
    sc = ServeConfig(capacity=3, queue_depth=2, c_thr=0.7)
    dense_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec)
    paged_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec,
                                    page_size=page)
    out_d = SL.DecodeServer(dense_fns, sc).generate(prompt, n_tok)
    srv_p = SL.DecodeServer(paged_fns, sc)
    out_p = srv_p.generate(prompt, n_tok)
    np.testing.assert_array_equal(out_d["tokens"], out_p["tokens"])
    np.testing.assert_array_equal(out_d["logits"], out_p["logits"])
    oracle = SL.HostLoopDecoder(dense_fns, sc).generate(prompt, n_tok)
    np.testing.assert_array_equal(oracle["tokens"], out_p["tokens"])
    # v3 gauges: the sync paged pool is exactly batch-sized
    st = srv_p.stats
    Mp = (S + n_tok) // page
    assert st.cache_pages_total == st.cache_pages_in_use \
        == prompt.shape[0] * Mp
    assert st.cache_hbm_bytes > 0


def test_sync_server_paged_needs_page_multiple(tiny_cfg, tiny_params,
                                               tiny_spec, prompt):
    fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec, page_size=4)
    with pytest.raises(ValueError, match="divisible"):
        SL.DecodeServer(fns, ServeConfig(capacity=2)).generate(prompt, 7)


# ---------------------------------------------------------------------------
# continuous scheduler: paged streams == dense streams == host oracle
# ---------------------------------------------------------------------------

N_TOKS = [7, 3, 5, 1, 7, 2]


def _run_sched(fns, sc, prompt, n_toks, *, n_slots, max_len, **kw):
    s = ContinuousScheduler(fns, sc, n_slots=n_slots, max_len=max_len,
                            clock=LogicalClock(), **kw)
    for i, n in enumerate(n_toks):
        s.submit(Request(sample_id=i, prompt=prompt[i], n_tokens=n))
    return s.drain(), s


def _expect(oracle_tokens, n_toks):
    return {i: [int(x) for x in oracle_tokens[i][:n]]
            for i, n in enumerate(n_toks)}


def test_continuous_paged_q_grid(tiny_cfg, tiny_params, tiny_spec, prompt):
    """The acceptance bar, single-device: paged continuous streams equal
    the dense continuous streams AND the host-loop oracle at calibrated
    q ∈ {0.1, 0.3, 0.5}."""
    max_len = prompt.shape[1] + max(N_TOKS) + 3   # 16: a page multiple
    conf = np.asarray(SL.decode_step0_confidences(
        tiny_params, tiny_cfg, tiny_spec, prompt, max_len=max_len))
    dense_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec)
    paged_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec,
                                    page_size=4)
    for q in (0.1, 0.3, 0.5):
        c_thr = float(np.quantile(conf, q))
        sc = ServeConfig(capacity=2, queue_depth=2, c_thr=c_thr)
        oracle = SL.HostLoopDecoder(dense_fns, sc).generate(prompt,
                                                            max(N_TOKS))
        want = _expect(oracle["tokens"], N_TOKS)
        res_d, _ = _run_sched(dense_fns, sc, prompt, N_TOKS, n_slots=3,
                              max_len=max_len)
        res_p, sp = _run_sched(paged_fns, sc, prompt, N_TOKS, n_slots=3,
                               max_len=max_len)
        assert res_d == want and res_p == want, q
        # drained pool: every page came home
        assert sp._alloc.n_free == sp.n_pages
        assert sp.stats.cache_pages_total == sp.n_pages


def test_continuous_paged_ring_overflow(tiny_cfg, tiny_params, tiny_spec,
                                        prompt):
    """All-hard traffic through a ring smaller than the pool: wraparound +
    overflow spill on the paged payload — stalls happen, streams stay
    exact, pages still all come home."""
    sc = ServeConfig(capacity=2, queue_depth=2, c_thr=1.1)
    n_toks = [5] * prompt.shape[0]
    paged_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec,
                                    page_size=4)
    dense_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec)
    oracle = SL.HostLoopDecoder(dense_fns, sc).generate(prompt, 5)
    res, sched = _run_sched(paged_fns, sc, prompt, n_toks,
                            n_slots=prompt.shape[0], max_len=12)
    assert sched.stats.n_stalls > 0
    assert res == _expect(oracle["tokens"], n_toks)
    assert sched._alloc.n_free == sched.n_pages


def test_continuous_paged_tight_pool_backpressure(tiny_cfg, tiny_params,
                                                  tiny_spec, prompt):
    """A pool holding FEWER pages than dense equivalence: admission
    backpressures on the free list (head blocks, nothing drops) and the
    streams still match dense."""
    sc = ServeConfig(capacity=2, queue_depth=2, c_thr=0.7)
    dense_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec)
    paged_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec,
                                    page_size=4)
    res_d, _ = _run_sched(dense_fns, sc, prompt, N_TOKS, n_slots=4,
                          max_len=16)
    # each request needs at most ceil((6+7-1)/4)=3 pages; 7 pages < 4*4
    res_p, sp = _run_sched(paged_fns, sc, prompt, N_TOKS, n_slots=4,
                           max_len=16, n_pages=7)
    assert res_d == res_p
    assert sp._alloc.n_free == 7
    assert sp.stats.n_samples == len(N_TOKS)


def test_continuous_paged_rejects_oversized_request(tiny_cfg, tiny_params,
                                                    tiny_spec, prompt):
    paged_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec,
                                    page_size=4)
    sc = ServeConfig(capacity=2, queue_depth=2, c_thr=0.7)
    s = ContinuousScheduler(paged_fns, sc, n_slots=2, max_len=16,
                            clock=LogicalClock(), n_pages=2)
    s.submit(Request(sample_id=0, prompt=prompt[0], n_tokens=8))
    with pytest.raises(ValueError, match="never be admitted"):
        s.drain()


def test_paged_ring_ships_indices_not_rows(tiny_cfg, tiny_params,
                                           tiny_spec, prompt):
    """The perf story the ring gauge tells: the paged payload hops page
    INDICES, so ring_bytes_moved collapses vs dense at identical traffic."""
    sc = ServeConfig(capacity=2, queue_depth=2, c_thr=1.1)   # all-hard
    n_toks = [5] * prompt.shape[0]
    dense_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec)
    paged_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec,
                                    page_size=4)
    _, sd = _run_sched(dense_fns, sc, prompt, n_toks, n_slots=3, max_len=12)
    _, sp = _run_sched(paged_fns, sc, prompt, n_toks, n_slots=3, max_len=12)
    assert sd.stats.ring_bytes_moved > 0 and sp.stats.ring_bytes_moved > 0
    assert sd.stats.ring_bytes_moved >= 5 * sp.stats.ring_bytes_moved
    # and the v3 dict carries all of it
    d = sp.stats.as_dict()
    for k in ("cache_pages_total", "cache_pages_in_use", "cache_pages_free",
              "cache_hbm_bytes", "page_fragmentation", "ring_bytes_moved"):
        assert k in d


# ---------------------------------------------------------------------------
# PageAllocator invariants (deterministic sweep; hypothesis when available)
# ---------------------------------------------------------------------------

def _check_alloc_invariants(n_pages, page_size, max_pages, ops_seed,
                            n_ops=60):
    rng = np.random.default_rng(ops_seed)
    alloc = PageAllocator(n_pages, page_size)
    live = {}                                     # handle -> (row, count)
    snap = None
    snap_live_pages = None
    next_h = 0
    for _ in range(n_ops):
        op = rng.integers(0, 4)
        if op == 0:                               # alloc
            count = int(rng.integers(1, max_pages + 1))
            if count > alloc.n_free:
                with pytest.raises(RuntimeError, match="exhausted"):
                    alloc.alloc(count, max_pages=max_pages)
                continue
            row = np.asarray(alloc.alloc(count, max_pages=max_pages))
            assert (row[:count] > 0).all() and (row[count:] == 0).all()
            live[next_h] = (row, count)
            next_h += 1
        elif op == 1 and live:                    # free
            h = list(live)[int(rng.integers(0, len(live)))]
            row, count = live.pop(h)
            alloc.free(jnp.asarray(row), count)
        elif op == 2:                             # snapshot
            snap = alloc.snapshot()
            snap_live_pages = sorted(
                p for row, c in live.values() for p in row[:c])
        elif op == 3 and snap is not None:        # restore + verify exact
            held = sorted(p for row, c in live.values() for p in row[:c])
            alloc.restore(snap)
            # restored free count complements the snapshot's live set
            assert alloc.n_free == alloc.n_pages - len(snap_live_pages)
            # resync the model to the restored reality: drop rows allocated
            # after the snapshot, resurrect nothing (the snapshot's live
            # rows are tracked by the caller in real use — here we just
            # rebuild `live` from the snapshot's complement)
            del held
            live = {i: (np.asarray([p] + [0] * (max_pages - 1)), 1)
                    for i, p in enumerate(snap_live_pages)}
            next_h = len(live)
        # global invariants after every op
        held = [p for row, c in live.values() for p in row[:c]]
        assert len(held) == len(set(held)), "double-allocated page"
        assert 0 not in held, "NULL page allocated"
        assert alloc.n_free + len(held) == alloc.n_pages, "page leak"
        lane_free = set(np.asarray(alloc._lane)[:alloc.n_free].tolist())
        assert len(lane_free) == alloc.n_free
        assert lane_free.isdisjoint(held), \
            "free-list aliases a live block table"
        assert lane_free | set(held) == set(range(1, n_pages + 1)), \
            "free list + live pages != pool"


@pytest.mark.parametrize("seed", range(4))
def test_allocator_invariants_deterministic(seed):
    _check_alloc_invariants(n_pages=13, page_size=4, max_pages=5,
                            ops_seed=seed)


def test_alloc_row_free_row_shapes():
    lane = jnp.arange(1, 9, dtype=jnp.int32)
    row = _alloc_row(lane, 8, 3, max_pages=4)
    np.testing.assert_array_equal(np.asarray(row), [6, 7, 8, 0])
    lane2 = _free_row(lane, 5, row)
    np.testing.assert_array_equal(np.asarray(lane2)[5:], [6, 7, 8])


try:
    from hypothesis import given, settings, strategies as st_h
    _HAVE_HYP = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYP = False


if _HAVE_HYP:
    @settings(max_examples=25, deadline=None)
    @given(n_pages=st_h.integers(2, 40), page_size=st_h.integers(1, 8),
           max_pages=st_h.integers(1, 8), seed=st_h.integers(0, 10_000))
    def test_allocator_invariants_random(n_pages, page_size, max_pages,
                                         seed):
        """No double-allocation, free-list conservation, block tables never
        alias live pages, snapshot/restore exact — under random op traces
        and pool geometries."""
        _check_alloc_invariants(n_pages, page_size,
                                min(max_pages, n_pages), seed, n_ops=40)


# ---------------------------------------------------------------------------
# live migration over the paged pool
# ---------------------------------------------------------------------------

def _mig_sched(fns, *, mig_after, plan, prompt, n_toks, n_pages=None):
    sc = ServeConfig(capacity=2, queue_depth=2, c_thr=0.7)
    sched = ContinuousScheduler(fns, sc, n_slots=3, max_len=16,
                                clock=LogicalClock(), n_pages=n_pages)

    class _Trig:
        ticks = 0

        def on_tick(self, s, nd, nh, conf):
            self.ticks += 1
            if self.ticks == mig_after:
                s.request_migration(plan)
    sched.controller = _Trig()
    for i, n in enumerate(n_toks):
        sched.submit(Request(sample_id=i, prompt=prompt[i], n_tokens=n))
    return sched


def test_paged_migration_stream_equivalence(tiny_cfg, tiny_params,
                                            tiny_spec, prompt):
    """A mid-trace capacity migration over a LIVE paged pool: streams
    bitwise-equal to the unmigrated paged (and dense) run, pool and
    allocator migrated, zero rollbacks."""
    paged_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec,
                                    page_size=4)
    base, _ = _run_sched(paged_fns,
                         ServeConfig(capacity=2, queue_depth=2, c_thr=0.7),
                         prompt, N_TOKS, n_slots=3, max_len=16)
    with faults.installed(None):
        sched = _mig_sched(paged_fns, mig_after=3,
                           plan=MigrationPlan(capacity=3, reason="test"),
                           prompt=prompt, n_toks=N_TOKS)
        res = sched.drain()
    assert res == base
    st = sched.stats
    assert st.n_migrations == 1 and st.n_migration_rollbacks == 0
    assert sched._alloc.n_free == sched.n_pages


@pytest.mark.parametrize("point", ["migrate:replace", "migrate:resume"])
def test_paged_migration_rollback_restores_allocator(tiny_cfg, tiny_params,
                                                     tiny_spec, prompt,
                                                     point):
    """A fault mid-migration rolls back with ZERO diffs: streams exact and
    the allocator's free list byte-identical to the pre-migration state
    (the snapshot is a defensive copy, so post-rollback frees cannot
    corrupt it)."""
    paged_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec,
                                    page_size=4)
    base, _ = _run_sched(paged_fns,
                         ServeConfig(capacity=2, queue_depth=2, c_thr=0.7),
                         prompt, N_TOKS, n_slots=3, max_len=16)
    with faults.installed(faults.FaultPlan.parse(f"{point}@1")):
        sched = _mig_sched(paged_fns, mig_after=3,
                           plan=MigrationPlan(capacity=3, reason="test"),
                           prompt=prompt, n_toks=N_TOKS)
        res = sched.drain()
    assert res == base
    st = sched.stats
    assert st.n_migration_rollbacks == 1 and st.n_migrations == 0
    assert sched.sc.capacity == 2                    # old plan restored
    assert sched._alloc.n_free == sched.n_pages      # every page home


def test_paged_migration_rejects_dense_fns(tiny_cfg, tiny_params, tiny_spec,
                                           prompt):
    """Migrating a paged scheduler onto dense stage fns must roll back
    (the live page pool's layout is not convertible mid-serve)."""
    from repro.runtime.stage_executor import StagePlacement
    paged_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec,
                                    page_size=4)
    dense_fns = SL.decode_stage_fns(tiny_params, tiny_cfg, tiny_spec)
    with faults.installed(None):
        sched = _mig_sched(
            paged_fns, mig_after=3, prompt=prompt, n_toks=N_TOKS,
            plan=MigrationPlan(placement=StagePlacement.single_device(),
                               fns=dense_fns, reason="bad"))
        res = sched.drain()
    base, _ = _run_sched(paged_fns,
                         ServeConfig(capacity=2, queue_depth=2, c_thr=0.7),
                         prompt, N_TOKS, n_slots=3, max_len=16)
    assert res == base
    assert sched.stats.n_migration_rollbacks == 1


# ---------------------------------------------------------------------------
# 8-device disaggregated bar (subprocess on every tier-1 run)
# ---------------------------------------------------------------------------

def test_paged_disaggregated_subprocess():
    """Paged continuous streams equal dense streams AND the host oracle,
    single-device and stage-disaggregated, at calibrated q ∈ {0.1, 0.3,
    0.5}, under --xla_force_host_platform_device_count=8."""
    code = ("import os\n"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import early_exit as ee
    from repro.core.stage_mesh import StageMeshPlan
    from repro.models.config import ArchConfig
    from repro.runtime import serve_loop as SL
    from repro.runtime.scheduler import (ContinuousScheduler, LogicalClock,
                                         Request)
    from repro.runtime.stage_executor import StagePlacement

    cfg = ArchConfig(name="t", family="dense", n_layers=4, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", param_dtype="float32",
                     tie_embeddings=True)
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(77), (6, 6),
                                           0, cfg.vocab))
    n_toks = [5, 3, 5, 1, 4, 2]
    conf = SL.decode_step0_confidences(params, cfg, spec, prompt,
                                       max_len=12)
    dense_fns = SL.decode_stage_fns(params, cfg, spec)

    def run(fns, sc, placement, n_pages=None):
        s = ContinuousScheduler(fns, sc, n_slots=3, max_len=12,
                                placement=placement, clock=LogicalClock(),
                                n_pages=n_pages)
        for i in range(6):
            s.submit(Request(i, prompt[i], n_toks[i]))
        return s.drain()

    for q in (0.1, 0.3, 0.5):
        c_thr = float(jnp.quantile(conf, q))
        sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=c_thr)
        oracle = SL.HostLoopDecoder(dense_fns, sc).generate(prompt, 5)
        want = {i: [int(x) for x in oracle["tokens"][i][:n_toks[i]]]
                for i in range(6)}
        pl = StagePlacement.from_plan(
            StageMeshPlan.proportional(max(q, 0.2), jax.device_count()))
        paged_fns = SL.decode_stage_fns(params, cfg, spec, pl, page_size=4)
        assert run(SL.decode_stage_fns(params, cfg, spec, None,
                                       page_size=4),
                   sc, None) == want, ("single", q)
        assert run(paged_fns, sc, pl) == want, ("disagg", q)
        assert run(paged_fns, sc, pl, n_pages=7) == want, ("tight", q)
        print("q", q, "OK")
    print("PAGED_EQUIV_ALL_OK")
    """))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=_REPO_ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PAGED_EQUIV_ALL_OK" in r.stdout
