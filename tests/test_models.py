"""Per-architecture smoke tests (reduced configs) + decode/prefill
consistency for the cache machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import early_exit as ee
from repro.models import transformer as T
from repro.models.registry import get_smoke, list_archs

from conftest import assert_finite


def _frontend(cfg, batch, key):
    if cfg.frontend == "vit_stub":
        return jax.random.normal(key, (batch, cfg.n_frontend_tokens,
                                       cfg.d_model)).astype(cfg.act_dtype())
    if cfg.encdec:
        return jax.random.normal(key, (batch, 8, cfg.d_model)
                                 ).astype(cfg.act_dtype())
    return None


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on the reduced config: shapes + finite."""
    cfg = get_smoke(arch)
    spec = ee.default_spec(cfg)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    B, S = 2, 16
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab)
    fe = _frontend(cfg, B, jax.random.fold_in(key, 2))

    eh, fh, aux = ee.forward_train(params, cfg, spec, tokens,
                                   frontend_embeds=fe)
    assert eh.shape == (B, S, cfg.d_model)
    assert fh.shape == (B, S, cfg.d_model)
    assert_finite(fh, f"{arch} final_hidden")

    from repro.core import losses

    def loss_fn(p):
        eh, fh, aux = ee.forward_train(p, cfg, spec, tokens,
                                       frontend_embeds=fe)
        loss, _ = losses.branchynet_joint_loss(p, cfg, eh, fh, labels,
                                               spec.loss_weights, aux=aux)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    assert_finite(grads, f"{arch} grads")


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_serve_batch(arch):
    """The full EE pipeline (stage1 -> decision -> buffer -> stage2 ->
    merge) on the reduced config."""
    cfg = get_smoke(arch)
    spec = ee.default_spec(cfg, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    B, S = 4, 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    fe = _frontend(cfg, B, jax.random.PRNGKey(3))
    out = ee.serve_batch(params, cfg, spec, tokens, frontend_embeds=fe)
    assert out["logits"].shape == (B, cfg.vocab)
    assert out["exit_mask"].shape == (B,)
    assert int(out["overflow"]) == 0
    assert_finite(out["logits"], f"{arch} serve logits")


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen3-4b", "mamba2-130m",
                                  "recurrentgemma-9b", "deepseek-v2-lite-16b",
                                  "grok-1-314b"])
def test_decode_matches_forward(arch):
    """prefill(t[:n]) + decode_step(t[n]) logits == forward(t[:n+1]) last
    logits — the cache machinery is exact (fp32 smoke configs)."""
    cfg = get_smoke(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    S = 9
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S + 1), 0,
                                cfg.vocab)
    full, _ = T.forward(params, cfg, tokens)                # (1, S+1, V)
    logits_p, caches, _ = T.prefill(params, cfg, tokens[:, :S],
                                    max_len=S + 4)
    nxt, caches = T.decode_step(params, cfg, tokens[:, S:S + 1], caches,
                                jnp.int32(S))
    np.testing.assert_allclose(np.asarray(nxt[0]), np.asarray(full[0, S]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen2-7b", "seamless-m4t-medium",
                                  "internvl2-2b"])
def test_staged_equals_unstaged(arch):
    """stage1 + stage2 composition == single-pass forward_hidden."""
    cfg = get_smoke(arch)
    spec = ee.default_spec(cfg)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    fe = _frontend(cfg, B, jax.random.PRNGKey(2))

    h, _, exit_logits, memory = ee.stage1_prefill(params, cfg, spec, tokens,
                                                  frontend_embeds=fe)
    final_logits, _ = ee.stage2_prefill(params, cfg, spec, h, memory=memory)

    fh, _ = T.forward_hidden(params["backbone"], cfg, tokens,
                             frontend_embeds=fe)
    want = T.head(params["backbone"], cfg, fh[:, -1])
    np.testing.assert_allclose(np.asarray(final_logits), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_cache_shapes_match_init(tiny_cfg):
    shapes = T.cache_shapes(tiny_cfg, 3, 16)
    real = T.init_cache(tiny_cfg, 3, 16)
    js, jr = jax.tree.leaves(shapes), jax.tree.leaves(real)
    assert len(js) == len(jr)
    for s, r in zip(js, jr):
        assert tuple(s.shape) == tuple(r.shape), (s.shape, r.shape)
        assert s.dtype == r.dtype


def test_split_caches_on_shapes_and_arrays(tiny_cfg, tiny_spec):
    for caches in (T.cache_shapes(tiny_cfg, 2, 8),
                   T.init_cache(tiny_cfg, 2, 8)):
        s1, s2 = ee.split_caches(tiny_cfg, tiny_spec, caches)
        n1 = jax.tree.leaves(s1["blocks"])[0].shape[0]
        n2 = jax.tree.leaves(s2["blocks"])[0].shape[0]
        assert n1 + n2 == tiny_cfg.n_layers  # pattern len 1 => superblocks
