"""Fault tolerance + serving runtime: checkpoint restart bit-exactness,
failure injection, straggler mitigation, the two-stage server."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as CK
from repro.core import early_exit as ee
from repro.data import pipeline as dp
from repro.runtime import serve_loop as SL
from repro.runtime import train_loop as TL


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_bit_exact(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.array([1, 2, 3], jnp.int32),
                  "d": jnp.array(2.5, jnp.bfloat16)}}
    CK.save(str(tmp_path), 7, tree, extra={"note": "x"})
    back = CK.restore(str(tmp_path), 7, tree)
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert l1.dtype == l2.dtype
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))


def test_ckpt_latest_and_gc(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (5, 10, 15, 20):
        CK.save(str(tmp_path), s, tree)
    assert CK.latest_step(str(tmp_path)) == 20
    CK.gc_old(str(tmp_path), keep=2)
    assert CK.latest_step(str(tmp_path)) == 20
    assert CK.restore(str(tmp_path), 20, tree) is not None
    with pytest.raises(Exception):
        CK.restore(str(tmp_path), 5, tree)      # collected


def test_ckpt_incomplete_write_ignored(tmp_path):
    """A checkpoint without its commit marker must be invisible (atomic
    commit protocol)."""
    tree = {"x": jnp.ones((2,))}
    CK.save(str(tmp_path), 3, tree)
    d = os.path.join(str(tmp_path), "step_00000008")
    os.makedirs(d)                               # torn write: dir, no marker
    with open(os.path.join(d, "data.npz"), "wb") as f:
        f.write(b"garbage")
    assert CK.latest_step(str(tmp_path)) == 3


def test_async_checkpointer(tmp_path):
    ck = CK.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.full((4,), 3.0)}
    for s in (1, 2, 3):
        ck.save_async(s, tree)
    ck.wait()
    assert CK.latest_step(str(tmp_path)) == 3


# ---------------------------------------------------------------------------
# train loop: restart bit-exactness + straggler mitigation
# ---------------------------------------------------------------------------

def _tc(tmp_path, **kw):
    from repro.optim import adamw
    base = dict(steps=8, ckpt_every=4, ckpt_dir=str(tmp_path), log_every=4,
                optim=adamw.AdamWConfig(lr=1e-3, warmup_steps=2,
                                        total_steps=8))
    base.update(kw)
    return TL.TrainConfig(**base)


def _stream(cfg):
    return dp.LMStreamSpec(global_batch=4, seq_len=16, vocab=cfg.vocab,
                           seed=0)


def test_train_loss_decreases(tiny_cfg, tiny_spec, tmp_path):
    tc = _tc(tmp_path, steps=12, ckpt_every=12, log_every=1,
             optim=__import__("repro.optim.adamw", fromlist=["x"]
                              ).AdamWConfig(lr=5e-3, warmup_steps=1,
                                            total_steps=12))
    out = TL.train(tiny_cfg, tiny_spec, tc, stream_spec=_stream(tiny_cfg))
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_restart_resumes_bit_exact(tiny_cfg, tiny_spec, tmp_path):
    """Kill at step 5 (after the step-4 checkpoint), restart, and compare
    final params against an uninterrupted run."""
    ref_dir, f_dir = str(tmp_path / "ref"), str(tmp_path / "fail")
    ref = TL.train(tiny_cfg, tiny_spec, _tc(ref_dir),
                   stream_spec=_stream(tiny_cfg))
    out = TL.train_with_restarts(tiny_cfg, tiny_spec,
                                 _tc(f_dir, fail_at_step=5),
                                 stream_spec=_stream(tiny_cfg))
    assert out["restarts"] == 1
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_straggler_backup_fetch(tiny_cfg, tiny_spec, tmp_path):
    """A stalling data fetch times out and the backup batch is used; the
    run completes."""
    tc = _tc(tmp_path, steps=3, ckpt_every=3, fetch_timeout_s=0.05,
             straggler=dp.StragglerModel(stall_prob=1.0, stall_s=0.5,
                                         seed=1))
    out = TL.train(tiny_cfg, tiny_spec, tc, stream_spec=_stream(tiny_cfg))
    assert out["step"] == 3


# ---------------------------------------------------------------------------
# two-stage server
# ---------------------------------------------------------------------------

def test_server_matches_one_shot(tiny_cfg, tiny_params):
    """Server results == serve_batch merged logits for every sample id."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=0.6)
    B, S, N = 4, 8, 16
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (N, S), 0,
                                         tiny_cfg.vocab))
    server = SL.build_server(tiny_params, tiny_cfg, spec,
                             SL.ServeConfig(capacity=4, c_thr=spec.c_thr))
    results = SL.serve_dataset(server, toks, batch=B)
    assert set(results) == set(range(N))
    assert server.stats.n_samples == N
    assert server.stats.n_exited + server.stats.n_stage2 == N

    one = ee.serve_batch(tiny_params, tiny_cfg, spec, jnp.asarray(toks),
                         capacity=N)
    merged = np.asarray(one["logits"])
    for sid in range(N):
        np.testing.assert_allclose(results[sid], merged[sid], rtol=2e-4,
                                   atol=2e-4)


def test_server_realized_q(tiny_cfg, tiny_params):
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=0.0)   # everything exits
    server = SL.build_server(tiny_params, tiny_cfg, spec,
                             SL.ServeConfig(capacity=2, c_thr=0.0))
    toks = np.zeros((8, 8), np.int32)
    res = SL.serve_dataset(server, toks, batch=4)
    assert server.stats.realized_q == 0.0
    assert len(res) == 8
