"""Online drift control plane: telemetry filters, the controller state
machine (warmup / hysteresis / cooldown / bounded actuation), the
scheduler control surface (threshold, drain policy, occupancy cap,
discrete-point capacity re-size — with token streams invariant under every
actuation), closed-loop convergence on a nonstationary trace, and the sync
policy's actuation path.

The end-to-end tests drive the REAL ``ContinuousScheduler`` with the drift
benchmark's semi-synthetic ``DecodeFns`` (analytic confidences/tokens, so
expected streams are known exactly and hard rates are controllable — see
``benchmarks/serve_drift.py``)."""
import jax
import numpy as np
import pytest

from benchmarks.serve_drift import (PROVISIONED_P, conf_of, difficulty_trace,
                                    drift_fns, make_controller, token_of)
from repro.core import exit_decision as ed
from repro.core.stage_mesh import StageMeshPlan, stage2_capacity
from repro.runtime import serve_loop as SL
from repro.runtime import telemetry as TM
from repro.runtime.controller import ControllerConfig, DriftController
from repro.runtime.scheduler import (ContinuousScheduler, LogicalClock,
                                     Request, ServeStats, SyncScheduler)
from repro.runtime.stage_executor import StagePlacement

_S = 4                       # drift_fns prompt length


# ---------------------------------------------------------------------------
# telemetry: the shared drift filter + rolling reservoir + control windows
# ---------------------------------------------------------------------------

def test_ewma_empty_and_constant():
    assert TM.ewma([]) == 0.0
    assert TM.ewma([0.3] * 50) == pytest.approx(0.3)


def test_ewma_window_bound():
    """Entries older than the window cannot haunt the estimate."""
    series = [1.0] * 10_000 + [0.0] * TM.DRIFT_WINDOW
    assert TM.ewma(series) < 1e-6
    # and the same series truncated to the window is identical
    assert TM.ewma(series) == TM.ewma(series[-TM.DRIFT_WINDOW:])


def test_ewma_tracks_step_change():
    """A step in q crosses most of the gap within ~2/alpha entries."""
    series = [0.25] * 100 + [0.8] * 30
    assert TM.ewma(series, alpha=0.1) > 0.6


def test_confidence_reservoir_rolls():
    r = TM.ConfidenceReservoir(size=8)
    r.extend(np.linspace(0.0, 1.0, 20))
    assert len(r) == 8 and r.full
    np.testing.assert_allclose(r.snapshot(),
                               np.linspace(0.0, 1.0, 20)[-8:].astype(
                                   np.float32))
    r.clear()
    assert len(r) == 0
    with pytest.raises(ValueError):
        TM.ConfidenceReservoir(size=0)


def test_control_window_deltas_survive_reset():
    """observe_counters folds LIFETIME counters as per-visit deltas; the
    high-water marks persist across window resets so a new window never
    re-counts old stalls."""
    w = TM.ControlWindow()
    w.observe(8, 2)
    w.observe_counters(n_stalls=3, n_buckets=2, bucket_fill_sum=1.5)
    assert w.stalls == 3 and w.mean_bucket_fill == pytest.approx(0.75)
    assert w.q == pytest.approx(0.25) and w.mean_active == 8
    w.reset()
    w.observe(4, 4)
    w.observe_counters(n_stalls=4, n_buckets=3, bucket_fill_sum=2.5)
    assert w.stalls == 1 and w.buckets == 1          # deltas, not lifetime
    assert w.q == pytest.approx(1.0)


def test_serve_stats_windowed_drift_view():
    """ServeStats exposes the windowed drift view through the SAME ewma
    definition the controller uses, and as_dict carries it."""
    st = ServeStats()
    for _ in range(20):
        st.record_decisions(10, 8)
    assert st.q_drift == 0.0                         # no provisioned p
    st.provisioned_p = 0.25
    assert st.realized_q_ewma == pytest.approx(
        TM.ewma(st.realized_q_series))
    assert st.q_drift == pytest.approx(st.realized_q_ewma - 0.25)
    d = st.as_dict()
    for k in ("provisioned_p", "realized_q_ewma", "q_drift"):
        assert k in d
    assert d["q_drift"] == pytest.approx(0.8 - 0.25)


# ---------------------------------------------------------------------------
# calibrate_threshold edge cases: the controller re-solves it ONLINE, so
# its corners are part of the control plane's contract
# ---------------------------------------------------------------------------

def test_calibrate_threshold_empty_and_bad_rate_raise():
    """An empty reservoir or a garbage target must fail loudly, never
    return a NaN threshold into the actuation path."""
    import jax.numpy as jnp
    with pytest.raises(ValueError, match="non-empty"):
        ed.calibrate_threshold(jnp.zeros((0,)), 0.5)
    conf = jnp.asarray([0.2, 0.6, 0.9])
    for bad in (-0.1, 1.5):
        with pytest.raises(ValueError, match="target_exit_rate"):
            ed.calibrate_threshold(conf, bad)


def test_calibrate_threshold_rate_extremes():
    """target 0: nobody exits (strict conf > C_thr); target 1: everybody
    exits, including ties at the minimum."""
    import jax.numpy as jnp
    conf = jnp.asarray([0.3, 0.3, 0.5, 0.7, 0.9])
    thr0 = ed.calibrate_threshold(conf, 0.0)
    assert float((np.asarray(conf) > thr0).mean()) == 0.0
    thr1 = ed.calibrate_threshold(conf, 1.0)
    assert thr1 < 0.3
    assert float((np.asarray(conf) > thr1).mean()) == 1.0
    # a single-element set works at every rate
    one = jnp.asarray([0.6])
    assert not bool(0.6 > ed.calibrate_threshold(one, 0.0))
    assert bool(0.6 > ed.calibrate_threshold(one, 1.0))


def test_calibrate_threshold_ties_at_quantile_boundary():
    """Mass at the quantile boundary under-exits (the strict comparison
    sends boundary samples to stage 2 — the conservative side), never
    over-exits."""
    import jax.numpy as jnp
    # all-identical confidences: any interior rate realizes 0 exits
    flat = jnp.full((100,), 0.5)
    thr = ed.calibrate_threshold(flat, 0.4)
    assert thr == pytest.approx(0.5)
    assert float((np.asarray(flat) > thr).mean()) == 0.0
    # bimodal with the quantile landing on the upper atom: the atom stays
    # hard (realized <= target), the clearly-confident half still exits
    bimodal = jnp.asarray([0.3] * 50 + [0.7] * 50)
    thr = ed.calibrate_threshold(bimodal, 0.25)
    assert float((np.asarray(bimodal) > thr).mean()) <= 0.25 + 1e-9
    thr_half = ed.calibrate_threshold(bimodal, 0.5)
    assert float((np.asarray(bimodal) > thr_half).mean()) == pytest.approx(
        0.5)


# ---------------------------------------------------------------------------
# ControllerConfig validation + the state machine over a fake scheduler
# ---------------------------------------------------------------------------

def test_controller_config_validation():
    with pytest.raises(ValueError, match="provisioned_p"):
        ControllerConfig(provisioned_p=0.0)
    with pytest.raises(ValueError, match="release_band"):
        ControllerConfig(provisioned_p=0.3, target_band=0.05,
                         release_band=0.05)
    with pytest.raises(ValueError, match="replan_band"):
        ControllerConfig(provisioned_p=0.3, target_band=0.1,
                         replan_band=0.05)
    with pytest.raises(ValueError, match="max_thr_step"):
        ControllerConfig(provisioned_p=0.3, max_thr_step=0.0)
    with pytest.raises(ValueError, match="persistence_ticks"):
        ControllerConfig(provisioned_p=0.3, persistence_ticks=0)


class FakeSched:
    """Minimal control surface: records every actuation, fakes stats."""

    def __init__(self, c_thr=0.8, n_slots=8, capacity=2):
        self.stats = ServeStats()
        self.c_thr = c_thr
        self.sc = SL.ServeConfig(capacity=capacity, c_thr=c_thr)
        self.n_slots = n_slots
        self.eager_drain_below = capacity
        self.active_cap = n_slots
        self.controller = None
        self.requested_capacity = None
        self.placement = StagePlacement.single_device()

    def set_c_thr(self, v):
        self.c_thr = float(v)

    def set_eager_drain_below(self, k):
        self.eager_drain_below = int(k)

    def set_active_cap(self, cap):
        self.active_cap = max(1, min(int(cap), self.n_slots))

    def request_capacity(self, cap):
        self.requested_capacity = int(cap)


def _tick(ctl, sched, n=8, n_hard=8, conf=None):
    sched.stats.record_decisions(n, n_hard)
    ctl.on_tick(sched, n, n_hard,
                conf if conf is not None else np.full(n, 0.5, np.float32))


def _mk(p=0.25, **kw):
    kw.setdefault("min_decisions", 32)
    kw.setdefault("persistence_ticks", 2)
    kw.setdefault("cooldown_ticks", 4)
    kw.setdefault("min_reservoir", 8)
    kw.setdefault("autoscale", False)
    kw.setdefault("replan", False)
    return DriftController(ControllerConfig(provisioned_p=p, **kw))


def test_warmup_gates_actuation():
    ctl = _mk(min_decisions=64)
    fake = ctl.attach(FakeSched(c_thr=0.8))
    assert fake.stats.provisioned_p == 0.25
    for _ in range(7):                     # 56 decisions, all hard: q = 1
        _tick(ctl, fake)
    assert ctl.state.phase == "warmup"
    assert fake.c_thr == 0.8 and ctl.state.n_recalibrations == 0


def test_hysteresis_needs_persistence_and_release_rearm():
    """An excursion shorter than persistence_ticks never actuates — the
    streak builds while the filtered drift sits outside the target band
    and resets once it re-enters the release band."""
    # persistence high enough that this trace can never trip it: what's
    # under test is the streak/re-arm bookkeeping, not the trip point
    ctl = _mk(min_decisions=8, persistence_ticks=50, cooldown_ticks=0)
    fake = ctl.attach(FakeSched(c_thr=0.8))
    for _ in range(10):                    # warmup met, EWMA(q) = 0.25
        _tick(ctl, fake, n=8, n_hard=2)
    assert ctl.state.phase == "steady" and ctl.state.drift_streak == 0
    for _ in range(12):                    # sustained drift: streak builds
        _tick(ctl, fake, n=8, n_hard=8)
    assert ctl.state.drift_streak > 0
    assert ctl.state.n_recalibrations == 0           # below persistence
    for _ in range(60):                    # back in band: streak re-arms
        _tick(ctl, fake, n=8, n_hard=2)
    assert ctl.state.drift_streak == 0 and ctl.state.phase == "steady"
    assert ctl.state.n_recalibrations == 0


def test_persistent_drift_actuates():
    ctl = _mk(min_decisions=8, persistence_ticks=3, cooldown_ticks=0)
    fake = ctl.attach(FakeSched(c_thr=0.8))
    for _ in range(30):                    # sustained all-hard traffic
        _tick(ctl, fake, n=8, n_hard=8)
    assert ctl.state.n_recalibrations >= 1
    assert fake.c_thr < 0.8


def test_cooldown_holds_after_actuation():
    ctl = _mk(min_decisions=8, persistence_ticks=1, cooldown_ticks=10)
    fake = ctl.attach(FakeSched(c_thr=0.8))
    for _ in range(40):                    # all-hard: actuate once
        _tick(ctl, fake)
    # every post-actuation visit inside the cooldown must not re-actuate:
    # 40 all-hard ticks with persistence 1 would otherwise actuate ~many
    # times; cooldown 10 caps it near 40 / 11
    assert 1 <= ctl.state.n_recalibrations <= 4


def test_recalibration_is_bounded_and_converges_to_quantile():
    """The solved threshold is the (1-p)-exit-rate quantile of the
    reservoir; each actuation moves at most max_thr_step toward it."""
    ctl = _mk(min_decisions=8, persistence_ticks=1, cooldown_ticks=0,
              max_thr_step=0.05, reservoir_size=64)
    fake = ctl.attach(FakeSched(c_thr=0.9))
    conf = np.linspace(0.1, 0.3, 8).astype(np.float32)   # all below thr
    prev = fake.c_thr
    while ctl.state.n_recalibrations == 0:
        _tick(ctl, fake, conf=conf)
    assert prev - fake.c_thr == pytest.approx(0.05, abs=1e-6), \
        "first step must clip at max_thr_step"
    for _ in range(80):
        _tick(ctl, fake, conf=conf)
    # converged: the 25th percentile of the reservoir (exit rate 0.75)
    want = float(np.quantile(np.linspace(0.1, 0.3, 8), 0.25))
    assert fake.c_thr == pytest.approx(want, abs=0.02)
    kinds = {a["kind"] for a in ctl.state.actions}
    assert "recalibrate" in kinds


def test_replan_escalation_reports_and_applies_capacity():
    """Past the re-plan band the Eq. (1)/proportional re-plan fires; under
    apply_replan the bucket-capacity half is requested on the scheduler."""
    ctl = _mk(min_decisions=8, persistence_ticks=1, cooldown_ticks=0,
              replan=True, apply_replan=True, replan_band=0.2)
    fake = ctl.attach(FakeSched(c_thr=0.8, n_slots=8, capacity=2))
    for _ in range(60):                    # q -> 1: way past the band
        _tick(ctl, fake)
    st = ctl.state
    assert st.n_replans >= 1
    assert fake.requested_capacity == stage2_capacity(
        8, min(max(st.q_ewma, 0.01), 1.0), multiple=1)
    # degenerate placement: no chip re-split to recommend
    assert st.recommended_plan is None
    assert any(a["kind"] == "replan" for a in st.actions)


def test_replan_with_taps_recommends_combined_design():
    """With profiled TAP curves the re-plan actuator runs the real Eq. (1)
    re-combination at the observed q."""
    from repro.core.tap import DesignPoint, TAPFunction
    mk = lambda scale: TAPFunction([
        DesignPoint(resources=(float(c), c * 16.0), throughput=scale * c)
        for c in range(1, 9)])
    ctl = DriftController(
        ControllerConfig(provisioned_p=0.25, min_decisions=8,
                         persistence_ticks=1, cooldown_ticks=0,
                         replan_band=0.2, recalibrate=False,
                         autoscale=False),
        taps=(mk(100.0), mk(80.0)), chips=8)
    fake = ctl.attach(FakeSched(c_thr=0.8))
    for _ in range(60):
        _tick(ctl, fake)
    plan = ctl.state.recommended_plan
    assert plan is not None
    assert plan.chips1 + plan.chips2 <= 8
    # q -> 1 means stage 2 sees ~full traffic: it gets at least as many
    # chips as the p = 0.25 provisioning would give it
    assert plan.chips2 >= 2


def test_autoscaler_slo_cap_and_drain_policy():
    """p99 over the SLO shrinks the live-occupancy cap (bounded, by one);
    once the transient ages out of the WINDOWED latency view and stalls
    stop, it grows back — on the same lifetime stats object, no reset. A
    starved window with healthy fill raises eager_drain_below."""
    ctl = _mk(min_decisions=8, autoscale=True, autoscale_every=4,
              latency_slo_p99=0.5, latency_window=8, target_band=0.5,
              replan_band=0.6)
    fake = ctl.attach(FakeSched(c_thr=0.8, n_slots=8, capacity=4))
    fake.eager_drain_below = 0
    # slow requests: p99 ~ 2.0 >> SLO 0.5
    for i in range(10):
        fake.stats.record_submit(i, 0.0)
        fake.stats.record_finish(i, 2.0)
    # starved pool: 1 live row per tick, buckets full when they dispatch
    for i in range(8):
        fake.stats.record_bucket(1.0)
        _tick(ctl, fake, n=1, n_hard=0)
    assert fake.active_cap < 8                       # SLO shrink
    assert fake.eager_drain_below > 0                # starvation drain
    assert ctl.state.n_autoscale_events >= 1
    # recovery: the overload is transient — later finishes are fast, the
    # slow ones age out of the latency window, and the cap must grow back
    cap_low = fake.active_cap
    for i in range(10, 30):
        fake.stats.record_submit(i, 0.0)
        fake.stats.record_finish(i, 0.01)
    for _ in range(12):
        _tick(ctl, fake, n=8, n_hard=0)
    assert fake.active_cap > cap_low


# ---------------------------------------------------------------------------
# the real scheduler's control surface, driven by drift_fns (analytic
# streams: every actuation must leave per-sample tokens EXACTLY intact)
# ---------------------------------------------------------------------------

def _flat_fns(n, difficulty=0.7):
    return drift_fns(np.full(n, difficulty, np.float32), d_model=16,
                     burn1=1, burn2=1)


def _run_sched(fns, sc, n, n_tokens, n_slots=4, attach=None, **kw):
    sched = ContinuousScheduler(fns, sc, n_slots=n_slots,
                                max_len=_S + n_tokens,
                                clock=LogicalClock(), **kw)
    if attach is not None:
        attach(sched)
    for i in range(n):
        sched.submit(Request(i, np.full((_S,), i, np.int32), n_tokens))
    return sched.run(), sched


def _expected(n, n_tokens):
    return {i: [token_of(i, t) for t in range(n_tokens)] for i in range(n)}


def test_set_c_thr_midrun_changes_rate_not_streams():
    """Re-aiming the threshold mid-run flips the hard rate (0.7-difficulty
    confidences: thr above -> all hard, below -> all easy) while per-sample
    token streams stay exactly the analytic ones."""
    n, n_tokens = 8, 12
    fns = _flat_fns(n)

    class FlipThr:
        def __init__(self):
            self.ticks = 0

        def on_tick(self, sched, n_dec, n_hard, conf=None):
            self.ticks += 1
            if self.ticks == 6:
                sched.set_c_thr(0.2)       # everyone exits from here on

    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.95)  # all hard
    def attach(s):
        s.controller = FlipThr()
    res, sched = _run_sched(fns, sc, n, n_tokens, attach=attach)
    assert res == _expected(n, n_tokens)
    qs = list(sched.stats.realized_q_series)
    assert qs[0] == 1.0 and qs[-1] == 0.0            # the flip happened


def test_active_cap_bounds_occupancy():
    n, n_tokens = 10, 6
    fns = _flat_fns(n)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    def attach(s):
        s.set_active_cap(2)
    res, sched = _run_sched(fns, sc, n, n_tokens, n_slots=6, attach=attach)
    assert res == _expected(n, n_tokens)
    assert sched.peak_busy <= 2
    assert sched.stats.n_finished == n


def test_active_cap_clamps():
    fns = _flat_fns(2)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    sched = ContinuousScheduler(fns, sc, n_slots=4, max_len=_S + 4,
                                clock=LogicalClock())
    sched.set_active_cap(0)
    assert sched.active_cap == 1                     # progress guaranteed
    sched.set_active_cap(99)
    assert sched.active_cap == 4


def test_request_capacity_applies_at_discrete_point():
    """A capacity re-size lands at an empty-ring boundary: the config is a
    fresh object (caller's untouched), the ring re-sizes, streams hold."""
    n, n_tokens = 8, 10
    fns = _flat_fns(n)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.95)  # all hard

    class Resize:
        def __init__(self):
            self.ticks = 0

        def on_tick(self, sched, n_dec, n_hard, conf=None):
            self.ticks += 1
            if self.ticks == 4:
                sched.request_capacity(4)

    def attach(s):
        s.controller = Resize()
    res, sched = _run_sched(fns, sc, n, n_tokens, n_slots=4, attach=attach)
    assert res == _expected(n, n_tokens)
    assert sched.sc.capacity == 4
    assert sc.capacity == 2                          # caller's config intact
    assert sched.ring.sc.capacity == 4


def test_controller_disabled_leaves_scheduler_untouched():
    """No controller: the control fields keep constructor values and the
    run is the PR-4 path (streams equal, no control state)."""
    n, n_tokens = 6, 8
    fns = _flat_fns(n)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    res, sched = _run_sched(fns, sc, n, n_tokens)
    assert res == _expected(n, n_tokens)
    assert sched.controller is None
    assert sched.c_thr == 0.5 and sched.active_cap == sched.n_slots
    assert sched.sc is sc                            # no config swap


# ---------------------------------------------------------------------------
# closed loop end to end: nonstationary trace -> controller converges
# ---------------------------------------------------------------------------

def test_closed_loop_converges_on_drift_trace():
    """On a piecewise/ramped difficulty trace the controlled scheduler
    re-calibrates and steers the realized exit rate back toward the
    provisioned p, while the uncontrolled one saturates at q ~ 1."""
    p = PROVISIONED_P
    n, n_tokens, n_slots = 64, 12, 8
    diff = difficulty_trace(n)
    fns = drift_fns(diff, d_model=16, burn1=1, burn2=1)
    # phase-A calibration
    sids = np.arange(n // 4)
    conf = np.concatenate([conf_of(sids, t, diff[sids])
                           for t in range(1, n_tokens)])
    thr0 = float(np.quantile(conf, p))
    sc = SL.ServeConfig(capacity=2, queue_depth=4, c_thr=thr0)

    res_u, sched_u = _run_sched(fns, sc, n, n_tokens, n_slots=n_slots)
    ctl = make_controller(p)
    res_c, sched_c = _run_sched(fns, sc, n, n_tokens, n_slots=n_slots,
                                attach=ctl.attach)
    assert res_u == _expected(n, n_tokens)
    assert res_c == _expected(n, n_tokens)           # actuation-invariant
    assert ctl.state.n_recalibrations >= 2
    q_tail_c = ctl.realized_q_tail(min_decisions=128)
    q_tail_u = np.mean(list(sched_u.stats.realized_q_series)[-24:])
    assert abs(q_tail_c - p) < 0.1, q_tail_c         # steered back to p
    assert q_tail_u > 0.9, q_tail_u                  # uncontrolled saturates
    assert ctl.state.c_thr < thr0                    # threshold moved down


def test_sync_scheduler_actuation_path():
    """The sync policy's controller visit: per-batch sensing, conf-sink
    reservoir feed through DecodeServer, threshold actuation applied."""
    n, n_tokens, n_slots = 12, 8, 4
    fns = _flat_fns(n, difficulty=0.4)               # conf ~ 0.31..0.49
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.95)  # all hard
    ctl = DriftController(ControllerConfig(
        provisioned_p=0.25, min_decisions=8, persistence_ticks=1,
        cooldown_ticks=0, max_thr_step=0.5, reservoir_size=128,
        min_reservoir=16, autoscale=False, replan=False))
    sched = SyncScheduler(SL.DecodeServer(fns, sc), n_slots,
                          clock=LogicalClock())
    ctl.attach(sched)
    assert sched.server.conf_sink is ctl.reservoir
    for i in range(n):
        sched.submit(Request(i, np.full((_S,), i, np.int32), n_tokens))
    res = sched.run()
    assert res == _expected(n, n_tokens)
    assert len(ctl.reservoir) > 0                    # sink fed
    assert ctl.state.n_recalibrations >= 1
    assert sched.server.c_thr < 0.95                 # actuation landed


@pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
def test_controller_disaggregated_replan_recommends_split(tiny_cfg,
                                                          tiny_params,
                                                          tiny_spec):
    """On a real disaggregated placement the re-plan actuator recommends a
    q-proportional chip re-split over the stage submeshes (report-only),
    and streams stay equivalent to the host-loop oracle."""
    from repro.core import early_exit as ee
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(21), (6, 8),
                                           0, tiny_cfg.vocab))
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=1.1)   # all hard
    oracle = SL.build_host_decoder(tiny_params, tiny_cfg, tiny_spec,
                                   sc).generate(prompt, 5)
    pl = StagePlacement.from_plan(
        StageMeshPlan.proportional(0.5, jax.device_count()))
    sched = SL.build_continuous_scheduler(tiny_params, tiny_cfg, tiny_spec,
                                          sc, n_slots=4, max_len=13,
                                          placement=pl, clock=LogicalClock())
    ctl = DriftController(ControllerConfig(
        provisioned_p=0.25, min_decisions=8, persistence_ticks=1,
        cooldown_ticks=0, recalibrate=False, autoscale=False,
        replan_band=0.1))
    ctl.attach(sched)
    for i in range(prompt.shape[0]):
        sched.submit(Request(i, prompt[i], 5))
    res = sched.run()
    want = {i: [int(x) for x in oracle["tokens"][i][:5]]
            for i in range(prompt.shape[0])}
    assert res == want
    plan = ctl.state.recommended_plan
    assert plan is not None
    assert plan.chips1 + plan.chips2 == 8
    assert plan.chips2 > plan.chips1                 # q ~ 1: stage 2 heavy
