"""Decode-time serving: bitwise parity of the device-resident DecodeServer
against the host-loop decode baseline, per-token ServeStats, backpressure
through the pytree ring, and FIFO property tests of the generalized ring
buffer (hypothesis, skipped when unavailable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import early_exit as ee
from repro.models import transformer as T
from repro.runtime import serve_loop as SL


def _decode_conf_median(tiny_cfg, tiny_params, tiny_spec, prompt):
    """A C_thr that splits the first decode step's tokens roughly in half,
    so parity tests exercise a mixed easy/hard pattern."""
    conf = SL.decode_step0_confidences(tiny_params, tiny_cfg, tiny_spec,
                                       prompt, max_len=prompt.shape[1] + 2)
    return float(np.median(np.asarray(conf)))


@pytest.fixture(scope="module")
def prompt(tiny_cfg):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(21), (6, 8), 0,
                                         tiny_cfg.vocab))


def _gen_both(tiny_params, tiny_cfg, spec, sc, prompt, n_tokens):
    fns = SL.decode_stage_fns(tiny_params, tiny_cfg, spec)
    dev = SL.DecodeServer(fns, sc)
    host = SL.HostLoopDecoder(fns, sc)
    return dev.generate(prompt, n_tokens), dev, host.generate(
        prompt, n_tokens), host


@pytest.mark.parametrize("c_thr", [0.0, 1.1, None])
def test_decode_server_bitwise_parity(tiny_cfg, tiny_params, tiny_spec,
                                      prompt, c_thr):
    """The tentpole parity bar, decode edition: per-token merged logits and
    greedy tokens bitwise identical between the device-resident path and
    the host-loop baseline — for all-exit, none-exit, and mixed traffic."""
    if c_thr is None:
        c_thr = _decode_conf_median(tiny_cfg, tiny_params, tiny_spec, prompt)
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=c_thr)
    sc = SL.ServeConfig(capacity=3, queue_depth=2, c_thr=c_thr)
    od, dev, oh, host = _gen_both(tiny_params, tiny_cfg, spec, sc, prompt, 6)
    np.testing.assert_array_equal(od["tokens"], oh["tokens"])
    np.testing.assert_array_equal(od["logits"], oh["logits"])
    assert dev.stats.n_decisions == host.stats.n_decisions
    assert dev.stats.n_exited == host.stats.n_exited
    assert dev.stats.n_stage2 == host.stats.n_stage2


def test_decode_stats_per_token(tiny_cfg, tiny_params, prompt):
    """Decode stats count per-token decisions, not per-sample: B samples x
    (n_tokens - 1) decode steps, realized_q per decision, and the new
    fields surface in as_dict for the benchmark JSON."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=1.1)     # every token hard
    sc = SL.ServeConfig(capacity=3, queue_depth=2, c_thr=spec.c_thr)
    od, dev, oh, host = _gen_both(tiny_params, tiny_cfg, spec, sc, prompt, 5)
    B, T_new = prompt.shape[0], 5
    for st in (dev.stats, host.stats):
        assert st.n_samples == B
        assert st.n_decisions == B * (T_new - 1)
        assert st.n_stage2 == B * (T_new - 1)
        assert st.n_exited == 0
        assert st.realized_q == 1.0
        assert st.decisions_per_sample == T_new - 1
        d = st.as_dict()
        assert d["n_decisions"] == B * (T_new - 1)
        assert d["decisions_per_sample"] == T_new - 1


def test_decode_ring_backpressure(tiny_cfg, tiny_params, prompt):
    """All-hard decode traffic through a ring smaller than the batch: the
    chunked enqueue must stall (full buckets drain first), never deadlock,
    never drop — and stay bitwise identical to the host loop."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=1.1)
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=spec.c_thr)
    assert sc.queue_depth * sc.capacity < prompt.shape[0]
    od, dev, oh, host = _gen_both(tiny_params, tiny_cfg, spec, sc, prompt, 4)
    assert dev.stats.n_stalls > 0
    np.testing.assert_array_equal(od["tokens"], oh["tokens"])
    np.testing.assert_array_equal(od["logits"], oh["logits"])


def test_decode_all_hard_matches_unstaged_decode(tiny_cfg, tiny_params,
                                                 tiny_spec, prompt):
    """With nothing exiting, staged EE decode must reproduce the plain
    full-depth decode loop (same greedy continuation)."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=1.1)
    sc = SL.ServeConfig(capacity=prompt.shape[0], queue_depth=2,
                        c_thr=spec.c_thr)
    n_tokens = 4
    out = SL.build_decode_server(tiny_params, tiny_cfg, spec,
                                 sc).generate(prompt, n_tokens)
    bb = tiny_params["backbone"]
    logits, caches, _ = T.prefill(bb, tiny_cfg, jnp.asarray(prompt),
                                  max_len=prompt.shape[1] + n_tokens)
    want_toks = [np.argmax(np.asarray(logits), -1).astype(np.int32)]
    for t in range(1, n_tokens):
        tok = jnp.asarray(want_toks[-1][:, None])
        logits, caches = T.decode_step(bb, tiny_cfg, tok, caches,
                                       jnp.int32(prompt.shape[1] + t - 1))
        want_toks.append(np.argmax(np.asarray(logits), -1).astype(np.int32))
        np.testing.assert_allclose(out["logits"][:, t], np.asarray(logits),
                                   rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(out["tokens"], np.stack(want_toks, 1))


def test_decode_exit_gap_cache_semantics(tiny_cfg, tiny_params, prompt):
    """A token that exits early leaves zeros at its position in the
    stage-2 cache segment (exit-gap), while the stage-1 segment advances
    for every token — both paths must agree on that state, which the
    bitwise logits parity above implies; here we check it directly."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=0.0)     # everything exits
    sc = SL.ServeConfig(capacity=3, queue_depth=2, c_thr=spec.c_thr)
    fns = SL.decode_stage_fns(tiny_params, tiny_cfg, spec)
    dev = SL.DecodeServer(fns, sc)
    S = prompt.shape[1]
    dev.generate(prompt, 4)
    for leaf in jax.tree.leaves(dev._rows):
        if leaf.ndim >= 3:       # (B, n_sb, L, KH, hd) K/V slabs
            decode_slots = np.asarray(leaf)[:, :, S:]
            np.testing.assert_array_equal(decode_slots,
                                          np.zeros_like(decode_slots))
    assert dev.stats.n_stage2 == 0 and dev.stats.n_exited > 0


# generalized-ring FIFO property tests live in tests/test_ring_properties.py
# (hypothesis-gated; this module must run without the optional dep)
