"""The while-aware HLO analyzer: trip-count multiplication, dot FLOPs,
collective payloads — on live-compiled programs and crafted HLO text."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import xla_cost_analysis
from repro.launch import hlo_analysis as HA


def test_scan_flops_multiplied():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    a = HA.analyze(comp.as_text())
    assert abs(a["flops"] - 7 * 2 * 64 ** 3) < 1e-6
    # and XLA's own analysis under-counts (the bug we fix)
    assert xla_cost_analysis(comp)["flops"] < a["flops"]


def test_nested_scan_flops():
    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    a = HA.analyze(comp.as_text())
    assert abs(a["flops"] - 15 * 2 * 32 ** 3) < 1e-6


def test_plain_dot_flops_and_bytes():
    def f(x, w):
        return jnp.tanh(x @ w)
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 128), jnp.float32)).compile()
    a = HA.analyze(comp.as_text())
    assert abs(a["flops"] - 2 * 256 * 512 * 128) < 1e-6
    xla_bytes = xla_cost_analysis(comp)["bytes accessed"]
    assert abs(a["bytes_accessed"] - xla_bytes) / xla_bytes < 0.5


def test_batched_dot_general():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((4, 16, 32), jnp.float32),
        jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)).compile()
    a = HA.analyze(comp.as_text())
    assert abs(a["flops"] - 2 * 4 * 16 * 32 * 8) < 1e-6


def test_crafted_while_collective_text():
    """Hermetic: a while loop with trip count 10 whose body does one
    all-reduce of bf16[1024] (2048 B) -> 20480 collective bytes."""
    text = """
HloModule m

%body (p: (s32[], bf16[1024])) -> (s32[], bf16[1024]) {
  %p = (s32[], bf16[1024]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = bf16[1024]{0} get-tuple-element(%p), index=1
  %ar = bf16[1024]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], bf16[1024]{0}) tuple(%ni, %ar)
}

%cond (p: (s32[], bf16[1024])) -> pred[] {
  %p = (s32[], bf16[1024]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: bf16[1024]) -> bf16[1024] {
  %a = bf16[1024]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], bf16[1024]{0}) tuple(%z, %a)
  %w = (s32[], bf16[1024]{0}) while(%t0), condition=%cond, body=%body
  ROOT %out = bf16[1024]{0} get-tuple-element(%w), index=1
}
"""
    a = HA.analyze(text)
    assert a["coll_all-reduce"] == 10 * 1024 * 2
    assert a["collective_count"] == 10


def test_crafted_known_trip_count_attr():
    """backend_config trip count takes precedence over the condition."""
    text = """
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(99)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    a = HA.analyze(text)
    assert abs(a["flops"] - 4 * 2 * 8 ** 3) < 1e-6


def test_convolution_flops():
    def f(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((1, 8, 8, 3), jnp.float32),
        jax.ShapeDtypeStruct((3, 3, 3, 16), jnp.float32)).compile()
    a = HA.analyze(comp.as_text())
    want = 2 * (1 * 8 * 8 * 16) * (3 * 3 * 3)
    # conv may be rewritten (im2col dot etc.); accept within 2x
    assert a["flops"] >= want * 0.5


def test_roofline_terms():
    from repro.launch.hlo import Roofline
    rl = Roofline(name="x", kind="train", chips=256, hlo_flops=1e18,
                  hlo_bytes=1e16, coll_bytes_per_chip=1e11,
                  model_flops=5e17, samples=256)
    assert abs(rl.t_compute - 1e18 / (256 * 197e12)) < 1e-6
    assert abs(rl.t_memory - 1e16 / (256 * 819e9)) < 1e-6
    assert abs(rl.t_collective - 2.0) < 1e-9
    assert rl.bottleneck == "memory"
    assert 0 < rl.mfu_bound < 1
    assert rl.useful_flops_frac == 0.5
