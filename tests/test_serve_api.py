"""Transport-agnostic serving API (`runtime/serve_api.py`): the shared
submit-side validation (byte-identical errors across every admission
surface), the RequestQueue revocation/copy semantics the fleet router and
live migration ride, the ReplicaHandle protocol both schedulers implement,
the unified `build()` construction matrix (+ the deprecation shims the old
`serve_loop.build_*` factories became), and the versioned ServeStats
schema freeze."""
import json
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.runtime import serve_api
from repro.runtime import serve_loop as SL
from repro.runtime.scheduler import (ContinuousScheduler, LogicalClock,
                                     Request, ServeStats, SyncScheduler)
from repro.runtime.serve_api import (ReplicaHandle, RequestQueue, build,
                                     validate_request)
from test_scheduler import _TOY_S, toy_decode_fns

_REPO_ROOT = Path(__file__).resolve().parents[1]


def _req(sid, n_tokens=2, arrival=0.0, prompt_len=_TOY_S):
    return Request(sample_id=sid,
                   prompt=np.full((prompt_len,), sid, np.int32),
                   n_tokens=n_tokens, arrival_time=arrival)


class _StubServer:
    """Just enough server for SyncScheduler's submit-side surface (the
    generate path never runs in these tests)."""

    def __init__(self):
        self.stats = ServeStats()


# ---------------------------------------------------------------------------
# one validation definition, byte-identical errors on every surface
# ---------------------------------------------------------------------------

def _submit_error(surface, req) -> str:
    with pytest.raises(ValueError) as ei:
        surface(req)
    return str(ei.value)


def test_validate_request_messages():
    assert _submit_error(validate_request, _req(0, n_tokens=0)) \
        == "n_tokens must be >= 1, got 0"
    msg = _submit_error(
        lambda r: validate_request(r, max_len=5), _req(7, n_tokens=9))
    assert msg == f"request 7: S + n_tokens = {_TOY_S + 9} exceeds pool " \
                  f"max_len 5"
    assert _submit_error(
        lambda r: validate_request(r, is_dup=lambda sid: True), _req(3)) \
        == "duplicate sample id 3"


def test_submit_errors_identical_across_surfaces():
    """The same malformed request produces the same error string whether
    it hits a bare RequestQueue, the continuous scheduler, the sync
    scheduler, or the fleet router — the single-definition contract."""
    from repro.runtime.router import FleetRouter
    max_len = _TOY_S + 4
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)

    def surfaces():
        clock = LogicalClock()
        cont = ContinuousScheduler(toy_decode_fns(50), sc, n_slots=2,
                                   max_len=max_len, clock=clock)
        return {
            "queue": RequestQueue(max_len=max_len),
            "continuous": cont,
            "sync": SyncScheduler(_StubServer(), n_slots=2,
                                  clock=LogicalClock(), max_len=max_len),
            # the router is unbounded in max_len (replicas own pool
            # geometry) so it only joins the n_tokens/duplicate cases
            "router": FleetRouter([cont]),
        }

    def errs(req, *, skip=()):
        out = {}
        for name, s in surfaces().items():
            if name in skip:
                continue
            fn = s.append if isinstance(s, RequestQueue) else s.submit
            out[name] = _submit_error(fn, req)
        return out

    got = errs(_req(0, n_tokens=0))
    assert len(set(got.values())) == 1, got
    got = errs(_req(1, n_tokens=99), skip=("router",))
    assert len(set(got.values())) == 1, got
    # duplicates: submit once, then again
    for name, s in surfaces().items():
        fn = s.append if isinstance(s, RequestQueue) else s.submit
        fn(_req(5))
        assert _submit_error(fn, _req(5)) == "duplicate sample id 5", name


def test_sync_scheduler_rejects_like_continuous(tiny_cfg, tiny_params,
                                                tiny_spec):
    """The sync policy validates at submit() too (it historically did
    not) — same errors as the continuous path, via the shared queue."""
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.9)
    sched = build(tiny_params, tiny_cfg, tiny_spec, sc, scheduler="sync",
                  n_slots=2, max_len=10, clock=LogicalClock())
    with pytest.raises(ValueError, match="exceeds pool max_len"):
        sched.submit(_req(0, n_tokens=99, prompt_len=8))
    with pytest.raises(ValueError, match="n_tokens must be >= 1"):
        sched.submit(_req(1, n_tokens=0, prompt_len=8))
    sched.submit(_req(2, n_tokens=2, prompt_len=8))
    with pytest.raises(ValueError, match="duplicate sample id"):
        sched.submit(_req(2, n_tokens=2, prompt_len=8))


# ---------------------------------------------------------------------------
# RequestQueue semantics: FIFO, head-gated arrival, revocation, snapshot
# ---------------------------------------------------------------------------

def test_request_queue_fifo_and_inspection():
    q = RequestQueue()
    for sid, t in [(3, 1.0), (1, 2.0), (2, 0.5)]:
        q.append(_req(sid, arrival=t))
    assert len(q) == 3 and bool(q)
    assert [r.sample_id for r in q] == [3, 1, 2]      # arrival order kept
    assert q.next_arrival() == 1.0                    # HEAD gates admission
    assert 3 in q and 9 not in q
    assert q.popleft().sample_id == 3
    assert 3 not in q                                 # pop = admission
    assert q.next_arrival() == 2.0
    q.append(_req(3))                                 # popped sid re-usable
    assert RequestQueue().next_arrival() is None


def test_request_queue_revoke_unadmitted_only():
    q = RequestQueue()
    for sid in range(5):
        q.append(_req(sid, arrival=float(sid)))
    admitted = q.popleft()                            # sid 0 is in flight
    taken = q.revoke([1, 3, 0, 99])                   # 0/99 aren't queued
    assert [r.sample_id for r in taken] == [1, 3]
    assert [r.sample_id for r in q] == [2, 4]         # survivor order kept
    assert admitted.sample_id == 0
    # revoked sids are re-appendable (re-queue on another replica)
    q.append(taken[0])
    assert [r.sample_id for r in q.revoke(None)] == [2, 4, 1]
    assert len(q) == 0


def test_request_queue_copy_is_independent():
    q = RequestQueue(max_len=20)
    q.append(_req(0))
    q.append(_req(1))
    import copy
    snap = copy.copy(q)
    q.popleft()
    q.append(_req(2))
    assert [r.sample_id for r in snap] == [0, 1]      # snapshot unperturbed
    assert [r.sample_id for r in q] == [1, 2]
    with pytest.raises(ValueError, match="duplicate sample id"):
        snap.append(_req(1))                          # membership copied too


# ---------------------------------------------------------------------------
# ReplicaHandle: both schedulers implement the routable surface
# ---------------------------------------------------------------------------

def test_schedulers_implement_replica_handle():
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    cont = ContinuousScheduler(toy_decode_fns(50), sc, n_slots=2,
                               max_len=_TOY_S + 4, clock=LogicalClock())
    sync = SyncScheduler(_StubServer(), n_slots=2, clock=LogicalClock())
    for s in (cont, sync):
        assert isinstance(s, ReplicaHandle)
        assert s.n_busy == 0 and s.queue_len == 0
        assert s.next_arrival() is None
        assert s.drain_finished() == []
    assert not isinstance(object(), ReplicaHandle)


def test_continuous_finish_feed_per_request():
    """drain_finished hands (sid, n_hard, n_decisions) per finished
    request — the per-request hardness the router's tenant estimates
    fold. All-hard toy traffic: n_hard == n_decisions == n_tokens - 1."""
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    sched = ContinuousScheduler(toy_decode_fns(100), sc, n_slots=2,
                                max_len=_TOY_S + 6, clock=LogicalClock())
    for sid, n in [(0, 4), (1, 2)]:
        sched.submit(_req(sid, n_tokens=n))
    sched.run()
    feed = sorted(sched.drain_finished())
    assert [(s, h, d) for s, h, d in feed] == [(0, 3, 3), (1, 1, 1)]
    assert sched.drain_finished() == []               # pop semantics


# ---------------------------------------------------------------------------
# build(): the one construction path, and the shims over it
# ---------------------------------------------------------------------------

def test_build_matrix_types(tiny_cfg, tiny_params, tiny_spec):
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    b = lambda **kw: build(tiny_params, tiny_cfg, tiny_spec, sc, **kw)
    assert isinstance(b(mode="prefill", scheduler=None), SL.TwoStageServer)
    assert isinstance(b(mode="prefill", scheduler=None, host=True),
                      SL.HostLoopServer)
    assert isinstance(b(scheduler=None), SL.DecodeServer)
    assert isinstance(b(scheduler=None, host=True), SL.HostLoopDecoder)
    assert isinstance(b(scheduler="sync", n_slots=2), SyncScheduler)
    cont = b(scheduler="continuous", n_slots=2, max_len=12,
             clock=LogicalClock())
    assert isinstance(cont, ContinuousScheduler)
    assert cont.fns_factory is not None               # migration rebuilds


def test_build_rejects_bad_points(tiny_cfg, tiny_params, tiny_spec):
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    b = lambda **kw: build(tiny_params, tiny_cfg, tiny_spec, sc, **kw)
    with pytest.raises(ValueError, match="mode must be one of"):
        b(mode="train")
    with pytest.raises(ValueError, match="scheduler must be one of"):
        b(scheduler="fifo")
    with pytest.raises(ValueError, match="no scheduling policy"):
        b(mode="prefill", scheduler="continuous")
    with pytest.raises(ValueError, match="needs n_slots"):
        b(scheduler="sync")
    with pytest.raises(ValueError, match="needs max_len"):
        b(scheduler="continuous", n_slots=2)
    with pytest.raises(ValueError, match="baseline-oracle knob"):
        b(scheduler="sync", n_slots=2, host=True)


def test_deprecated_factories_warn_once_and_build(tiny_cfg, tiny_params,
                                                  tiny_spec):
    sc = SL.ServeConfig(capacity=2, queue_depth=2, c_thr=0.5)
    serve_api._WARNED.discard("build_host_decoder")
    with pytest.warns(DeprecationWarning, match="serve_api.build"):
        dec = SL.build_host_decoder(tiny_params, tiny_cfg, tiny_spec, sc)
    assert isinstance(dec, SL.HostLoopDecoder)
    with warnings.catch_warnings():
        warnings.simplefilter("error")                # second call: silent
        SL.build_host_decoder(tiny_params, tiny_cfg, tiny_spec, sc)
    serve_api._WARNED.discard("build_continuous_scheduler")
    with pytest.warns(DeprecationWarning):
        sched = SL.build_continuous_scheduler(
            tiny_params, tiny_cfg, tiny_spec, sc, n_slots=2, max_len=12,
            clock=LogicalClock())
    assert isinstance(sched, ContinuousScheduler)


# ---------------------------------------------------------------------------
# ServeStats: the versioned, frozen schema
# ---------------------------------------------------------------------------

_SERVE_STATS_V3_KEYS = frozenset({
    "schema_version", "n_samples", "n_decisions", "n_exited", "n_stage2",
    "n_stalls", "realized_q", "decisions_per_sample", "mean_bucket_fill",
    "stage1_chips", "stage2_chips", "stage1_occupancy", "stage2_occupancy",
    "n_finished", "latency_p50", "latency_p90", "latency_p99",
    "provisioned_p", "realized_q_ewma", "q_drift", "n_migrations",
    "n_migration_rollbacks", "migration_pause_p50_ms",
    "migration_pause_p99_ms", "cache_pages_total", "cache_pages_in_use",
    "cache_pages_free", "cache_hbm_bytes", "page_fragmentation",
    "ring_bytes_moved", "realized_q_series",
})


def test_serve_stats_schema_frozen():
    """Adding/removing/renaming an as_dict key REQUIRES a schema_version
    bump — this freeze makes that deliberate. (If you changed the schema
    on purpose: bump ServeStats.SCHEMA_VERSION, update this set, and the
    README's serving-stats schema table.)"""
    d = ServeStats().as_dict()
    assert set(d) == _SERVE_STATS_V3_KEYS
    assert d["schema_version"] == ServeStats.SCHEMA_VERSION == 3


# baseline_cpu.json metric leaves that are sourced straight from a
# ServeStats field (vs computed by the benchmark itself) -> the as_dict
# key that must keep existing for the gate to stay meaningful
_STATS_BACKED_LEAVES = {
    "migration_pause_p99_ms": "migration_pause_p99_ms",
    "n_migrations": "n_migrations",
    "n_rollbacks": "n_migration_rollbacks",
    # serve_paged's ring gate is dense/paged ring_bytes_moved
    "ring_bytes_ratio": "ring_bytes_moved",
}


def test_baseline_gated_metrics_exist_in_stats_schema():
    baseline = json.loads(
        (_REPO_ROOT / "benchmarks" / "baseline_cpu.json").read_text())
    d = ServeStats().as_dict()
    hits = 0
    for metric in baseline["metrics"]:
        leaf = metric.rsplit(".", 1)[-1]
        if leaf in _STATS_BACKED_LEAVES:
            hits += 1
            assert _STATS_BACKED_LEAVES[leaf] in d, metric
    assert hits >= 3          # the map must not go dead silently
