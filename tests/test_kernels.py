"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs ref.py
pure-jnp oracles, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (exit_decision_op, flash_attention_op,
                           gather_compact_op)
from repro.kernels.exit_decision.ref import exit_decision_ref
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.gather_compact.ref import gather_compact_ref


# ---------------------------------------------------------------------------
# exit decision kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows", [1, 3, 8])
@pytest.mark.parametrize("vocab", [8, 100, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_exit_decision_shapes_dtypes(rows, vocab, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(rows * vocab), (rows, vocab))
         * 4.0).astype(dtype)
    for c_thr in (0.1, 0.5, 0.9, 0.99):
        ek, pk, ck = exit_decision_op(x, c_thr)
        er, pr, cr = exit_decision_ref(x.reshape(rows, vocab), c_thr)
        np.testing.assert_array_equal(np.asarray(ek), np.asarray(er))
        np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
        np.testing.assert_allclose(np.asarray(ck), np.asarray(cr),
                                   rtol=1e-5, atol=1e-6)


def test_exit_decision_extreme_logits_stable():
    """Raw Eq. (4) overflows exp(x) for big logits; the max-shifted kernel
    must not."""
    x = jnp.array([[500.0, -500.0, 0.0], [90.0, 89.0, 88.0]], jnp.float32)
    e, p, c = exit_decision_op(x, 0.9)
    assert bool(jnp.isfinite(c).all())
    assert int(p[0]) == 0 and bool(e[0])        # one-hot -> confident exit
    assert float(c[0]) > 0.999


def test_exit_decision_uniform_logits_never_exit():
    x = jnp.zeros((4, 10), jnp.float32)
    e, p, c = exit_decision_op(x, 0.5)
    np.testing.assert_allclose(np.asarray(c), 0.1, rtol=1e-5)
    assert not bool(e.any())


def test_exit_decision_leading_dims():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 64), jnp.float32)
    e, p, c = exit_decision_op(x, 0.5)
    assert e.shape == (2, 3) and p.shape == (2, 3) and c.shape == (2, 3)


# ---------------------------------------------------------------------------
# gather-compact (conditional buffer) kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 7, 16, 64])
@pytest.mark.parametrize("feat", [1, 8, 33])
@pytest.mark.parametrize("p_hard", [0.0, 0.3, 1.0])
def test_gather_compact_sweep(batch, feat, p_hard):
    key = jax.random.PRNGKey(batch * feat + 1)
    x = jax.random.normal(key, (batch, feat), jnp.float32)
    mask = jax.random.bernoulli(jax.random.fold_in(key, 1), p_hard, (batch,))
    for capacity in {max(1, batch // 2), batch}:
        sk, ik, nk = gather_compact_op(x, mask, capacity)
        sr, ir, nr = gather_compact_ref(x.reshape(batch, -1), mask, capacity)
        np.testing.assert_allclose(np.asarray(sk).reshape(capacity, -1),
                                   np.asarray(sr))
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        assert int(nk) == int(nr) == int(mask.sum())


def test_gather_compact_dtypes():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4)).astype(jnp.bfloat16)
    mask = jnp.array([1, 0, 1, 0, 0, 1, 0, 0], bool)
    s, ids, n = gather_compact_op(x, mask, 4)
    assert s.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(ids), [0, 2, 5, -1])


def test_gather_compact_semantics():
    """Slab rows [0, n_hard) are exactly the hard rows in original order;
    flush slots are id -1."""
    x = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    mask = jnp.array([0, 1, 0, 1, 1, 0], bool)
    s, ids, n = gather_compact_op(x, mask, 6)
    assert int(n) == 3
    np.testing.assert_array_equal(np.asarray(ids), [1, 3, 4, -1, -1, -1])
    np.testing.assert_allclose(np.asarray(s)[:3], np.asarray(x)[[1, 3, 4]])


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seq", [64, 128, 200, 384])
@pytest.mark.parametrize("heads,kv_heads", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_shapes(seq, heads, kv_heads):
    k = jax.random.PRNGKey(seq + heads)
    q = jax.random.normal(k, (2, seq, heads, 32), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, seq, kv_heads, 32),
                           jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, seq, kv_heads, 32),
                          jnp.float32)
    out = flash_attention_op(q, kk, v, causal=True)
    ref = flash_attention_op(q, kk, v, causal=True, use_pallas=False)
    assert out.shape == q.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 256, 2, 32), jnp.float32)
    kv = jax.random.normal(jax.random.fold_in(k, 1), (1, 256, 2, 32),
                           jnp.float32)
    out = flash_attention_op(q, kv, kv, causal=True, window=window)
    ref = flash_attention_op(q, kv, kv, causal=True, window=window,
                             use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    k = jax.random.PRNGKey(7)
    q = jax.random.normal(k, (1, 128, 2, 64)).astype(jnp.bfloat16)
    kv = jax.random.normal(jax.random.fold_in(k, 1), (1, 128, 2, 64)
                           ).astype(jnp.bfloat16)
    out = flash_attention_op(q, kv, kv, causal=True)
    ref = flash_attention_op(q, kv, kv, causal=True, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_vs_naive_softmax():
    """Independent oracle: materialized softmax attention."""
    k = jax.random.PRNGKey(3)
    q = jax.random.normal(k, (1, 128, 2, 16), jnp.float32)
    kv = jax.random.normal(jax.random.fold_in(k, 1), (1, 128, 2, 16),
                           jnp.float32)
    out = flash_attention_op(q, kv, kv, causal=True)
    qt = q.transpose(0, 2, 1, 3)
    kt = kv.transpose(0, 2, 1, 3)
    vt = kv.transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qt, kt) / jnp.sqrt(16.0)
    mask = jnp.tril(jnp.ones((128, 128), bool))
    scores = jnp.where(mask, scores, -jnp.inf)
    naive = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(scores, axis=-1), vt)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(naive.transpose(0, 2, 1, 3)),
                               rtol=2e-3, atol=2e-3)
