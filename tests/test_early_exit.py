"""EarlyExitModel semantics: exit routing, boundary validation, threshold
extremes, capacity overflow behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import early_exit as ee
from repro.core import exit_decision as ed


def test_boundary_validation(tiny_cfg):
    ee.validate_boundary(tiny_cfg, 2)
    with pytest.raises(ValueError):
        ee.validate_boundary(tiny_cfg, 99)
    cfg2 = tiny_cfg.replace(pattern=("attn", "attn"))
    with pytest.raises(ValueError):
        ee.validate_boundary(cfg2, 3)          # not superblock aligned
    ee.validate_boundary(cfg2, 2)


def test_default_exit_layer_alignment():
    from repro.models.registry import get_arch, list_archs
    for a in list_archs():
        cfg = get_arch(a)
        k = cfg.default_exit_layers()[0]
        ee.validate_boundary(cfg, k)
        assert cfg.first_k_dense < k < cfg.n_layers


def test_cthr_extremes_route_everything(tiny_cfg, tiny_params):
    """c_thr<=0 -> every sample exits (logits from stage 1);
    c_thr>=1 -> none exit (logits from stage 2)."""
    B, S = 4, 8
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                                tiny_cfg.vocab)

    spec_all = ee.EarlyExitSpec(exit_layer=2, c_thr=0.0)
    out = ee.serve_batch(tiny_params, tiny_cfg, spec_all, tokens)
    assert bool(out["exit_mask"].all())
    np.testing.assert_allclose(np.asarray(out["logits"]),
                               np.asarray(out["exit_logits"]), rtol=1e-6)

    spec_none = ee.EarlyExitSpec(exit_layer=2, c_thr=1.1)
    out = ee.serve_batch(tiny_params, tiny_cfg, spec_none, tokens,
                         capacity=B)
    assert not bool(out["exit_mask"].any())
    assert int(out["n_hard"]) == B
    # merged logits must come from stage 2, i.e. differ from exit logits
    assert not np.allclose(np.asarray(out["logits"]),
                           np.asarray(out["exit_logits"]))


def test_serve_batch_merge_consistency(tiny_cfg, tiny_params, tiny_spec):
    """Easy rows of the merged output equal the exit logits row-for-row."""
    B, S = 6, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                tiny_cfg.vocab)
    out = ee.serve_batch(tiny_params, tiny_cfg, tiny_spec, tokens,
                         capacity=B)
    mask = np.asarray(out["exit_mask"])
    merged = np.asarray(out["logits"])
    exitl = np.asarray(out["exit_logits"])
    np.testing.assert_allclose(merged[mask], exitl[mask], rtol=1e-6)
    # decision recomputed from logits matches the mask
    re_mask = np.asarray(ed.exit_decision(out["exit_logits"],
                                          tiny_spec.c_thr))
    np.testing.assert_array_equal(mask, re_mask)


def test_capacity_overflow_reports(tiny_cfg, tiny_params):
    """With capacity 1 and no sample exiting, overflow = B - 1."""
    spec = ee.EarlyExitSpec(exit_layer=2, c_thr=1.1)
    B, S = 5, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                tiny_cfg.vocab)
    out = ee.serve_batch(tiny_params, tiny_cfg, spec, tokens, capacity=1)
    assert int(out["overflow"]) == B - 1


def test_exit_head_uses_tied_embedding(tiny_cfg, tiny_params, tiny_spec):
    h = jax.random.normal(jax.random.PRNGKey(3), (2, tiny_cfg.d_model),
                          jnp.float32)
    logits = ee.exit_head(tiny_params, tiny_cfg, h)
    assert logits.shape == (2, tiny_cfg.vocab)
    assert logits.dtype == jnp.float32


def test_two_stage_decode_consistency(tiny_cfg, tiny_params, tiny_spec):
    """stage1_decode + stage2_decode on the full batch equals the unstaged
    decode_step."""
    from repro.models import transformer as T
    B, S = 3, 6
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S + 1), 0,
                                tiny_cfg.vocab)
    _, caches, _ = T.prefill(tiny_params["backbone"], tiny_cfg,
                             tokens[:, :S], max_len=S + 4)
    want, _ = T.decode_step(tiny_params["backbone"], tiny_cfg,
                            tokens[:, S:S + 1],
                            jax.tree.map(lambda x: x, caches), jnp.int32(S))

    c1, c2 = ee.split_caches(tiny_cfg, tiny_spec, caches)
    h, nc1, exit_logits = ee.stage1_decode(tiny_params, tiny_cfg, tiny_spec,
                                           tokens[:, S:S + 1], c1,
                                           jnp.int32(S))
    slab_idx = jnp.arange(B, dtype=jnp.int32)     # all samples "hard"
    final_logits, nc2 = ee.stage2_decode(tiny_params, tiny_cfg, tiny_spec,
                                         jnp.take(h, slab_idx, axis=0), c2,
                                         jnp.int32(S))
    np.testing.assert_allclose(np.asarray(final_logits), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
