"""AdamW with ZeRO-1 moment sharding, schedules, clipping and int8 gradient
compression with error feedback.

Pure-functional (init/update) like optax, but self-contained and
sharding-aware: ``zero1_sharding`` produces moment shardings that spread the
fp32 (m, v) pairs over the ``data`` mesh axis, the standard ZeRO-1 layout —
params/grads stay in their TP layout, optimizer state adds no replicated
fp32 copies.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    compress_grads: bool = False      # int8 + error feedback


class AdamWState(NamedTuple):
    step: jnp.ndarray                 # () int32
    m: Any                            # fp32 pytree
    v: Any                            # fp32 pytree
    err: Any                          # error-feedback residual (or None)


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(cfg: AdamWConfig, params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        err=jax.tree.map(zeros, params) if cfg.compress_grads else None,
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


# -- int8 gradient compression with error feedback ---------------------------

def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    a = jnp.max(jnp.abs(g)).astype(jnp.float32)
    scale = jnp.where(a > 0, a / 127.0, 1.0)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _compress_with_feedback(g, e):
    """g' = Q(g + e); e' = (g + e) - g'. The residual is re-injected next
    step so the quantization error doesn't bias the trajectory."""
    t = g.astype(jnp.float32) + e
    q, s = compress_int8(t)
    d = decompress_int8(q, s)
    return d, t - d


def update(cfg: AdamWConfig, state: AdamWState, params, grads
           ) -> Tuple[Any, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    new_err = state.err
    if cfg.compress_grads:
        pairs = jax.tree.map(_compress_with_feedback, grads, state.err)
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))

    step = state.step + 1
    lr = schedule(cfg, step)
    c1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    m = jax.tree.map(lambda mm, g: cfg.b1 * mm + (1 - cfg.b1) * g,
                     state.m, grads)
    v = jax.tree.map(lambda vv, g: cfg.b2 * vv + (1 - cfg.b2) * g * g,
                     state.v, grads)

    def step_fn(p, mm, vv):
        upd = (mm / c1) / (jnp.sqrt(vv / c2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    new_params = jax.tree.map(step_fn, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v, err=new_err), {
        "grad_norm": gn, "lr": lr}


# -- sharding -----------------------------------------------------------------

def zero1_sharding(mesh, param_specs) -> AdamWState:
    """NamedSharding pytree for AdamWState: moments take the param's spec
    with the FIRST unsharded dimension additionally sharded over 'data'
    (ZeRO-1). Falls back to the param spec when no dim is divisible."""
    data_ax = "data"

    def moment_spec(spec: P) -> P:
        parts = list(spec) if spec else []
        for i, ax in enumerate(parts):
            if ax is None:
                parts[i] = data_ax
                return P(*parts)
        return P(*parts) if parts else P()

    def shard(spec):
        return NamedSharding(mesh, moment_spec(spec))

    m_sh = jax.tree.map(shard, param_specs)
    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=m_sh, v=m_sh,
        err=None,
    )
