"""--arch qwen2-7b (see configs/archs.py for the full definition)."""
from repro.configs.archs import QWEN2_7B as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
