"""--arch mamba2-130m (see configs/archs.py for the full definition)."""
from repro.configs.archs import MAMBA2_130M as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
