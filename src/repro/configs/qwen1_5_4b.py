"""--arch qwen1.5-4b (see configs/archs.py for the full definition)."""
from repro.configs.archs import QWEN1_5_4B as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
