"""--arch qwen3-4b (see configs/archs.py for the full definition)."""
from repro.configs.archs import QWEN3_4B as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
