"""--arch internvl2-2b (see configs/archs.py for the full definition)."""
from repro.configs.archs import INTERNVL2_2B as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
