"""The ten assigned architectures, exact configs from the assignment pool.

Each also lives in its own module (``repro/configs/<id>.py``) exposing CONFIG,
so ``--arch <id>`` resolves via the registry. Reduced smoke variants keep the
structural skeleton (pattern, first_k_dense, remainder, MoE/MLA/SSM blocks)
while shrinking widths so a forward/train step runs on CPU in seconds.
"""
from __future__ import annotations

from repro.models.config import (ArchConfig, MLAConfig, MoEConfig, RGLRUConfig,
                                 SSMConfig)

# --- ssm ---------------------------------------------------------------------
MAMBA2_130M = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24, d_ff=0,
    vocab=50280, pattern=("mamba2",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1, chunk=256),
    tie_embeddings=True, subquadratic=True,
)  # [arXiv:2405.21060]

# --- dense -------------------------------------------------------------------
QWEN2_1_5B = ArchConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151936, qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
)  # [arXiv:2407.10671]

QWEN2_7B = ArchConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, qkv_bias=True, rope_theta=1e6, tie_embeddings=False,
)  # [arXiv:2407.10671]

QWEN1_5_4B = ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab=151936, qkv_bias=True, rope_theta=1e6, tie_embeddings=False,
)  # [hf:Qwen/Qwen1.5 family]

QWEN3_4B = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=9728,
    vocab=151936, qk_norm=True, head_dim=128, rope_theta=1e6,
    tie_embeddings=True,
)  # [hf:Qwen/Qwen3 family — qk_norm, GQA]

# --- moe ---------------------------------------------------------------------
DEEPSEEK_V2_LITE = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400, first_k_dense=1, dense_ff=10944,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    tie_embeddings=False,
)  # [arXiv:2405.04434 — MLA kv_lora=512, shared+routed experts top-6]

GROK_1_314B = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab=131072,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768, n_shared=0),
    tie_embeddings=False,
)  # [hf:xai-org/grok-1 — 8 experts top-2]

# --- audio (enc-dec) -----------------------------------------------------------
SEAMLESS_M4T_MEDIUM = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, encdec=True, n_enc_layers=12, mlp_act="gelu",
    frontend="speech_stub", tie_embeddings=True,
)  # [arXiv:2308.11596 — enc-dec, frontend stubbed]

# --- hybrid -------------------------------------------------------------------
RECURRENTGEMMA_9B = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab=256000, head_dim=256, window=2048,
    pattern=("rglru", "rglru", "lattn"), mlp_act="geglu",
    rglru=RGLRUConfig(lru_width=4096, conv_kernel=4, c=8.0),
    tie_embeddings=True, subquadratic=True,
)  # [arXiv:2402.19427 — RG-LRU + local attn, 1:2 ratio]

# --- vlm ----------------------------------------------------------------------
INTERNVL2_2B = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab=92553, frontend="vit_stub", n_frontend_tokens=256,
    tie_embeddings=True,
)  # [arXiv:2404.16821 — InternViT (stub) + InternLM2 backbone]


ARCHS = {
    c.name: c for c in [
        MAMBA2_130M, QWEN2_1_5B, QWEN2_7B, QWEN1_5_4B, QWEN3_4B,
        DEEPSEEK_V2_LITE, GROK_1_314B, SEAMLESS_M4T_MEDIUM,
        RECURRENTGEMMA_9B, INTERNVL2_2B,
    ]
}


# --- input shapes (assigned set; uniform across LM archs) ----------------------
SHAPES = {
    "train_4k":    {"kind": "train",   "seq_len": 4_096,   "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32_768,  "global_batch": 32},
    "decode_32k":  {"kind": "decode",  "seq_len": 32_768,  "global_batch": 128},
    "long_500k":   {"kind": "decode",  "seq_len": 524_288, "global_batch": 1},
}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped). long_500k only for sub-quadratic archs."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention stack: 500k-cache decode is the "
                       "quadratic-family case the assignment skips")
    return True, ""


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config: small widths, few layers/experts, tiny
    vocab — but the same block pattern, leading-dense and remainder structure
    so the scan/stage machinery is exercised."""
    pl = cfg.pattern_len
    n_layers = cfg.first_k_dense + 2 * pl + min(cfg.n_remainder, pl - 1 if pl > 1 else 0)
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=128,
        head_dim=16 if cfg.head_dim else None,
        window=8 if cfg.window else None,
        n_frontend_tokens=4 if cfg.frontend == "vit_stub" else 0,
        n_enc_layers=2 if cfg.encdec else 0,
        dense_ff=96 if cfg.dense_ff else None,
        dtype="float32", param_dtype="float32",
    )
    if cfg.moe:
        kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                              n_shared=min(cfg.moe.n_shared, 1))
    if cfg.mla:
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16)
    if cfg.ssm:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2, n_groups=1,
                              chunk=8)
    if cfg.rglru:
        kw["rglru"] = RGLRUConfig(lru_width=64, conv_kernel=4, c=8.0)
    return cfg.replace(**kw)
