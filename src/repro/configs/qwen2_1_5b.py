"""--arch qwen2-1.5b (see configs/archs.py for the full definition)."""
from repro.configs.archs import QWEN2_1_5B as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
