"""--arch grok-1-314b (see configs/archs.py for the full definition)."""
from repro.configs.archs import GROK_1_314B as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
