"""--arch deepseek-v2-lite-16b (see configs/archs.py for the full definition)."""
from repro.configs.archs import DEEPSEEK_V2_LITE as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
