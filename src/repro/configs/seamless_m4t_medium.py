"""--arch seamless-m4t-medium (see configs/archs.py for the full definition)."""
from repro.configs.archs import SEAMLESS_M4T_MEDIUM as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
