"""--arch recurrentgemma-9b (see configs/archs.py for the full definition)."""
from repro.configs.archs import RECURRENTGEMMA_9B as CONFIG, smoke_config

SMOKE = smoke_config(CONFIG)
