"""Analytic performance / resource model (the fpgaConvNet model analogue).

Two families:

1. **CNN pipeline model** — faithful to fpgaConvNet's folding model: each
   layer l has workload W_l (MACs/sample); with parallelism P_l (DSP-analogue
   units) its initiation interval is W_l / P_l cycles; a streaming pipeline's
   rate is clock / max_l(W_l / P_l). Resources consumed scale with sum(P_l).
   This generates the discrete TAP fronts the paper's optimizer produces, and
   is what the Table I/IV and Fig. 9 benchmarks use.

2. **TPU LM stage model** — the same three roofline terms the dry-run
   measures (compute / HBM / ICI), evaluated analytically per layer range so
   the DSE can search sharding configs quickly. The dry-run's HLO-derived
   numbers are ground truth; this model is the search heuristic.

Hardware constants (TPU v5e, per assignment): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.tap import DesignPoint, TAPFunction
from repro.models.cnn import CNNConfig, _stage_out_shape
from repro.models.config import ArchConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
HBM_GB = 16.0                # v5e HBM capacity
FPGA_CLOCK = 125e6           # paper's conservative 125 MHz


# ============================================================================
# 1. CNN pipeline (fpgaConvNet folding model)
# ============================================================================

def cnn_stage_workloads(cfg: CNNConfig, stage_idx: int) -> List[float]:
    """MACs per sample for each conv/linear layer of a backbone stage."""
    h, w, c = cfg.in_shape if stage_idx == 0 else _stage_out_shape(cfg, stage_idx)
    loads = []
    st = cfg.stages[stage_idx]
    for cv in st.convs:
        s = cv.get("stride", 1)
        oh, ow = -(-h // s), -(-w // s)
        loads.append(oh * ow * cv["kernel"] ** 2 * c * cv["out"])
        h, w, c = oh, ow, cv["out"]
        if cv.get("pool"):
            h, w = h // cv["pool"], w // cv["pool"]
    if st.flatten:
        feat = h * w * c
        dims = list(st.linear) + (
            [cfg.n_classes] if stage_idx == len(cfg.stages) - 1 else [])
        din = feat
        for dout in dims:
            loads.append(din * dout)
            din = dout
    return loads


def cnn_exit_workloads(cfg: CNNConfig, exit_idx: int) -> List[float]:
    h, w, c = _stage_out_shape(cfg, exit_idx + 1)
    loads = []
    ex = cfg.exits[exit_idx]
    for cv in ex.convs:
        s = cv.get("stride", 1)
        oh, ow = -(-h // s), -(-w // s)
        loads.append(oh * ow * cv["kernel"] ** 2 * c * cv["out"])
        h, w, c = oh, ow, cv["out"]
        if cv.get("pool"):
            h, w = h // cv["pool"], w // cv["pool"]
    din = h * w * c
    for dout in list(ex.linear) + [cfg.n_classes]:
        loads.append(din * dout)
        din = dout
    return loads


def pipeline_rate(workloads: Sequence[float], parallelism: Sequence[int],
                  clock: float = FPGA_CLOCK) -> float:
    """Streaming pipeline throughput (samples/s) = clock / max II."""
    ii = max(w / max(p, 1) for w, p in zip(workloads, parallelism))
    return clock / ii


def optimal_folding(workloads: Sequence[float], budget: int,
                    levels: Optional[Sequence[int]] = None) -> List[int]:
    """Allocate parallelism units to maximize pipeline rate under
    sum(P) <= budget. Water-filling (P_l proportional to W_l) projected onto
    the discrete folding levels fpgaConvNet uses (powers of two)."""
    if levels is None:
        levels = [1 << i for i in range(11)]
    tot = sum(workloads)
    alloc = []
    for wl in workloads:
        ideal = budget * wl / tot
        lv = max(l for l in levels if l <= max(ideal, 1))
        alloc.append(lv)
    # greedily spend leftover budget on the bottleneck layer
    def bump(a):
        while True:
            iis = [w / p for w, p in zip(workloads, a)]
            i = iis.index(max(iis))
            nxt = next((l for l in levels if l > a[i]), None)
            if nxt is None or sum(a) - a[i] + nxt > budget:
                return a
            a[i] = nxt
    return bump(alloc)


def cnn_stage_tap(workloads: Sequence[float], budgets: Sequence[int],
                  name: str = "", clock: float = FPGA_CLOCK,
                  bram_per_unit: float = 0.25) -> TAPFunction:
    """TAP curve for one pipeline stage: for each resource budget, the best
    folding's throughput. Resource axis 0 = MAC units (DSP analogue),
    axis 1 = buffer memory (BRAM analogue, grows with parallelism)."""
    pts = []
    for b in budgets:
        alloc = optimal_folding(workloads, b)
        thr = pipeline_rate(workloads, alloc, clock)
        used = sum(alloc)
        pts.append(DesignPoint(resources=(used, used * bram_per_unit), throughput=thr,
                               meta={"folding": tuple(alloc), "budget": b}))
    return TAPFunction(pts, name=name)


# ============================================================================
# 2. TPU LM stage roofline model
# ============================================================================

@dataclass(frozen=True)
class ShardPlan:
    dp: int                  # data-parallel ways
    tp: int                  # tensor-parallel ways
    fsdp: bool = False       # shard params over dp too
    microbatch: int = 0      # 0 = no microbatching
    seq_shard: bool = False  # sequence (context) parallel for long prefill

    @property
    def chips(self) -> int:
        return self.dp * self.tp


def _layer_param_bytes(cfg: ArchConfig, kind: str, dense_mlp: bool) -> float:
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    by = 2.0  # bf16
    p = 0.0
    if kind in ("attn", "lattn"):
        if cfg.mla:
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            p += d * H * qk + d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
            p += H * m.v_head_dim * d
        else:
            p += d * H * hd + 2 * d * KH * hd + H * hd * d
    elif kind == "mamba2":
        s = cfg.ssm
        di = s.expand * d
        gn = s.n_groups * s.d_state
        p += d * (2 * di + 2 * gn + di // s.head_dim) + di * d
    elif kind == "rglru":
        w = cfg.rglru.lru_width or d
        p += 2 * d * w + 2 * w * w + w * d
    # mlp / moe
    if cfg.moe is not None and not dense_mlp:
        m = cfg.moe
        p += m.n_experts * 3 * d * m.d_ff_expert + d * m.n_experts
        p += m.n_shared * 3 * d * m.d_ff_expert
    elif cfg.d_ff > 0 or dense_mlp:
        ff = cfg.dense_ff if (dense_mlp and cfg.dense_ff) else cfg.d_ff
        n_mats = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        p += n_mats * d * ff
    return p * by


def _layer_flops_per_token(cfg: ArchConfig, kind: str, dense_mlp: bool,
                           ctx_len: float) -> float:
    """Matmul FLOPs per token (fwd). ctx_len = average attended length."""
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    f = 0.0
    if kind in ("attn", "lattn"):
        if cfg.mla:
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            f += 2 * d * H * qk + 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim)
            f += 2 * m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
            f += 2 * H * m.v_head_dim * d
            f += 2 * 2 * H * qk * ctx_len
        else:
            f += 2 * (d * H * hd + 2 * d * KH * hd + H * hd * d)
            f += 2 * 2 * H * hd * ctx_len          # scores + out
    elif kind == "mamba2":
        s = cfg.ssm
        di = s.expand * d
        gn = s.n_groups * s.d_state
        f += 2 * d * (2 * di + 2 * gn + di // s.head_dim) + 2 * di * d
        f += 2 * 2 * di * s.d_state                # state update + output
        f += 2 * 2 * (di // s.head_dim) * s.chunk * s.head_dim  # intra-chunk
    elif kind == "rglru":
        w = cfg.rglru.lru_width or d
        f += 2 * (2 * d * w + 2 * w * w + w * d) + 10 * w
    if cfg.moe is not None and not dense_mlp:
        m = cfg.moe
        f += 2 * 3 * d * m.d_ff_expert * (m.top_k + m.n_shared)
        f += 2 * d * m.n_experts                   # router
    elif cfg.d_ff > 0 or dense_mlp:
        ff = cfg.dense_ff if (dense_mlp and cfg.dense_ff) else cfg.d_ff
        n_mats = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        f += 2 * n_mats * d * ff
    return f


def stage_params_bytes(cfg: ArchConfig, lo: int, hi: int,
                       include_embed: bool = True) -> float:
    tot = 0.0
    for i in range(lo, hi):
        tot += _layer_param_bytes(cfg, cfg.layer_kind(i), i < cfg.first_k_dense)
    if include_embed and lo == 0:
        tot += cfg.vocab * cfg.d_model * 2.0
    if hi == cfg.n_layers and not cfg.tie_embeddings:
        tot += cfg.vocab * cfg.d_model * 2.0
    if cfg.encdec and lo == 0:
        enc_layer = (2 * (cfg.d_model * cfg.n_heads * cfg.resolved_head_dim) +
                     2 * cfg.d_model * cfg.n_kv_heads * cfg.resolved_head_dim +
                     2 * cfg.d_model * cfg.d_ff) * 2.0
        tot += cfg.n_enc_layers * enc_layer
        # decoder cross-attention adds another attention block per layer
        tot += (hi - lo) * _layer_param_bytes(cfg, "attn", False) * 0.5
    return tot


def stage_flops_per_sample(cfg: ArchConfig, lo: int, hi: int, *, kind: str,
                           seq_len: int) -> float:
    """Forward matmul FLOPs per sample for layers [lo, hi).
    kind: train|prefill -> seq_len tokens, causal avg ctx seq_len/2;
          decode -> 1 token, ctx = seq_len."""
    if kind == "decode":
        n_tok, ctx = 1.0, float(seq_len)
    else:
        n_tok, ctx = float(seq_len), seq_len / 2.0
    f = 0.0
    for i in range(lo, hi):
        k = cfg.layer_kind(i)
        c = ctx if k != "lattn" else min(ctx, (cfg.window or ctx))
        if k in ("mamba2", "rglru"):
            c = 0.0
        f += n_tok * _layer_flops_per_token(cfg, k, i < cfg.first_k_dense, c)
    if lo == 0:
        if cfg.encdec:
            enc_tok = min(max(seq_len // 4, 256), 4096)
            enc_f = (2 * 4 * cfg.d_model * cfg.n_heads * cfg.resolved_head_dim +
                     2 * 2 * cfg.d_model * cfg.d_ff +
                     2 * 2 * cfg.n_heads * cfg.resolved_head_dim * enc_tok / 2)
            f += cfg.n_enc_layers * enc_tok * enc_f
    if hi == cfg.n_layers:
        f += n_tok * 2 * cfg.d_model * cfg.vocab          # unembed
    if kind == "train":
        f *= 3.0                                           # bwd ~ 2x fwd
    return f


def stage_roofline(cfg: ArchConfig, lo: int, hi: int, *, kind: str,
                   seq_len: int, batch: int, plan: ShardPlan) -> Dict[str, float]:
    """Three roofline terms (seconds per global batch) + feasibility."""
    n = plan.chips
    fl = stage_flops_per_sample(cfg, lo, hi, kind=kind, seq_len=seq_len) * batch
    pb = stage_params_bytes(cfg, lo, hi)

    # --- compute term ---
    t_comp = fl / (n * PEAK_FLOPS)

    # --- memory term: weights stream once per step + activation traffic ---
    n_tok = batch * (seq_len if kind != "decode" else 1)
    act_bytes = n_tok * cfg.d_model * 2.0 * (hi - lo) * 6      # rough per-layer io
    w_bytes = pb / plan.tp / (plan.dp if plan.fsdp else 1)
    if kind == "train":
        w_traffic = (pb / plan.tp) * 4                         # grads + opt rw
    else:
        w_traffic = pb / plan.tp
    cache_bytes = 0.0
    if kind == "decode":
        cache_bytes = _decode_cache_bytes(cfg, lo, hi, seq_len, batch)
    t_mem = (w_traffic + act_bytes / n + cache_bytes / n) / HBM_BW

    # --- collective term ---
    coll = 0.0
    n_attn = sum(1 for i in range(lo, hi) if cfg.layer_kind(i) in ("attn", "lattn"))
    n_layers = hi - lo
    if plan.tp > 1:
        # 2 all-reduces of (tokens, d) per layer (Megatron-style)
        per_ar = 2.0 * (plan.tp - 1) / plan.tp * n_tok / plan.dp * cfg.d_model * 2.0
        coll += 2 * n_layers * per_ar
    if cfg.moe is not None:
        # all-to-all dispatch+combine of (tokens*topk, d), within dp group
        a2a = 2.0 * n_tok / plan.dp * cfg.moe.top_k * cfg.d_model * 2.0
        coll += n_layers * a2a
    if kind == "train" and plan.dp > 1:
        coll += 2.0 * (plan.dp - 1) / plan.dp * pb / plan.tp   # grad all-reduce
    if plan.fsdp:
        coll += (plan.dp - 1) / plan.dp * pb / plan.tp          # param all-gather
    t_ici = coll / ICI_BW
    del n_attn

    # --- HBM feasibility ---
    opt_bytes = 0.0
    if kind == "train":
        opt_bytes = (pb / 2.0) * 8 / plan.tp / (plan.dp if plan.fsdp else plan.dp)
        # fp32 m+v sharded over all chips (ZeRO-1)
    live_act = n_tok / plan.dp * cfg.d_model * 2.0 * (4 if kind == "train" else 2)
    hbm_need = (w_bytes + opt_bytes + live_act + cache_bytes / n) / 1e9
    t_total = max(t_comp, t_mem, t_ici)
    return {
        "t_compute": t_comp, "t_memory": t_mem, "t_ici": t_ici,
        "t_total": t_total,
        "throughput": batch / t_total if t_total > 0 else float("inf"),
        "hbm_gb_per_chip": hbm_need,
        "feasible": hbm_need <= HBM_GB * 0.92,
        "flops": fl, "param_bytes": pb, "coll_bytes": coll,
    }


def _decode_cache_bytes(cfg: ArchConfig, lo: int, hi: int, seq_len: int,
                        batch: int) -> float:
    by = 0.0
    for i in range(lo, hi):
        k = cfg.layer_kind(i)
        if k == "attn":
            if cfg.mla:
                by += batch * seq_len * (cfg.mla.kv_lora_rank +
                                         cfg.mla.qk_rope_head_dim) * 2.0
            else:
                by += 2 * batch * seq_len * cfg.n_kv_heads * cfg.resolved_head_dim * 2.0
        elif k == "lattn":
            w = min(cfg.window or seq_len, seq_len)
            by += 2 * batch * w * cfg.n_kv_heads * cfg.resolved_head_dim * 2.0
        elif k == "mamba2":
            s = cfg.ssm
            di = s.expand * cfg.d_model
            by += batch * (di // s.head_dim) * s.head_dim * s.d_state * 4.0
        elif k == "rglru":
            w = cfg.rglru.lru_width or cfg.d_model
            by += batch * w * 4.0
    return by


def lm_stage_tap(cfg: ArchConfig, lo: int, hi: int, *, kind: str, seq_len: int,
                 batch: int, chip_budgets: Sequence[int],
                 name: str = "") -> TAPFunction:
    """TAP curve for a layer range: best (dp, tp) plan per chip budget.
    Resource axes: (chips, hbm_gb_total)."""
    pts = []
    for n in chip_budgets:
        best = None
        tp = 1
        while tp <= n:
            if n % tp == 0:
                for fsdp in (False, True):
                    plan = ShardPlan(dp=n // tp, tp=tp, fsdp=fsdp)
                    r = stage_roofline(cfg, lo, hi, kind=kind, seq_len=seq_len,
                                       batch=batch, plan=plan)
                    if r["feasible"] and (best is None or
                                          r["throughput"] > best[0]["throughput"]):
                        best = (r, plan)
            tp *= 2
        if best:
            r, plan = best
            pts.append(DesignPoint(
                resources=(n, r["hbm_gb_per_chip"] * n),
                throughput=r["throughput"],
                meta={"plan": plan, "roofline": r}))
    return TAPFunction(pts, name=name)
