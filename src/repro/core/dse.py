"""Design Space Exploration — simulated annealing, as in fpgaConvNet/ATHEENA.

The paper's optimizer proposes incremental transformations to hardware blocks
(folding factors), scores them with the resource/performance model, and
anneals. Here the two search spaces are:

- CNN folding vectors (parallelism per pipeline layer) under a MAC-unit
  budget — used for the paper's own networks;
- LM sharding plans (dp/tp/fsdp/microbatch) under a chip budget — used for
  the assigned architectures.

``atheena_optimize`` is the top-level flow of Fig. 5: profile p -> per-stage
TAP (via DSE under scaled budgets) -> Eq. (1) combination -> stage designs.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import perf_model as pm
from repro.core.tap import CombinedDesign, DesignPoint, TAPFunction, combine
from repro.models.cnn import CNNConfig
from repro.models.config import ArchConfig


# ---------------------------------------------------------------------------
# generic simulated annealing
# ---------------------------------------------------------------------------

@dataclass
class SAResult:
    best_state: object
    best_score: float
    trace: List[float]


def simulated_annealing(init_state, score: Callable, neighbour: Callable, *,
                        iters: int = 2000, t0: float = 1.0, t1: float = 1e-3,
                        seed: int = 0) -> SAResult:
    """Maximise score. Standard geometric-cooling SA."""
    rng = random.Random(seed)
    state = init_state
    s = score(state)
    best, best_s = state, s
    trace = [s]
    alpha = (t1 / t0) ** (1.0 / max(iters - 1, 1))
    t = t0
    for _ in range(iters):
        cand = neighbour(state, rng)
        cs = score(cand)
        if cs >= s or rng.random() < math.exp((cs - s) / max(t, 1e-12)):
            state, s = cand, cs
            if s > best_s:
                best, best_s = state, s
        t *= alpha
        trace.append(best_s)
    return SAResult(best_state=best, best_score=best_s, trace=trace)


# ---------------------------------------------------------------------------
# CNN folding DSE
# ---------------------------------------------------------------------------

FOLD_LEVELS = [1 << i for i in range(11)]


def cnn_folding_dse(workloads: Sequence[float], budget: int, *, iters: int = 1500,
                    seed: int = 0) -> Tuple[List[int], float]:
    """SA over per-layer folding levels; score = pipeline rate, infeasible
    (over budget) states scored by soft penalty. Matches the paper's
    'run ten times, keep the best' usage when called with multiple seeds."""
    n = len(workloads)

    def clamp(state):
        return [max(1, min(p, FOLD_LEVELS[-1])) for p in state]

    def score(state):
        used = sum(state)
        thr = pm.pipeline_rate(workloads, state)
        if used > budget:
            return thr * (budget / used) ** 4      # soft penalty
        return thr

    def neighbour(state, rng):
        s = list(state)
        i = rng.randrange(n)
        li = FOLD_LEVELS.index(s[i])
        li = max(0, min(len(FOLD_LEVELS) - 1, li + rng.choice([-1, 1])))
        s[i] = FOLD_LEVELS[li]
        return clamp(s)

    init = pm.optimal_folding(workloads, budget)
    res = simulated_annealing(init, score, neighbour, iters=iters, seed=seed)
    state = res.best_state
    if sum(state) > budget:                        # repair: fold down smallest II slack
        state = pm.optimal_folding(workloads, budget)
    return list(state), pm.pipeline_rate(workloads, state)


def cnn_tap_sa(workloads: Sequence[float], budgets: Sequence[int], *,
               n_seeds: int = 10, name: str = "",
               bram_per_unit: float = 0.25) -> TAPFunction:
    """Paper §IV-A: optimizers run ten times per budget, best points kept."""
    pts = []
    for b in budgets:
        best: Optional[Tuple[List[int], float]] = None
        for s in range(n_seeds):
            alloc, thr = cnn_folding_dse(workloads, b, seed=s)
            if best is None or thr > best[1]:
                best = (alloc, thr)
        alloc, thr = best
        used = sum(alloc)
        pts.append(DesignPoint(resources=(used, used * bram_per_unit),
                               throughput=thr,
                               meta={"folding": tuple(alloc), "budget": b}))
    return TAPFunction(pts, name=name)


# ---------------------------------------------------------------------------
# LM sharding DSE
# ---------------------------------------------------------------------------

def lm_sharding_dse(cfg: ArchConfig, lo: int, hi: int, *, kind: str,
                    seq_len: int, batch: int, chips: int,
                    iters: int = 300, seed: int = 0) -> Optional[Dict]:
    """SA over (tp, fsdp) for a fixed chip count (dp = chips/tp).
    Small space — SA kept for parity with the toolflow; exhaustive check
    confirms optimality in tests."""
    tps = [t for t in [1, 2, 4, 8, 16, 32] if t <= chips and chips % t == 0]

    def mk(tp, fsdp):
        return pm.ShardPlan(dp=chips // tp, tp=tp, fsdp=fsdp)

    def score(state):
        tp, fsdp = state
        r = pm.stage_roofline(cfg, lo, hi, kind=kind, seq_len=seq_len,
                              batch=batch, plan=mk(tp, fsdp))
        return r["throughput"] if r["feasible"] else r["throughput"] * 1e-3

    def neighbour(state, rng):
        tp, fsdp = state
        if rng.random() < 0.5:
            tp = rng.choice(tps)
        else:
            fsdp = not fsdp
        return (tp, fsdp)

    res = simulated_annealing((tps[0], False), score, neighbour,
                              iters=iters, seed=seed)
    tp, fsdp = res.best_state
    plan = mk(tp, fsdp)
    r = pm.stage_roofline(cfg, lo, hi, kind=kind, seq_len=seq_len, batch=batch,
                          plan=plan)
    if not r["feasible"]:
        return None
    return {"plan": plan, "roofline": r}


def lm_stage_tap_sa(cfg: ArchConfig, lo: int, hi: int, *, kind: str,
                    seq_len: int, batch: int, chip_budgets: Sequence[int],
                    name: str = "") -> TAPFunction:
    pts = []
    for n in chip_budgets:
        best = lm_sharding_dse(cfg, lo, hi, kind=kind, seq_len=seq_len,
                               batch=batch, chips=n)
        if best:
            r = best["roofline"]
            pts.append(DesignPoint(resources=(n, r["hbm_gb_per_chip"] * n),
                                   throughput=r["throughput"],
                                   meta=best))
    return TAPFunction(pts, name=name)


# ---------------------------------------------------------------------------
# the ATHEENA optimizer (Fig. 5 flow)
# ---------------------------------------------------------------------------

@dataclass
class AtheenaDesign:
    combined: CombinedDesign
    tap1: TAPFunction
    tap2: TAPFunction
    baseline: TAPFunction
    p: float

    def gain_vs_baseline(self) -> float:
        base = self.baseline.query(self.combined.resources)
        if base is None:
            base = max(self.baseline.points, key=lambda d: d.throughput)
        return self.combined.design_throughput / base.throughput


def atheena_optimize_cnn(cfg: CNNConfig, p: float, budget: int, *,
                         budgets: Optional[Sequence[int]] = None,
                         n_seeds: int = 10) -> AtheenaDesign:
    """Two-stage EE CNN: stage 1 = backbone stage 0 + exit-1 layers (must run
    at full rate), stage 2 = backbone stage 1 (rate scaled by 1/p)."""
    if budgets is None:
        budgets = sorted({max(2, int(budget * f))
                          for f in (0.05, 0.1, 0.15, 0.2, 0.3, 0.4, 0.5,
                                    0.6, 0.7, 0.8, 0.9, 1.0)})
    w1 = pm.cnn_stage_workloads(cfg, 0) + pm.cnn_exit_workloads(cfg, 0)
    w2 = pm.cnn_stage_workloads(cfg, 1)
    wb = pm.cnn_stage_workloads(cfg, 0) + pm.cnn_stage_workloads(cfg, 1)
    tap1 = cnn_tap_sa(w1, budgets, n_seeds=n_seeds, name="stage1")
    tap2 = cnn_tap_sa(w2, budgets, n_seeds=n_seeds, name="stage2")
    base = cnn_tap_sa(wb, budgets, n_seeds=n_seeds, name="baseline")
    comb = combine(tap1, tap2, p, budget=(budget, budget * 0.6))
    if comb is None:
        raise RuntimeError("no feasible combined design within budget")
    return AtheenaDesign(combined=comb, tap1=tap1, tap2=tap2, baseline=base, p=p)


def atheena_optimize_lm(cfg: ArchConfig, exit_layer: int, p: float, *,
                        kind: str, seq_len: int, batch: int, chips: int,
                        chip_budgets: Optional[Sequence[int]] = None
                        ) -> AtheenaDesign:
    """Two-stage EE LM serving design over a chip budget."""
    if chip_budgets is None:
        chip_budgets = [c for c in (4, 8, 16, 32, 48, 64, 96, 128, 192, 224, 256)
                        if c <= chips]
    tap1 = lm_stage_tap_sa(cfg, 0, exit_layer, kind=kind, seq_len=seq_len,
                           batch=batch, chip_budgets=chip_budgets, name="stage1")
    tap2 = lm_stage_tap_sa(cfg, exit_layer, cfg.n_layers, kind=kind,
                           seq_len=seq_len, batch=batch,
                           chip_budgets=chip_budgets, name="stage2")
    base = lm_stage_tap_sa(cfg, 0, cfg.n_layers, kind=kind, seq_len=seq_len,
                           batch=batch, chip_budgets=chip_budgets, name="baseline")
    comb = combine(tap1, tap2, p, budget=(chips, chips * pm.HBM_GB))
    if comb is None:
        raise RuntimeError("no feasible combined design within chip budget")
    return AtheenaDesign(combined=comb, tap1=tap1, tap2=tap2, baseline=base, p=p)
