"""Throughput-Area Pareto (TAP) functions and the combination operator ⊕.

Paper §III-A. A TAP function f maps a resource budget to the best achievable
throughput for one network stage, and is (non-strictly) monotonically
increasing in each resource argument. Stage TAPs are merged by Eq. (1):

    (f ⊕_{p,q} g)(x) = min(f(x1), g(x2)/q)
    where (x1, x2) = argmax_{x1+x2 <= x} min(f(x1), g(x2)/p)

p: design-time probability a sample is "hard" (needs stage 2);
q: probability actually encountered at run time.

On TPU the resource vector is (chips, hbm_gb) — chips are the DSP/LUT
analogue (compute+bandwidth scale with them), HBM feasibility is the BRAM
analogue. The implementation is resource-vector-generic.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class DesignPoint:
    resources: Tuple[float, ...]     # e.g. (chips,) or (chips, hbm_gb)
    throughput: float                # samples/s
    meta: dict = field(default_factory=dict, compare=False, hash=False)

    def fits(self, budget: Sequence[float]) -> bool:
        return all(r <= b + 1e-9 for r, b in zip(self.resources, budget))


class TAPFunction:
    """A discrete TAP function: the Pareto set of feasible design points."""

    def __init__(self, points: Sequence[DesignPoint], name: str = ""):
        self.name = name
        self.points = self._pareto(list(points))

    @staticmethod
    def _pareto(points: List[DesignPoint]) -> List[DesignPoint]:
        kept = []
        for p in sorted(points, key=lambda d: (d.throughput, *(-r for r in d.resources)),
                        reverse=True):
            if not any(k.throughput >= p.throughput - 1e-12 and
                       all(kr <= pr + 1e-9 for kr, pr in zip(k.resources, p.resources))
                       and k is not p for k in kept):
                kept.append(p)
        return sorted(kept, key=lambda d: d.resources)

    def __call__(self, budget: Sequence[float]) -> float:
        best = self.query(budget)
        return best.throughput if best else 0.0

    def query(self, budget: Sequence[float]) -> Optional[DesignPoint]:
        feas = [p for p in self.points if p.fits(budget)]
        return max(feas, key=lambda p: p.throughput) if feas else None

    def is_monotone(self) -> bool:
        """Check the defining property on the stored points."""
        for a in self.points:
            for b in self.points:
                if all(ar <= br for ar, br in zip(a.resources, b.resources)):
                    if self(a.resources) > self(b.resources) + 1e-9:
                        return False
        return True


@dataclass(frozen=True)
class CombinedDesign:
    """The result of f ⊕_{p,q} g at a fixed total budget."""
    stage1: DesignPoint
    stage2: DesignPoint
    p: float
    design_throughput: float          # min(f(x1), g(x2)/p)

    def throughput_at(self, q: float) -> float:
        """Runtime throughput under encountered hard-probability q (Eq. 1
        outer min). q <= p can exceed the design point up to the stage-1
        bound — the paper's Fig. 4 upper shaded region."""
        if q <= 0:
            return self.stage1.throughput
        return min(self.stage1.throughput, self.stage2.throughput / q)

    @property
    def resources(self) -> Tuple[float, ...]:
        return tuple(a + b for a, b in
                     zip(self.stage1.resources, self.stage2.resources))


def combine(f: TAPFunction, g: TAPFunction, p: float,
            budget: Sequence[float]) -> Optional[CombinedDesign]:
    """Eq. (1): pick (x1, x2), x1 + x2 <= budget, maximising
    min(f(x1), g(x2)/p). Enumerates the (small) Pareto sets directly —
    exactly the argmax in the paper, no heuristics."""
    assert 0.0 < p <= 1.0
    best: Optional[CombinedDesign] = None
    for a, b in itertools.product(f.points, g.points):
        tot = [ar + br for ar, br in zip(a.resources, b.resources)]
        if any(t > bb + 1e-9 for t, bb in zip(tot, budget)):
            continue
        d = min(a.throughput, b.throughput / p)
        if best is None or d > best.design_throughput:
            best = CombinedDesign(stage1=a, stage2=b, p=p, design_throughput=d)
    return best


def combine_multistage(taps: Sequence[TAPFunction], survival: Sequence[float],
                       budget: Sequence[float]) -> Optional[dict]:
    """N-stage generalization ('trivial to extend' — paper §III-A): stage i
    sees a fraction survival[i] of input samples (survival[0] == 1).
    Maximise min_i f_i(x_i) / survival[i] subject to sum x_i <= budget.
    Exhaustive over Pareto-set products (fine for <= 4 stages)."""
    assert len(taps) == len(survival) and abs(survival[0] - 1.0) < 1e-9
    best = None
    for combo in itertools.product(*[t.points for t in taps]):
        tot = [sum(c.resources[i] for c in combo)
               for i in range(len(budget))]
        if any(t > b + 1e-9 for t, b in zip(tot, budget)):
            continue
        thr = min(c.throughput / s for c, s in zip(combo, survival))
        if best is None or thr > best["design_throughput"]:
            best = {"stages": combo, "design_throughput": thr,
                    "survival": tuple(survival)}
    return best


def robustness_band(design: CombinedDesign, qs: Sequence[float]) -> Dict[float, float]:
    """Fig. 4 / Fig. 9 sweep: runtime throughput for each encountered q."""
    return {q: design.throughput_at(q) for q in qs}


def iso_throughput_resources(f_comb: TAPFunction, baseline: TAPFunction,
                             ) -> Optional[Tuple[float, float, float]]:
    """Paper's '46% of resources at matched throughput' metric: find the
    smallest combined-resource budget whose throughput >= the baseline's best,
    and report (combined_res, baseline_res, ratio) on the first resource
    axis (chips)."""
    if not baseline.points or not f_comb.points:
        return None
    target = max(p.throughput for p in baseline.points)
    base_res = min(p.resources[0] for p in baseline.points
                   if p.throughput >= target - 1e-9)
    cand = [p.resources[0] for p in f_comb.points if p.throughput >= target - 1e-9]
    if not cand:
        return None
    comb_res = min(cand)
    return comb_res, base_res, comb_res / base_res
