"""EarlyExitModel: stage partitioning + exit heads for LM backbones.

Wraps any registry backbone (models/transformer.py) with depth early exits
(ATHEENA's CDFG form, Fig. 3): stage 1 = embed + layers [0, k) + exit head,
stage 2 = layers [k, N) + final head. The exit head is RMSNorm + tied
unembedding (the LM analogue of BranchyNet's lightweight exit classifier).

The staged entry points mirror the hardware: `stage1_*` produce intermediate
hidden states + exit logits; the exit decision + conditional buffer
(core/conditional.py) filter samples; `stage2_*` finish the hard ones.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import conditional as cond
from repro.kernels import dispatch
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.models.layers import init_rmsnorm, rmsnorm, unembed


@dataclass(frozen=True)
class EarlyExitSpec:
    exit_layer: int            # stage boundary k (superblock-aligned)
    c_thr: float = 0.9         # Eq. (2) confidence threshold
    loss_weights: Tuple[float, float] = (0.3, 1.0)   # (exit, final) — BranchyNet


def default_spec(cfg: ArchConfig, c_thr: float = 0.9) -> EarlyExitSpec:
    return EarlyExitSpec(exit_layer=cfg.default_exit_layers()[0], c_thr=c_thr)


def validate_boundary(cfg: ArchConfig, k: int) -> None:
    base = cfg.first_k_dense
    if not (base <= k <= cfg.n_layers):
        raise ValueError(f"exit layer {k} outside [{base}, {cfg.n_layers}]")
    if (k - base) % cfg.pattern_len != 0:
        raise ValueError(
            f"exit layer {k} must be superblock-aligned (pattern len "
            f"{cfg.pattern_len}, leading dense {base})")


def init_ee_params(key, cfg: ArchConfig, spec: EarlyExitSpec) -> dict:
    validate_boundary(cfg, spec.exit_layer)
    k1, k2 = jax.random.split(key)
    return {
        "backbone": T.init_params(k1, cfg),
        "exit_head": {"norm": init_rmsnorm(cfg.d_model, cfg.p_dtype())},
    }


def ee_param_shapes(cfg: ArchConfig, spec: EarlyExitSpec):
    return jax.eval_shape(lambda k: init_ee_params(k, cfg, spec),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def exit_head(params, cfg: ArchConfig, h):
    """Exit classifier: norm + tied unembed -> fp32 logits."""
    hn = rmsnorm(params["exit_head"]["norm"], h, cfg.norm_eps)
    bb = params["backbone"]
    if cfg.tie_embeddings or "head" not in bb:
        return unembed(bb["embed"], hn)
    return jnp.einsum("...d,dv->...v", hn.astype(jnp.float32),
                      bb["head"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# training: all exits computed for every sample (joint loss)
# ---------------------------------------------------------------------------

def forward_train(params, cfg: ArchConfig, spec: EarlyExitSpec, tokens, *,
                  frontend_embeds=None):
    """Returns (exit_hidden, final_hidden, aux): hidden states before each
    head so the loss can chunk the unembedding over sequence."""
    bb = params["backbone"]
    memory = None
    if cfg.encdec:
        memory = T.encode(bb, cfg, frontend_embeds)
        frontend_embeds = None
    h = T.embed_tokens(bb, cfg, tokens, frontend_embeds)
    h, _, aux1 = T.run_layers(bb, cfg, h, 0, spec.exit_layer, mode="train",
                              memory=memory)
    exit_hidden = rmsnorm(params["exit_head"]["norm"], h, cfg.norm_eps)
    h, _, aux2 = T.run_layers(bb, cfg, h, spec.exit_layer, cfg.n_layers,
                              mode="train", memory=memory)
    final_hidden = rmsnorm(bb["final_norm"], h, cfg.norm_eps)
    return exit_hidden, final_hidden, aux1 + aux2


# ---------------------------------------------------------------------------
# serving: staged execution (the hardware mapping)
# ---------------------------------------------------------------------------

def stage1_prefill(params, cfg: ArchConfig, spec: EarlyExitSpec, tokens, *,
                   frontend_embeds=None):
    """Stage 1: embed + layers [0,k) + exit head on the last position.
    Returns (hidden (B,S,d), caches_seg1, exit_logits (B,V), memory)."""
    bb = params["backbone"]
    memory = None
    if cfg.encdec:
        memory = T.encode(bb, cfg, frontend_embeds)
        frontend_embeds = None
    h = T.embed_tokens(bb, cfg, tokens, frontend_embeds)
    h, caches, _ = T.run_layers(bb, cfg, h, 0, spec.exit_layer, mode="prefill",
                                memory=memory)
    logits = exit_head(params, cfg, h[:, -1])
    return h, caches, logits, memory


def _stage2_base_sb(cfg: ArchConfig, spec: EarlyExitSpec) -> int:
    return (spec.exit_layer - cfg.first_k_dense) // cfg.pattern_len


def stage2_prefill(params, cfg: ArchConfig, spec: EarlyExitSpec, h, *,
                   memory=None, presliced_params: bool = False):
    """Stage 2: layers [k,N) + final head on hard samples only.
    h: (C, S, d) compacted slab. Returns (logits (C,V), caches_seg2).
    ``presliced_params``: params is a stage-2 slice (ee.split_params), whose
    'blocks' leaves start at the exit boundary."""
    bb = params["backbone"]
    base = _stage2_base_sb(cfg, spec) if presliced_params else 0
    h, caches, _ = T.run_layers(bb, cfg, h, spec.exit_layer, cfg.n_layers,
                                mode="prefill", memory=memory,
                                param_base_sb=base)
    return T.head(bb, cfg, h[:, -1]), caches


def stage1_decode(params, cfg: ArchConfig, spec: EarlyExitSpec, token, caches,
                  step):
    """One-token stage 1. Returns (hidden (B,1,d), new_caches, exit_logits)."""
    bb = params["backbone"]
    h = T.embed_tokens(bb, cfg, token)
    h, ncaches, _ = T.run_layers(bb, cfg, h, 0, spec.exit_layer, mode="decode",
                                 caches=caches, step=step)
    return h, ncaches, exit_head(params, cfg, h[:, 0])


def stage2_decode(params, cfg: ArchConfig, spec: EarlyExitSpec, h, caches,
                  step, *, presliced: bool = True,
                  presliced_params: bool = False):
    """One-token stage 2 on the compacted hard slab. ``caches`` is the
    stage-2 SEGMENT cache (ee.split_caches) by default — its bucket batch
    size differs from stage 1's, so the pytrees cannot be shared.
    ``presliced_params`` marks a stage-2 param slice (ee.split_params)."""
    bb = params["backbone"]
    base = ((spec.exit_layer - cfg.first_k_dense) // cfg.pattern_len
            if presliced else 0)
    pbase = _stage2_base_sb(cfg, spec) if presliced_params else 0
    h, ncaches, _ = T.run_layers(bb, cfg, h, spec.exit_layer, cfg.n_layers,
                                 mode="decode", caches=caches, step=step,
                                 cache_base_sb=base, param_base_sb=pbase)
    return T.head(bb, cfg, h[:, 0]), ncaches


def _slice0(x, lo: int, hi: Optional[int]):
    """Slice axis 0 of an array OR a ShapeDtypeStruct (dry-run shapes)."""
    if isinstance(x, jax.ShapeDtypeStruct):
        n = x.shape[0]
        stop = n if hi is None else hi
        return jax.ShapeDtypeStruct((max(stop - lo, 0),) + x.shape[1:],
                                    x.dtype)
    return x[lo:] if hi is None else x[lo:hi]


def split_caches(cfg: ArchConfig, spec: EarlyExitSpec, caches):
    """Slice a full-depth cache pytree into (stage1, stage2) segments,
    mirroring run_layers' superblock slicing. Works on arrays and on
    ShapeDtypeStruct stand-ins (the dry-run path)."""
    pl = cfg.pattern_len
    k_super = (spec.exit_layer - cfg.first_k_dense) // pl
    s1 = {
        "first": caches["first"],
        "blocks": jax.tree.map(lambda x: _slice0(x, 0, k_super),
                               caches["blocks"]),
        "rem": [],
    }
    s2 = {
        "first": [],
        "blocks": jax.tree.map(lambda x: _slice0(x, k_super, None),
                               caches["blocks"]),
        "rem": caches["rem"],
    }
    return s1, s2


def split_params(cfg: ArchConfig, spec: EarlyExitSpec, params):
    """Slice the EE param tree into (stage1, stage2) resident sets — the
    multi-accelerator analogue of ATHEENA's per-stage floorplan regions,
    consumed by the StageExecutors (runtime/stage_executor.py) so each
    stage's submesh holds only its own layers.

    stage 1: embed + leading dense + superblocks [0, k_super) + exit head
             (+ the unembedding the exit head reads — the tied table or the
             untied 'head' matrix);
    stage 2: superblocks [k_super, N) + remainder + final norm + its
             unembedding. The unembedding both heads read is the one
             tensor resident on BOTH submeshes (the tied table, or the
             untied 'head' matrix — in which case the embed table stays on
             stage 1 only); everything else lives on exactly one.

    Slicing the stacked superblock leaves COPIES them (jnp slices are new
    buffers), so only split when there are disjoint submeshes to place the
    slices on — the degenerate single-device builders close over the full
    tree instead. Stage-2 'blocks' leaves start at the exit boundary —
    pass ``presliced_params=True`` to the stage-2 entry points (they
    forward ``param_base_sb`` to run_layers)."""
    bb = params["backbone"]
    k_super = _stage2_base_sb(cfg, spec)
    # the unembedding: T.head and exit_head read the tied table, or the
    # separate 'head' matrix when untied (same fallback condition as both)
    shared = {}
    if cfg.tie_embeddings or "head" not in bb:
        shared["embed"] = bb["embed"]
    else:
        shared["head"] = bb["head"]
    bb1 = dict(shared)
    bb1["embed"] = bb["embed"]               # embed_tokens is stage 1's
    bb1["first"] = bb["first"]
    bb1["blocks"] = jax.tree.map(lambda x: _slice0(x, 0, k_super),
                                 bb["blocks"])
    bb1["rem"] = []
    if "encoder" in bb:                      # enc-dec: memory is stage 1's
        bb1["encoder"] = bb["encoder"]
    bb2 = dict(shared)
    bb2["first"] = []
    bb2["blocks"] = jax.tree.map(lambda x: _slice0(x, k_super, None),
                                 bb["blocks"])
    bb2["rem"] = bb["rem"]
    bb2["final_norm"] = bb["final_norm"]
    return ({"backbone": bb1, "exit_head": params["exit_head"]},
            {"backbone": bb2})


# ---------------------------------------------------------------------------
# one-shot batched EE inference (classification-style; used by the profiler
# and the CPU-measurable throughput benchmark)
# ---------------------------------------------------------------------------

def serve_batch(params, cfg: ArchConfig, spec: EarlyExitSpec, tokens, *,
                capacity: Optional[int] = None, frontend_embeds=None):
    """Full EE pipeline on one batch (prefill-style): stage 1 for all, exit
    decision, conditional buffer compaction, stage 2 for the hard slab, exit
    merge by sample id. Returns dict with merged last-token logits, the exit
    mask, and occupancy stats.

    The decision + compaction route through the kernel dispatch layer
    (``kernels.dispatch``): the fused Pallas kernels on TPU, their jnp
    oracles under XLA on CPU — never a per-sample host loop and never a
    materialized (B, V) softmax."""
    B = tokens.shape[0]
    sample_ids = jnp.arange(B, dtype=jnp.int32)
    h, _, exit_logits, memory = stage1_prefill(params, cfg, spec, tokens,
                                               frontend_embeds=frontend_embeds)
    exit_mask, pred, conf = dispatch.exit_decision_op(exit_logits, spec.c_thr)
    hard_mask = ~exit_mask
    cap = capacity if capacity is not None else B
    slab, slab_ids, n_hard = dispatch.gather_compact_op(h, hard_mask, cap)
    overflow = jnp.maximum(n_hard - cap, 0)
    mem_slab = None
    if memory is not None:
        # reuse the hidden slab's permutation: sample_ids is arange(B), so
        # slab_ids ARE the surviving row indices (flush slots -1 -> row 0,
        # matching the conditional-buffer padding contract)
        take = jnp.maximum(slab_ids, 0)
        mem_slab = jax.tree.map(lambda x: jnp.take(x, take, axis=0), memory)
    final_logits, _ = stage2_prefill(params, cfg, spec, slab, memory=mem_slab)
    easy_ids = jnp.where(exit_mask, sample_ids, -1)
    merged = cond.exit_merge(B, easy_ids, exit_logits, slab_ids, final_logits)
    return {
        "logits": merged,
        "exit_mask": exit_mask,
        "exit_logits": exit_logits,
        "confidence": conf,
        "n_hard": n_hard,
        "overflow": overflow,
    }
