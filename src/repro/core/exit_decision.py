"""Exit (Softmax) Decision layer — paper §III-C.1, Eqs. (2)-(4).

An early exit occurs when  max_i [Softmax(x)]_i > C_thr  (Eq. 2). The paper
removes the Softmax division (Eq. 4):

    max_i exp(x_i) > C_thr * sum_j exp(x_j)

On TPU we additionally shift by the row max m = max_j x_j, under which the
left side becomes exp(0) = 1, so the whole decision collapses to ONE fused
online reduction:

    1 > C_thr * sum_j exp(x_j - m)            (division-free AND stable)

i.e. the decision needs only (m, sum-exp) — the same (m, l) pair flash
attention tracks — and never materializes the softmax. The Pallas kernel in
kernels/exit_decision implements exactly this; this module is the framework-
level API and the jnp reference used everywhere off the hot path.

The entropy criterion (BranchyNet's default) is also provided for parity
with the literature; ATHEENA itself uses max-softmax.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp


def softmax_confidence(logits: jnp.ndarray) -> jnp.ndarray:
    """max_i softmax(x)_i per row, computed stably. logits: (..., C)."""
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1)
    s = jnp.sum(jnp.exp(x - m[..., None]), axis=-1)
    return 1.0 / s          # max softmax prob == exp(0)/sum == 1/s


def exit_decision(logits: jnp.ndarray, c_thr: float) -> jnp.ndarray:
    """Eq. (4), division-free and max-shifted: 1 > C_thr * sum exp(x - m).
    Returns bool (...,) — True means the sample exits early."""
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1)
    s = jnp.sum(jnp.exp(x - m[..., None]), axis=-1)
    return 1.0 > c_thr * s


def entropy_confidence(logits: jnp.ndarray) -> jnp.ndarray:
    """Normalized entropy in [0,1] (0 = certain). BranchyNet's criterion."""
    x = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(x, axis=-1)
    ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
    return ent / jnp.log(jnp.float32(x.shape[-1]))


def exit_decision_entropy(logits: jnp.ndarray, e_thr: float) -> jnp.ndarray:
    return entropy_confidence(logits) < e_thr


def decision_and_argmax(logits: jnp.ndarray, c_thr: float
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(exit_mask bool, predicted class int32, confidence fp32) in one pass.
    This is the fused triple the hardware layer produces. The mask uses the
    division-free form ``1 > c_thr * s`` — the same fp32 expression as
    ``exit_decision`` and the Pallas kernel ref — rather than the rounded
    ``1/s > c_thr``, so every decision path in the repo agrees bitwise on
    threshold-boundary samples."""
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1)
    s = jnp.sum(jnp.exp(x - m[..., None]), axis=-1)
    conf = 1.0 / s
    pred = jnp.argmax(x, axis=-1).astype(jnp.int32)
    return jnp.float32(c_thr) * s < 1.0, pred, conf


def calibrate_threshold(confidences: jnp.ndarray, target_exit_rate: float) -> float:
    """Pick C_thr so that a ``target_exit_rate`` fraction of the profiling
    set exits early (paper: 'C_thr determined after training prior to exit
    profiling'). confidences: (N,) stage-1 max-softmax values.

    Called ONLINE by the drift controller on a rolling reservoir, so the
    corners are pinned down rather than left to quantile semantics:

      * an empty calibration set raises (a threshold from nothing would
        silently steer the exit rate to garbage);
      * ``target_exit_rate`` outside [0, 1] raises;
      * rate 0 returns the max confidence — the exit test is STRICT
        (``conf > C_thr``, the division-free ``c_thr * s < 1``), so
        nothing in the set exits;
      * rate 1 returns the largest float strictly below the min, so ties
        AT the minimum still exit;
      * ties at the quantile boundary under-exit rather than over-exit
        (strict comparison sends boundary samples to stage 2 — the
        conservative side: accuracy is preserved, throughput re-plans).
    """
    conf = jnp.asarray(confidences, jnp.float32).reshape(-1)
    if conf.size == 0:
        raise ValueError("calibrate_threshold needs a non-empty confidence "
                         "set (the online reservoir has not filled yet?)")
    if not 0.0 <= target_exit_rate <= 1.0:
        raise ValueError(f"target_exit_rate must be in [0, 1], got "
                         f"{target_exit_rate}")
    if target_exit_rate <= 0.0:
        return float(jnp.max(conf))
    if target_exit_rate >= 1.0:
        return float(np.nextafter(np.float32(jnp.min(conf)), np.float32(-1)))
    q = jnp.quantile(conf, 1.0 - target_exit_rate)
    return float(q)
