"""Conditional Buffer / Split / Exit Merge — paper §III-C.2-4, TPU-native.

The FPGA conditional buffer holds a sample's intermediate feature map while
the exit decision is computed, then either drops it (single-cycle address
invalidation) or streams it to stage 2. On TPU the equivalent is a static-
shaped **compaction**: a stable prefix-sum partition that moves hard samples
(exit_mask == False) to the front, plus the Sample-ID tags the paper threads
through the pipeline so out-of-order completions can be merged.

The queue simulator at the bottom models the buffer occupancy / stall
behaviour (paper Fig. 7 deadlock-avoidance sizing and the Fig. 4 q-vs-p
robustness band) for the serving runtime.

NOTE: this module is the framework-level reference. The serving hot path
(core/early_exit.serve_batch and runtime/serve_loop.TwoStageServer) performs
the compaction through ``kernels.dispatch.gather_compact_op`` — the Pallas
stream-compaction kernel on TPU, its jnp oracle under XLA elsewhere — and
carries hard samples between batches in the device-side ring buffer
(runtime/serve_loop.ring_enqueue / ring_drain). The functions here remain
the semantics contract those kernels are tested against, and the off-hot-
path API (property tests, the dry-run planner, pytree inputs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def compact_indices(hard_mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable partition: indices of hard samples first, then easy.

    hard_mask: (B,) bool. Returns (perm (B,) int32, n_hard () int32) where
    perm[:n_hard] are hard-sample indices in original order.
    """
    b = hard_mask.shape[0]
    hard = hard_mask.astype(jnp.int32)
    pos_hard = jnp.cumsum(hard) - 1                     # slot among hard
    pos_easy = jnp.cumsum(1 - hard) - 1                 # slot among easy
    n_hard = jnp.sum(hard)
    slot = jnp.where(hard_mask, pos_hard, n_hard + pos_easy)
    perm = jnp.zeros((b,), jnp.int32).at[slot].set(jnp.arange(b, dtype=jnp.int32))
    return perm, n_hard


def conditional_buffer(hidden, sample_ids, hard_mask, capacity: int):
    """The Conditional Buffer: keep hard samples, emit a fixed-size slab.

    hidden: (B, ...) stage-1 intermediate activations (pytree ok)
    sample_ids: (B,) int32 tags; hard_mask: (B,) bool.
    capacity: stage-2 bucket size (static; = ceil(p*B) rounded for sharding).

    Returns (slab_hidden (capacity, ...), slab_ids (capacity,), n_hard, overflow)
    — slots beyond n_hard carry the *flush* id -1 (the paper flushes the
    stage-2 pipeline with an unused Sample ID to avoid deadlock); overflow
    counts hard samples dropped to the retry queue when n_hard > capacity.
    """
    perm, n_hard = compact_indices(hard_mask)
    take = perm[:capacity]
    valid = jnp.arange(capacity) < jnp.minimum(n_hard, capacity)
    slab = jax.tree.map(lambda x: jnp.take(x, take, axis=0), hidden)
    slab_ids = jnp.where(valid, jnp.take(sample_ids, take), -1)
    overflow = jnp.maximum(n_hard - capacity, 0)
    return slab, slab_ids, n_hard, overflow


def split_stream(x):
    """Split layer: duplicate the stream at a branch point. Under XLA this is
    free (no copy until divergent writes); kept explicit for graph parity
    with the paper's CDFG."""
    return x, x


def exit_merge(batch: int, easy_ids, easy_vals, hard_ids, hard_vals,
               fill_value=0):
    """Exit Merge: coherently merge out-of-order exit streams by Sample ID.

    easy_ids: (B,) int32 with -1 for non-exited slots; easy_vals: (B, ...)
    hard_ids: (C,) int32 with -1 for flush slots;      hard_vals: (C, ...)
    Returns merged (batch, ...) ordered by sample id.
    """
    def scat(ids, vals, out):
        safe = jnp.where(ids >= 0, ids, batch)          # flush ids -> scratch row
        padded = jnp.concatenate([out, out[:1]], axis=0)
        padded = padded.at[safe].set(vals)
        return padded[:batch]

    shape = (batch,) + easy_vals.shape[1:]
    out = jnp.full(shape, fill_value, easy_vals.dtype)
    out = scat(easy_ids, easy_vals, out)
    out = scat(hard_ids, hard_vals, out)
    return out


# ---------------------------------------------------------------------------
# buffer sizing + queue model (paper Fig. 7 / Fig. 4 robustness)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BufferSpec:
    """Minimum conditional-buffer depth to avoid a stall (Fig. 7): the buffer
    must hold the samples in flight while the exit path (exit layers +
    decision) evaluates, plus slack for q-vs-p variance."""
    decision_latency_samples: float   # exit-path latency / stage-1 sample period
    q_slack: float = 0.10             # tolerated (q - p) before stalling

    def min_depth(self, batch: int, p: float) -> int:
        inflight = int(np.ceil(self.decision_latency_samples))
        variance = int(np.ceil(self.q_slack * batch))
        return inflight + variance


def simulate_two_stage_queue(hard_seq: np.ndarray, *, stage1_rate: float,
                             stage2_rate: float, buffer_depth: int
                             ) -> dict:
    """Discrete-event model of the two-stage pipeline on a 0/1 hard-sample
    sequence. Returns achieved throughput + stall statistics. Used by tests
    and the Fig. 4 robustness benchmark (no hardware needed).

    stage1_rate / stage2_rate: samples per unit time each stage can absorb.
    """
    t1 = 1.0 / stage1_rate
    t2 = 1.0 / stage2_rate
    n = len(hard_seq)
    stage1_free = 0.0
    stage2_free = 0.0
    queue = []          # completion times of stage-1 output awaiting stage 2
    stalls = 0
    done = 0.0
    for i, hard in enumerate(hard_seq):
        start = max(stage1_free, 0.0)
        # backpressure: if the buffer is full, stage 1 stalls until a slot frees
        while len(queue) >= buffer_depth:
            t = queue.pop(0)
            stage2_free = max(stage2_free, t) + t2
            stalls += 1
        stage1_free = start + t1
        if hard:
            queue.append(stage1_free)
        done = max(done, stage1_free)
    while queue:
        t = queue.pop(0)
        stage2_free = max(stage2_free, t) + t2
    done = max(done, stage2_free)
    return {
        "throughput": n / done if done > 0 else float("inf"),
        "stalls": stalls,
        "makespan": done,
    }
