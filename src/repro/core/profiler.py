"""Early-Exit Profiler — paper §III-B.1.

Apportions a profiling set into multiple distinct splits (similar average
hard-sample probability, individual variation), runs batched inference,
and collects per-exit probability, per-exit accuracy and cumulative
accuracy. The average hard probability feeds the ATHEENA optimizer as p.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exit_decision as ed


@dataclass
class ExitProfile:
    c_thr: float
    p_hard: float                      # fraction NOT exiting early (mean)
    p_hard_splits: List[float]         # per-split variation
    exit_accuracy: float               # accuracy of exited samples at exit 1
    final_accuracy: float              # accuracy of samples finishing stage 2
    cumulative_accuracy: float         # overall EE accuracy
    baseline_accuracy: float           # all samples through the full net
    n_samples: int

    def as_dict(self):
        return self.__dict__.copy()


def apportion(n: int, n_splits: int, rng: np.random.Generator) -> List[np.ndarray]:
    """Split indices into n_splits random, equal, disjoint subsets."""
    idx = rng.permutation(n)
    return [np.array(s) for s in np.array_split(idx, n_splits)]


def profile_early_exit(
    exit_logits: jnp.ndarray,          # (N, C) stage-1 exit logits
    final_logits: jnp.ndarray,         # (N, C) full-network logits
    labels: jnp.ndarray,               # (N,)
    c_thr: float,
    n_splits: int = 5,
    seed: int = 0,
) -> ExitProfile:
    """Pure profiling math on precomputed logits (model-agnostic)."""
    exit_mask = np.asarray(ed.exit_decision(exit_logits, c_thr))
    exit_pred = np.asarray(jnp.argmax(exit_logits, axis=-1))
    final_pred = np.asarray(jnp.argmax(final_logits, axis=-1))
    y = np.asarray(labels)
    n = len(y)

    hard = ~exit_mask
    p_hard = float(hard.mean())
    rng = np.random.default_rng(seed)
    splits = apportion(n, n_splits, rng)
    p_splits = [float(hard[s].mean()) for s in splits]

    exit_acc = float((exit_pred[exit_mask] == y[exit_mask]).mean()) if exit_mask.any() else 0.0
    fin_acc = float((final_pred[hard] == y[hard]).mean()) if hard.any() else 0.0
    ee_pred = np.where(exit_mask, exit_pred, final_pred)
    cum_acc = float((ee_pred == y).mean())
    base_acc = float((final_pred == y).mean())
    return ExitProfile(
        c_thr=c_thr, p_hard=p_hard, p_hard_splits=p_splits,
        exit_accuracy=exit_acc, final_accuracy=fin_acc,
        cumulative_accuracy=cum_acc, baseline_accuracy=base_acc,
        n_samples=n,
    )


def profile_model(
    stage1_fn: Callable,               # batch -> exit logits (B, C)
    full_fn: Callable,                 # batch -> final logits (B, C)
    batches: Sequence,                 # iterable of (inputs, labels)
    c_thr: float,
    n_splits: int = 5,
) -> ExitProfile:
    """Run batched inference over the profiling set and profile."""
    e_all, f_all, y_all = [], [], []
    for x, y in batches:
        e_all.append(np.asarray(stage1_fn(x)))
        f_all.append(np.asarray(full_fn(x)))
        y_all.append(np.asarray(y))
    return profile_early_exit(jnp.asarray(np.concatenate(e_all)),
                              jnp.asarray(np.concatenate(f_all)),
                              jnp.asarray(np.concatenate(y_all)),
                              c_thr, n_splits=n_splits)


def sweep_thresholds(exit_logits, final_logits, labels,
                     thresholds: Sequence[float]) -> List[ExitProfile]:
    """The accuracy/p trade-off curve the user picks C_thr from."""
    return [profile_early_exit(exit_logits, final_logits, labels, t)
            for t in thresholds]


def make_test_set_with_q(exit_logits, labels, c_thr: float, q: float,
                         n: int, seed: int = 0) -> np.ndarray:
    """Sample indices whose hard fraction is exactly q (paper §IV-A: 'sampled
    test set proportioned according to the required test probabilities but
    distributed randomly within the batch')."""
    exit_mask = np.asarray(ed.exit_decision(exit_logits, c_thr))
    hard_idx = np.flatnonzero(~exit_mask)
    easy_idx = np.flatnonzero(exit_mask)
    n_hard = int(round(q * n))
    rng = np.random.default_rng(seed)
    if len(hard_idx) < n_hard or len(easy_idx) < n - n_hard:
        raise ValueError("profiling set too small for requested q")
    pick = np.concatenate([rng.choice(hard_idx, n_hard, replace=False),
                           rng.choice(easy_idx, n - n_hard, replace=False)])
    rng.shuffle(pick)
    return pick
