"""Stage-mesh apportionment: turn a combined TAP design point into disjoint
device-mesh slices for stage 1 / stage 2 (the spatial analogue of the FPGA
floorplan: both stages resident simultaneously, no reconfiguration).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

import jax

from repro.core.perf_model import ShardPlan
from repro.core.tap import CombinedDesign


@dataclass(frozen=True)
class StageMeshPlan:
    chips1: int
    chips2: int
    plan1: ShardPlan
    plan2: ShardPlan

    @classmethod
    def from_design(cls, design: CombinedDesign) -> "StageMeshPlan":
        return cls(
            chips1=int(design.stage1.resources[0]),
            chips2=int(design.stage2.resources[0]),
            plan1=design.stage1.meta.get("plan") or
            design.stage1.meta.get("roofline", {}).get("plan"),
            plan2=design.stage2.meta.get("plan") or
            design.stage2.meta.get("roofline", {}).get("plan"),
        )


def make_stage_meshes(devices, plan: StageMeshPlan
                      ) -> Tuple[jax.sharding.Mesh, jax.sharding.Mesh]:
    """Carve two disjoint submeshes out of a flat device list. Stage 1 takes
    the first chips1 devices, stage 2 the next chips2. Each submesh is
    (data, model) shaped per its ShardPlan."""
    devs = np.asarray(devices).reshape(-1)
    need = plan.chips1 + plan.chips2
    if len(devs) < need:
        raise ValueError(f"{need} chips required, {len(devs)} available")
    d1 = devs[:plan.chips1].reshape(plan.plan1.dp, plan.plan1.tp)
    d2 = devs[plan.chips1:need].reshape(plan.plan2.dp, plan.plan2.tp)
    m1 = jax.sharding.Mesh(d1, ("data", "model"))
    m2 = jax.sharding.Mesh(d2, ("data", "model"))
    return m1, m2


def stage2_capacity(batch: int, p: float, multiple: int = 8,
                    slack: float = 0.1) -> int:
    """Bucket size for the stage-2 hard-sample slab: ceil((p+slack)*B),
    rounded up to the sharding multiple (the conditional buffer's BRAM-slack
    analogue — over-provisioning stage 2 'increases robustness to variation
    in the hard samples' exit probability', §IV-A)."""
    c = int(np.ceil((p + slack) * batch))
    c = max(multiple, ((c + multiple - 1) // multiple) * multiple)
    return min(c, batch)
