"""Stage-mesh apportionment: turn a combined TAP design point into disjoint
device-mesh slices for stage 1 / stage 2 (the spatial analogue of the FPGA
floorplan: both stages resident simultaneously, no reconfiguration).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

import jax

from repro.core.perf_model import ShardPlan
from repro.core.tap import CombinedDesign, DesignPoint


def _recover_plan(point: DesignPoint, label: str) -> ShardPlan:
    """Pull the ShardPlan out of a DesignPoint's meta. The LM DSE stores it
    either at meta['plan'] (lm_sharding_dse) or meta['roofline']['plan'];
    both lookups are validated so a design without a recoverable plan fails
    loudly instead of yielding a None plan that breaks mesh carving later."""
    meta = point.meta if isinstance(point.meta, dict) else {}
    plan = meta.get("plan")
    if plan is None:
        roofline = meta.get("roofline")
        if isinstance(roofline, dict):
            plan = roofline.get("plan")
    if not isinstance(plan, ShardPlan):
        raise ValueError(
            f"no ShardPlan recoverable from {label} DesignPoint meta "
            f"(looked at meta['plan'] and meta['roofline']['plan'], got "
            f"{type(plan).__name__}); was this design produced by the LM "
            f"sharding DSE? meta keys: {sorted(meta)}")
    return plan


@dataclass(frozen=True)
class StageMeshPlan:
    chips1: int
    chips2: int
    plan1: ShardPlan
    plan2: ShardPlan

    def __post_init__(self):
        for i, (chips, plan) in enumerate(
                ((self.chips1, self.plan1), (self.chips2, self.plan2)), 1):
            if chips < 1:
                raise ValueError(f"stage {i}: chips must be >= 1, got {chips}")
            if plan.chips != chips:
                raise ValueError(
                    f"stage {i}: plan dp*tp = {plan.dp}*{plan.tp} = "
                    f"{plan.chips} != chips{i} = {chips}")

    @classmethod
    def from_design(cls, design: CombinedDesign) -> "StageMeshPlan":
        return cls(
            chips1=int(design.stage1.resources[0]),
            chips2=int(design.stage2.resources[0]),
            plan1=_recover_plan(design.stage1, "stage1"),
            plan2=_recover_plan(design.stage2, "stage2"),
        )

    @classmethod
    def from_chips(cls, chips1: int, chips2: int) -> "StageMeshPlan":
        """Pure data-parallel plan over explicit chip counts (the serve-CLI
        path when no TAP design is in hand)."""
        return cls(chips1=chips1, chips2=chips2,
                   plan1=ShardPlan(dp=chips1, tp=1),
                   plan2=ShardPlan(dp=chips2, tp=1))

    @classmethod
    def resolve(cls, p: float, n_devices: int,
                chips1: Optional[int] = None,
                chips2: Optional[int] = None) -> "StageMeshPlan":
        """The CLI/benchmark resolution rule, in one place: explicit chip
        counts when given (a missing one is the complement of the other
        within ``n_devices``), else the p-proportional apportionment. An
        explicit 0 is NOT treated as unset — it reaches the >= 1
        validation and fails loudly."""
        if chips1 is not None or chips2 is not None:
            if chips1 is None:
                chips1 = n_devices - chips2
            if chips2 is None:
                chips2 = n_devices - chips1
            return cls.from_chips(chips1, chips2)
        return cls.proportional(p, n_devices)

    @classmethod
    def proportional(cls, p: float, n_devices: int) -> "StageMeshPlan":
        """p-proportional apportionment (ATHEENA §IV): stage 2 sees a p
        fraction of the traffic, so it gets ~p of the chips, and stage 1
        the rest — the default when no TAP curves have been profiled."""
        if n_devices < 2:
            raise ValueError(
                f"disaggregation needs >= 2 devices, got {n_devices}")
        chips2 = min(max(1, round(p * n_devices)), n_devices - 1)
        return cls.from_chips(n_devices - chips2, chips2)


def carve_stage_devices(devices, plan: StageMeshPlan
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Carve a flat device list into two disjoint (dp, tp) grids. Stage 1
    takes the first chips1 devices, stage 2 the next chips2 — together they
    cover exactly the first chips1+chips2 devices, never sharing one (the
    'both stages resident' floorplan). Pure indexing, no jax state."""
    devs = np.asarray(devices, dtype=object).reshape(-1)
    need = plan.chips1 + plan.chips2
    if len(devs) < need:
        raise ValueError(f"{need} chips required, {len(devs)} available")
    d1 = devs[:plan.chips1].reshape(plan.plan1.dp, plan.plan1.tp)
    d2 = devs[plan.chips1:need].reshape(plan.plan2.dp, plan.plan2.tp)
    return d1, d2


def make_stage_meshes(devices, plan: StageMeshPlan
                      ) -> Tuple[jax.sharding.Mesh, jax.sharding.Mesh]:
    """Carve two disjoint submeshes out of a flat device list; each submesh
    is (data, model) shaped per its ShardPlan (see carve_stage_devices)."""
    d1, d2 = carve_stage_devices(devices, plan)
    m1 = jax.sharding.Mesh(d1, ("data", "model"))
    m2 = jax.sharding.Mesh(d2, ("data", "model"))
    return m1, m2


def stage2_capacity(batch: int, p: float, multiple: int = 8,
                    slack: float = 0.1) -> int:
    """Bucket size for the stage-2 hard-sample slab: ceil((p+slack)*B),
    rounded up to the sharding multiple (the conditional buffer's BRAM-slack
    analogue — over-provisioning stage 2 'increases robustness to variation
    in the hard samples' exit probability', §IV-A). Clamped to [1, batch]:
    p=0 still provisions one `multiple`-sized bucket (the slack floor), p=1
    yields the full batch, and a batch smaller than the sharding multiple
    caps at the batch itself."""
    c = int(np.ceil((p + slack) * batch))
    c = max(multiple, ((c + multiple - 1) // multiple) * multiple)
    return max(1, min(c, batch))
