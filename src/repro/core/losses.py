"""Losses: sequence-chunked cross entropy, vocab-parallel (Megatron-style)
cross entropy for TP meshes, and the BranchyNet joint EE loss."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models import hints
from repro.models.config import ArchConfig
from repro.models.layers import unembed


def _logits_chunk(params_bb, cfg: ArchConfig, h_chunk):
    if cfg.tie_embeddings or "head" not in params_bb:
        return unembed(params_bb["embed"], h_chunk)
    return jnp.einsum("...d,dv->...v", h_chunk.astype(jnp.float32),
                      params_bb["head"].astype(jnp.float32))


def chunked_ce(params_bb, cfg: ArchConfig, hidden, labels, mask=None,
               chunk: int = 512) -> jnp.ndarray:
    """Cross entropy without materializing (B, S, V): scan over sequence
    chunks, unembedding one chunk at a time. hidden must already be
    normalised (final/exit norm applied). labels: (B, S) int32; mask: (B, S)
    1.0 where the position counts."""
    B, S, _ = hidden.shape
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    nc = hidden.shape[1] // chunk
    hs = hidden.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        h_c, l_c, m_c = xs
        logits = _logits_chunk(params_bb, cfg, h_c)           # (B, chunk, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m_c
        return (tot + jnp.sum(nll), cnt + jnp.sum(m_c)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def _vp_applicable(cfg: ArchConfig) -> bool:
    """Vocab-parallel CE applies when the ambient mesh has a model axis
    that divides the vocab and the unembedding is the tied table (the
    sharding planner puts the table's vocab dim on 'model' exactly then)."""
    mesh = hints.mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    m = mesh.shape["model"]
    return m > 1 and cfg.vocab % m == 0


@jax.custom_jvp
def _pmax_model_sg(x):
    """pmax over 'model' with stop-gradient semantics (pmax has no JVP rule;
    the softmax max-shift must not carry gradient anyway)."""
    return jax.lax.pmax(x, axis_name="model")


@_pmax_model_sg.defjvp
def _pmax_model_sg_jvp(primals, tangents):
    (x,) = primals
    return _pmax_model_sg(x), jnp.zeros_like(x)


def vocab_parallel_ce(params_bb, cfg: ArchConfig, hidden, labels, mask=None,
                      chunk: int = 512) -> jnp.ndarray:
    """Megatron-style TP cross entropy: each model-rank unembeds its OWN
    vocab shard; the softmax statistics (running max, sum-exp, gold logit)
    are combined with two tiny collectives per sequence chunk instead of
    materializing (B, S, V) logits or resharding hidden per chunk.

    hidden: (B, S, d) pre-normalised; table sharded P('model', None)."""
    mesh = hints.mesh()
    m = mesh.shape["model"]
    table = params_bb["embed"]["table"]
    B, S, _ = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    baxes = hints.batch_axes()
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    bspec = baxes if (baxes and B % nb == 0) else None
    v_loc = cfg.vocab // m
    chunk = min(chunk, S)
    pad = (-S) % chunk

    def body(h, y, w, tbl):
        r = jax.lax.axis_index("model")
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            y = jnp.pad(y, ((0, 0), (0, pad)))
            w = jnp.pad(w, ((0, 0), (0, pad)))
        nc = h.shape[1] // chunk
        hs = h.reshape(h.shape[0], nc, chunk, -1).transpose(1, 0, 2, 3)
        ys = y.reshape(y.shape[0], nc, chunk).transpose(1, 0, 2)
        ws = w.reshape(w.shape[0], nc, chunk).transpose(1, 0, 2)

        def step(carry, xs):
            tot, cnt = carry
            h_c, y_c, w_c = xs
            lg = jnp.einsum("bsd,vd->bsv", h_c.astype(jnp.float32),
                            tbl.astype(jnp.float32))      # (b, chunk, v_loc)
            m_loc = jnp.max(lg, axis=-1)
            m_glob = _pmax_model_sg(jax.lax.stop_gradient(m_loc))
            s_loc = jnp.sum(jnp.exp(lg - m_glob[..., None]), axis=-1)
            s_glob = jax.lax.psum(s_loc, axis_name="model")
            y_rel = y_c - r * v_loc
            in_rng = (y_rel >= 0) & (y_rel < v_loc)
            gold_loc = jnp.take_along_axis(
                lg, jnp.clip(y_rel, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
            gold = jax.lax.psum(jnp.where(in_rng, gold_loc, 0.0),
                                axis_name="model")
            nll = (m_glob + jnp.log(s_glob) - gold) * w_c
            return (tot + jnp.sum(nll), cnt + jnp.sum(w_c)), None

        (tot, cnt), _ = jax.lax.scan(
            step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hs, ys, ws))
        return tot[None], cnt[None]

    tot, cnt = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, None), P(bspec, None),
                  P("model", None)),
        out_specs=(P(bspec), P(bspec)),
        check_vma=False,
    )(hidden, labels, mask.astype(jnp.float32), table)
    return jnp.sum(tot) / jnp.maximum(jnp.sum(cnt), 1.0)


def branchynet_joint_loss(params, cfg: ArchConfig, exit_hidden, final_hidden,
                          labels, weights: Tuple[float, float], mask=None,
                          aux: jnp.ndarray | None = None,
                          aux_weight: float = 0.01):
    """L = w_exit * CE(exit) + w_final * CE(final) (+ MoE aux).
    Hidden tensors are pre-normalised (B, S, d); labels (B, S)."""
    bb = params["backbone"]
    ce = (vocab_parallel_ce
          if (_vp_applicable(cfg) and
              (cfg.tie_embeddings or "head" not in bb))
          else lambda *a, **k: chunked_ce(*a, **k))
    l_exit = ce(bb, cfg, exit_hidden, labels, mask)
    l_final = ce(bb, cfg, final_hidden, labels, mask)
    loss = weights[0] * l_exit + weights[1] * l_final
    if aux is not None:
        loss = loss + aux_weight * aux
    return loss, {"ce_exit": l_exit, "ce_final": l_final}


def cnn_joint_loss(logits_list: Sequence[jnp.ndarray], labels,
                   weights: Sequence[float]):
    """BranchyNet joint loss for the CNN family: weighted CE over all exits."""
    total = jnp.zeros((), jnp.float32)
    metrics = {}
    for i, (lg, w) in enumerate(zip(logits_list, weights)):
        logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        nll = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
        total = total + w * nll
        metrics[f"ce_exit{i}"] = nll
    return total, metrics
