"""Version-compatibility shims for the jax APIs this repo uses.

The codebase targets the modern API surface (``jax.shard_map`` with
``check_vma``); these shims keep it importable and correct on jax 0.4.x,
where shard_map lives in ``jax.experimental.shard_map`` and the replication
check is spelled ``check_rep``.
"""
from __future__ import annotations

try:
    from jax import shard_map as _shard_map          # jax >= 0.6
    _CHECK_KW = "check_vma"
except ImportError:                                   # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` signature, portable across jax versions."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as one dict: jax 0.4.x returns a
    per-partition list, newer jax a flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost
