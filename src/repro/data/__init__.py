from repro.data import pipeline
