"""Synthetic sharded data pipeline.

Deterministic, seekable, host-shardable token streams (training) and a
clustered classification generator with *controllable difficulty structure*
(profiling / EE experiments need a dataset where some samples genuinely are
easy and some hard — iid noise has no early-exit signal).

Production behaviours implemented:
  - per-host sharding: host i of H draws rows [i::H] of each global batch;
  - seekability: batch t is a pure function of (seed, t) so a restored
    checkpoint replays the exact stream (bit-exact resume tests rely on it);
  - straggler injection + mitigation: an optional delay model simulates slow
    hosts; ``fetch_with_timeout`` re-issues the draw against the backup
    generator (batch re-issue — the data-side straggler strategy).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterator, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LMStreamSpec:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def lm_batch(spec: LMStreamSpec, step: int) -> Tuple[np.ndarray, np.ndarray]:
    """(tokens, labels) for global step ``step`` — this host's shard only.

    Tokens follow a Zipf-ish marginal with a per-sequence Markov repeat
    process so sequences are compressible (finite loss floor) rather than
    uniform noise."""
    rng = np.random.default_rng(
        np.random.SeedSequence([spec.seed, step, spec.host_id]))
    b, s = spec.host_batch, spec.seq_len
    # zipf marginal clipped to vocab
    base = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
    base = (base - 1) % spec.vocab
    # markov repeats: with prob .3 copy the previous token (structure to learn)
    rep = rng.random((b, s + 1)) < 0.3
    for j in range(1, s + 1):
        base[:, j] = np.where(rep[:, j], base[:, j - 1], base[:, j])
    tokens = base[:, :-1].astype(np.int32)
    labels = base[:, 1:].astype(np.int32)
    return tokens, labels


def lm_stream(spec: LMStreamSpec, start_step: int = 0
              ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    t = start_step
    while True:
        yield lm_batch(spec, t)
        t += 1


# ---------------------------------------------------------------------------
# classification set with difficulty structure (EE profiling)
# ---------------------------------------------------------------------------

def clustered_classification(n: int, n_classes: int, dim: int, *,
                             hard_frac: float = 0.3, seed: int = 0,
                             margin_easy: float = 4.0, margin_hard: float = 0.6
                             ) -> dict:
    """Gaussian class clusters; a ``hard_frac`` of samples are drawn near the
    decision boundary (small margin), the rest far (large margin). Returns
    x (n, dim), y (n,), is_hard (n,) — the ground-truth difficulty used to
    sanity-check the profiler (profiled p should track hard_frac)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    y = rng.integers(0, n_classes, size=n)
    is_hard = rng.random(n) < hard_frac
    margin = np.where(is_hard, margin_hard, margin_easy).astype(np.float32)
    x = centers[y] * margin[:, None] + rng.normal(
        size=(n, dim)).astype(np.float32)
    return {"x": x.astype(np.float32), "y": y.astype(np.int32),
            "is_hard": is_hard}


def mnist_like(n: int, *, seed: int = 0, hard_frac: float = 0.3) -> dict:
    """28x28x1 image-shaped version of the clustered set (for the paper's
    B-LeNet pipeline): class templates + per-sample noise scaled by
    difficulty."""
    rng = np.random.default_rng(seed)
    templates = rng.normal(size=(10, 28, 28, 1)).astype(np.float32)
    y = rng.integers(0, 10, size=n)
    is_hard = rng.random(n) < hard_frac
    noise_scale = np.where(is_hard, 2.5, 0.5).astype(np.float32)
    x = templates[y] + rng.normal(size=(n, 28, 28, 1)).astype(np.float32) \
        * noise_scale[:, None, None, None]
    return {"x": x, "y": y.astype(np.int32), "is_hard": is_hard}


# ---------------------------------------------------------------------------
# straggler injection + mitigation
# ---------------------------------------------------------------------------

class StragglerModel:
    """Simulates a host that occasionally stalls on a fetch."""

    def __init__(self, stall_prob: float = 0.0, stall_s: float = 1.0,
                 seed: int = 0):
        self.stall_prob = stall_prob
        self.stall_s = stall_s
        self._rng = np.random.default_rng(seed)

    def maybe_stall(self):
        if self.stall_prob and self._rng.random() < self.stall_prob:
            time.sleep(self.stall_s)
            return True
        return False


def fetch_with_timeout(fetch: Callable[[], object], *, timeout_s: float,
                       backup: Optional[Callable[[], object]] = None):
    """Run ``fetch`` in a worker thread; on timeout re-issue via ``backup``
    (defaults to ``fetch`` itself — the draw is deterministic so the re-issue
    returns identical data). Returns (value, timed_out)."""
    result: list = [None]
    err: list = [None]

    def run():
        try:
            result[0] = fetch()
        except Exception as e:                      # pragma: no cover
            err[0] = e

    th = threading.Thread(target=run, daemon=True)
    th.start()
    th.join(timeout_s)
    if th.is_alive():
        value = (backup or fetch)()
        return value, True
    if err[0] is not None:
        raise err[0]
    return result[0], False


# ---------------------------------------------------------------------------
# device placement
# ---------------------------------------------------------------------------

def shard_batch(batch, sharding):
    """Place a host-local numpy batch onto devices under ``sharding``."""
    return jax.tree.map(
        lambda x: jax.device_put(jnp.asarray(x), sharding), batch)
