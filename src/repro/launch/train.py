"""Training driver: ``PYTHONPATH=src python -m repro.launch.train
--arch qwen2-1.5b --smoke --steps 50``.

Runs the EE joint-loss training loop (checkpoint/restart, straggler
mitigation) on the local platform. ``--smoke`` swaps in the reduced
same-family config so the driver runs anywhere; without it the full config
is used (real accelerators). The same step function is what the dry-run
lowers on the production mesh."""
from __future__ import annotations

import argparse
import json

from repro.core import early_exit as ee
from repro.data import pipeline as dp
from repro.models.registry import get_arch, get_smoke, list_archs
from repro.optim import adamw
from repro.runtime import train_loop as TL


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (restart demo)")
    ap.add_argument("--exit-layer", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    spec = (ee.EarlyExitSpec(exit_layer=args.exit_layer)
            if args.exit_layer is not None else ee.default_spec(cfg))
    tc = TL.TrainConfig(
        steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=f"{args.ckpt_dir}/{args.arch}", log_every=10,
        fail_at_step=args.fail_at,
        optim=adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                                total_steps=args.steps))
    stream = dp.LMStreamSpec(global_batch=args.batch, seq_len=args.seq,
                             vocab=cfg.vocab, seed=0)

    def on_step(t, m):
        print(f"step {t:5d}  loss {m['loss']:.4f}  "
              f"ce_exit {m['ce_exit']:.4f}  ce_final {m['ce_final']:.4f}  "
              f"lr {m['lr']:.2e}", flush=True)

    runner = TL.train_with_restarts if args.fail_at is not None else TL.train
    out = runner(cfg, spec, tc, stream_spec=stream) \
        if args.fail_at is not None else \
        TL.train(cfg, spec, tc, stream_spec=stream, on_step=on_step)
    print(json.dumps({"arch": args.arch, "steps": out["step"],
                      "final_loss": out["history"][-1]["loss"]
                      if out["history"] else None,
                      "restarts": out.get("restarts", 0)}))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
