"""While-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` lowered to ``while`` has its body counted a single time, so a
24-layer scanned transformer under-reports FLOPs by ~24x. The roofline
report would be meaningless. This module re-derives the three roofline
inputs (FLOPs, HBM bytes-accessed, collective payload bytes) from the
post-optimization HLO text with call-graph multipliers:

  * ``while`` bodies/conditions x known trip count (XLA records
    ``backend_config={"known_trip_count":{"n":...}}``; fallback: the
    condition's ``compare(LT, constant)`` bound; fallback 1),
  * ``fusion``/``call``/``conditional`` descend x1,
  * FLOPs descend into fusion bodies (dots can be fused); bytes are counted
    at the fusion call site only (operands + outputs — XLA's convention),
  * collective payloads multiply through loops like everything else.

The text grammar is the stable HLO printer format: one instruction per
line, ``%name = TYPE opcode(operands), attrs``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*(?:e\dm\d(?:fn)?)?)\[([\d,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# instruction line:   [ROOT] %name = TYPE opcode(...)...
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
# computation header: %name (args) -> type {    /  ENTRY %name (...) ... {
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\{\s*$")

_TRIP_RE = re.compile(r'known_trip_count[^}]*"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

# opcodes whose operand/output bytes we do NOT charge (pure plumbing)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "custom-call", "copy-start", "copy-done",
}
# opcodes that terminate descent for byte accounting (body bytes already
# represented by the op's own operands/outputs)
_OPAQUE_FOR_BYTES = {"fusion", "reduce", "sort", "scatter", "map",
                     "reduce-window", "select-and-scatter", "reduce-scatter",
                     "all-reduce"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[List[int]]:
    """All shape literals in a type string as dim lists."""
    out = []
    for _dt, dims in _SHAPE_RE.findall(type_str):
        out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    rest: str                      # operands + attrs (tail of the line)
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # instr -> type


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line or mc.group(1)):
            cur = Computation(name=mc.group(2))
            comps[cur.name] = cur
            if mc.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rtype, opcode, rest = mi.groups()
        # operand names: %refs before the first "), " attr boundary
        paren = rest.split("), ")[0]
        ops = _OPERAND_RE.findall(paren)
        ins = Instr(name=name, result_type=rtype, opcode=opcode, rest=rest,
                    operands=ops)
        cur.instrs.append(ins)
        cur.shapes[name] = rtype
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_dims = _shape_dims(ins.result_type)
    out_n = 1
    for d in (out_dims[0] if out_dims else []):
        out_n *= d
    m = _LHS_C_RE.search(ins.rest)
    contract = 1
    if m and ins.operands:
        lhs_t = comp.shapes.get(ins.operands[0], "")
        lhs_dims = _shape_dims(lhs_t)
        if lhs_dims:
            for i in (int(x) for x in m.group(1).split(",") if x):
                if i < len(lhs_dims[0]):
                    contract *= lhs_dims[0][i]
    return 2.0 * out_n * contract


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_dims = _shape_dims(ins.result_type)
    out_n = 1
    for d in (out_dims[0] if out_dims else []):
        out_n *= d
    if len(ins.operands) < 2:
        return 0.0
    rhs_dims = _shape_dims(comp.shapes.get(ins.operands[1], ""))
    if not rhs_dims:
        return 0.0
    # dim_labels ...->..., rhs part between _ and ->, 'o' marks out-channels
    mo = re.search(r"dim_labels=[^_]+_([\dio]+)->", ins.rest)
    rhs = rhs_dims[0]
    k = 1
    for d in rhs:
        k *= d
    if mo:
        o_pos = mo.group(1).find("o")
        if 0 <= o_pos < len(rhs) and rhs[o_pos]:
            k //= rhs[o_pos]
    return 2.0 * out_n * k


@dataclass
class Costs:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in _COLLECTIVES})
    coll_count: float = 0.0

    def add(self, other: "Costs", mult: float = 1.0,
            bytes_too: bool = True) -> None:
        self.flops += other.flops * mult
        if bytes_too:
            self.bytes_accessed += other.bytes_accessed * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
        self.coll_count += other.coll_count * mult


def _trip_count(ins: Instr, comps: Dict[str, Computation]) -> float:
    m = _TRIP_RE.search(ins.rest)
    if m:
        return float(m.group(1))
    mc = _COND_RE.search(ins.rest)
    if mc and mc.group(1) in comps:
        for ci in comps[mc.group(1)].instrs:
            if ci.opcode == "constant":
                mconst = re.search(r"constant\((\d+)\)", "constant(" +
                                   ci.rest)
                if mconst:
                    return float(mconst.group(1))
    return 1.0


def _sliced_param_bytes(callee: Computation) -> Dict[int, int]:
    """For a fused computation: parameter indices that are consumed ONLY by
    (dynamic-)slice ops -> the bytes actually read (sum of slice outputs).
    XLA fuses `dynamic-slice(big)` into consumers; the big operand is
    address-computed, not streamed."""
    params: Dict[str, int] = {}
    for ins in callee.instrs:
        if ins.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", "parameter(" + ins.rest)
            if m:
                params[ins.name] = int(m.group(1))
    out: Dict[int, int] = {}
    bad: set = set()
    for ins in callee.instrs:
        if ins.opcode == "parameter":
            continue
        for o in ins.operands:
            if o in params:
                if ins.opcode in ("dynamic-slice", "slice") and \
                        ins.operands and ins.operands[0] == o:
                    out[params[o]] = out.get(params[o], 0) + \
                        _shape_bytes(ins.result_type)
                else:
                    bad.add(params[o])
    return {i: b for i, b in out.items() if i not in bad}


def _instr_bytes(ins: Instr, comp: Computation,
                 comps: Optional[Dict[str, Computation]] = None,
                 opcode_of: Optional[Dict[str, str]] = None,
                 loop: bool = False,
                 tile: int = 0) -> Tuple[float, float]:
    """(hbm_bytes, vmem_bytes) for one instruction.

    Conventions (documented in EXPERIMENTS.md §Roofline methodology):
      * (dynamic-)slice / dynamic-update-slice move the WINDOW, not the
        operand (in-place on TPU) — including slices fused into a kLoop
        fusion's body (XLA's address-computation fusion);
      * inside a while body, with a VMEM tile budget: operands/outputs that
        are loop-INTERNAL intermediates <= tile stay in VMEM (this is what
        the Pallas kernels enforce with BlockSpecs — flash tiles, online
        softmax carries); loop INPUTS (parameters / get-tuple-element of
        the carry) <= tile are loop-resident state (VMEM scratch); big
        buffers and the slices streamed out of them are HBM traffic.
    """
    out_b = _shape_bytes(ins.result_type)
    if ins.opcode in ("dynamic-slice", "slice"):
        return 2 * out_b, 0.0
    if ins.opcode == "dynamic-update-slice":
        upd = (_shape_bytes(comp.shapes.get(ins.operands[1], ""))
               if len(ins.operands) > 1 else out_b)
        return 2 * upd, 0.0
    sliced: Dict[int, int] = {}
    dus_out: Optional[int] = None
    if ins.opcode == "fusion" and comps is not None:
        m = _CALLS_RE.search(ins.rest)
        if m and m.group(1) in comps:
            callee = comps[m.group(1)]
            sliced = _sliced_param_bytes(callee)
            # in-place update fusion: root is dynamic-update-slice(param,
            # update, ...) — traffic is 2x the update window (read-modify-
            # write, buffer aliased on TPU), not the full buffer.
            root = callee.instrs[-1] if callee.instrs else None
            if root is not None and root.opcode == "dynamic-update-slice" \
                    and len(root.operands) > 1:
                upd_b = _shape_bytes(callee.shapes.get(root.operands[1], ""))
                for ci in callee.instrs:
                    if ci.opcode == "parameter" and ci.name == \
                            root.operands[0]:
                        pm = re.search(r"parameter\((\d+)\)",
                                       "parameter(" + ci.rest)
                        if pm:
                            sliced[int(pm.group(1))] = upd_b
                            dus_out = upd_b
                        break
    if dus_out is not None:
        out_b = dus_out                  # write = the update window
    if not (loop and tile):
        b = out_b
        for idx, o in enumerate(ins.operands):
            b += sliced.get(idx, _shape_bytes(comp.shapes.get(o, "")))
        return b, 0.0

    hbm = 0.0
    vmem = 0.0
    # output: tile-sized -> VMEM (a consumer or the carry picks it up);
    # bigger -> HBM write
    if out_b <= tile:
        vmem += out_b
    else:
        hbm += out_b
    for idx, o in enumerate(ins.operands):
        full = _shape_bytes(comp.shapes.get(o, ""))
        eff = sliced.get(idx, full)
        src = (opcode_of or {}).get(o, "")
        external = src in ("parameter", "get-tuple-element")
        if external and full <= tile:
            vmem += eff          # loop-resident small state (m/l/acc ...)
        elif eff <= tile and not external:
            vmem += eff          # tile intermediate
        else:
            hbm += eff           # streamed from HBM (slices of big buffers)
    return hbm, vmem


# TPU VMEM tile model: a while-body instruction whose output and every
# operand fit in a VMEM tile is kept on-chip by the fused/Pallas hot path
# (v5e VMEM = 128 MiB; flash tiles are <= a few MiB by construction). Such
# instructions are charged to VMEM, not HBM. Loop-carried accumulators
# bigger than the threshold (e.g. remat'd hidden states) stay charged.
VMEM_TILE_BYTES = 8 * 1024 * 1024


def analyze(text: str, breakdown: bool = False,
            vmem_tile_bytes: int = VMEM_TILE_BYTES) -> Dict[str, float]:
    """Trip-count-aware totals for one partitioned (per-device) HLO module.

    With ``breakdown=True`` also returns:
      by_opcode  — {opcode: bytes} at loop-multiplied weight,
      top        — the 30 single instructions with the largest
                   bytes x trips (bytes, name, opcode, mult).
    """
    comps, entry = parse_module(text)
    # --- pass 1: total multiplier per computation (flops descend into
    # fusion bodies; bytes stop at the fusion call site). in_loop marks
    # computations reached through a while body (VMEM tile rule scope). ----
    mult_f: Dict[str, float] = {}
    mult_b: Dict[str, float] = {}
    in_loop: Dict[str, bool] = {}

    def spread(name: str, mf: float, mb: float, loop: bool,
               depth: int = 0) -> None:
        if name not in comps or depth > 64:
            return
        mult_f[name] = mult_f.get(name, 0.0) + mf
        mult_b[name] = mult_b.get(name, 0.0) + mb
        in_loop[name] = in_loop.get(name, False) or loop
        for ins in comps[name].instrs:
            op = ins.opcode
            if op == "while":
                trips = _trip_count(ins, comps)
                for pat in (_BODY_RE, _COND_RE):
                    m = pat.search(ins.rest)
                    if m:
                        spread(m.group(1), mf * trips, mb * trips, True,
                               depth + 1)
            elif op == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    spread(m.group(1), mf, 0.0, loop, depth + 1)
            elif op in ("call", "async-start", "custom-call"):
                m = _TO_APPLY_RE.search(ins.rest) or _CALLS_RE.search(ins.rest)
                if m:
                    spread(m.group(1), mf, mb, loop, depth + 1)
            elif op == "conditional":
                mbr = _BRANCHES_RE.search(ins.rest)
                if mbr:
                    for b in _OPERAND_RE.findall(mbr.group(1)):
                        spread(b, mf, mb, loop, depth + 1)
            elif op in ("reduce", "sort", "scatter", "map", "reduce-window",
                        "select-and-scatter", "reduce-scatter", "all-reduce"):
                m = _TO_APPLY_RE.search(ins.rest)
                if m:
                    spread(m.group(1), mf, 0.0, loop, depth + 1)

    if entry:
        spread(entry, 1.0, 1.0, False)

    # --- pass 2: flat weighted sums over instructions -----------------------
    total = Costs()
    vmem_bytes = 0.0
    by_opcode: Dict[str, float] = {}
    top: List[Tuple[float, str, str, float]] = []
    for cname, comp in comps.items():
        mf = mult_f.get(cname, 0.0)
        mb = mult_b.get(cname, 0.0)
        loop = in_loop.get(cname, False)
        if mf == 0.0 and mb == 0.0:
            continue
        opcode_of = {i.name: i.opcode for i in comp.instrs}
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                total.flops += mf * _dot_flops(ins, comp)
            elif op == "convolution":
                total.flops += mf * _conv_flops(ins, comp)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                total.coll[base] += mf * _shape_bytes(ins.result_type)
                total.coll_count += mf
            if mb > 0.0 and op not in _FREE_OPS:
                hbm, vmem = _instr_bytes(ins, comp, comps, opcode_of,
                                         loop=loop, tile=vmem_tile_bytes)
                vmem_bytes += mb * vmem
                if hbm == 0.0:
                    continue
                total.bytes_accessed += mb * hbm
                if breakdown:
                    by_opcode[op] = by_opcode.get(op, 0.0) + mb * hbm
                    top.append((mb * hbm, ins.name, op, mb))

    out = {
        "flops": total.flops,
        "bytes_accessed": total.bytes_accessed,
        "vmem_bytes": vmem_bytes,
        "collective_count": total.coll_count,
    }
    if breakdown:
        out["by_opcode"] = dict(sorted(by_opcode.items(),
                                       key=lambda kv: -kv[1]))
        out["top"] = sorted(top, reverse=True)[:30]
    for k in _COLLECTIVES:
        out[f"coll_{k}"] = total.coll[k]
    out["coll_total"] = sum(total.coll[k] for k in _COLLECTIVES)
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Back-compat shim matching launch.hlo.collective_bytes's shape, but
    loop-aware."""
    a = analyze(hlo_text)
    out = {k: a[f"coll_{k}"] for k in _COLLECTIVES}
    out["count"] = a["collective_count"]
    out["total"] = a["coll_total"]
    return out
