"""Step builders + abstract input specs for every (arch x shape) cell.

One cell = (ArchConfig, shape kind) -> a jittable step function plus the
ShapeDtypeStruct stand-ins and NamedShardings for all its inputs. The SAME
builders power the real drivers (launch/train.py, launch/serve.py) and the
dry-run (launch/dryrun.py): what compiles in the dry-run is what runs.

Cell kinds:
  train    — EE joint-loss train step (fwd+bwd+AdamW, remat'd scan)
  prefill  — the full ATHEENA pipeline in one program: stage 1 -> exit
             decision -> conditional-buffer compaction -> stage 2 on the
             hard slab -> exit merge (core/early_exit.serve_batch)
  decode   — one token: stage 1 for the whole request batch, exit decision,
             stage 2 only for the persistent hard bucket (capacity = the
             conditional-buffer size from p)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import conditional as cond
from repro.core import early_exit as ee
from repro.core import exit_decision as ed
from repro.core.stage_mesh import stage2_capacity
from repro.core import losses
from repro.launch import shardings as sh
from repro.models import hints
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.optim import adamw

PAPER_P = 0.25          # design-time hard-sample probability (paper §IV-A)


@dataclass
class Cell:
    """Everything the dry-run / driver needs for one (arch x shape)."""
    name: str
    kind: str
    step_fn: Callable
    args: Tuple[Any, ...]               # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    donate: Tuple[int, ...] = ()
    meta: Dict[str, Any] = None


# ---------------------------------------------------------------------------
# frontend stubs
# ---------------------------------------------------------------------------

def frontend_len(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.frontend == "vit_stub":
        return cfg.n_frontend_tokens
    if cfg.encdec:
        return min(seq_len, 4096)       # audio frames (stubbed frontend)
    return 0


def _frontend_struct(cfg: ArchConfig, batch: int, seq_len: int):
    n = frontend_len(cfg, seq_len)
    if n == 0:
        return None
    return jax.ShapeDtypeStruct((batch, n, cfg.d_model), cfg.act_dtype())


# ---------------------------------------------------------------------------
# train cell
# ---------------------------------------------------------------------------

def make_train_cell(cfg: ArchConfig, mesh, *, seq_len: int, global_batch: int,
                    spec: Optional[ee.EarlyExitSpec] = None,
                    opt: Optional[adamw.AdamWConfig] = None,
                    fsdp: Optional[bool] = None) -> Cell:
    hints.set_mesh(mesh)
    spec = spec or ee.default_spec(cfg)
    opt = opt or adamw.AdamWConfig()
    p_shapes = ee.ee_param_shapes(cfg, spec)
    if fsdp is None:
        fsdp = sh.auto_fsdp(cfg, p_shapes, mesh)
    p_sh = sh.param_shardings(cfg, mesh, p_shapes, fsdp=fsdp)
    o_shapes = jax.eval_shape(functools.partial(adamw.init, opt), p_shapes)
    o_sh = sh.opt_shardings(cfg, mesh, p_shapes, fsdp=fsdp)
    tok_sh = sh.token_sharding(mesh, global_batch)
    fe = _frontend_struct(cfg, global_batch, seq_len)

    def loss_fn(params, tokens, labels, frontend):
        eh, fh, aux = ee.forward_train(params, cfg, spec, tokens,
                                       frontend_embeds=frontend)
        loss, parts = losses.branchynet_joint_loss(
            params, cfg, eh, fh, labels, spec.loss_weights, aux=aux)
        return loss, parts

    def train_step(params, opt_state, tokens, labels, frontend=None):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, tokens, labels, frontend)
        params, opt_state, om = adamw.update(opt, opt_state, params, grads)
        return params, opt_state, {"loss": loss, **parts, **om}

    tok = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    args = [p_shapes, o_shapes, tok, tok]
    shards = [p_sh, o_sh, tok_sh, tok_sh]
    if fe is not None:
        args.append(fe)
        shards.append(NamedSharding(
            mesh, P(sh.batch_spec(mesh, global_batch) or None, None, None)))
    return Cell(name=cfg.name, kind="train", step_fn=train_step,
                args=tuple(args), in_shardings=tuple(shards),
                donate=(0, 1), meta={"fsdp": fsdp, "exit_layer": spec.exit_layer})


# ---------------------------------------------------------------------------
# prefill cell — the one-program ATHEENA pipeline
# ---------------------------------------------------------------------------

def make_prefill_cell(cfg: ArchConfig, mesh, *, seq_len: int,
                      global_batch: int, p: float = PAPER_P,
                      spec: Optional[ee.EarlyExitSpec] = None,
                      fsdp: Optional[bool] = None) -> Cell:
    hints.set_mesh(mesh)
    spec = spec or ee.default_spec(cfg)
    p_shapes = ee.ee_param_shapes(cfg, spec)
    if fsdp is None:
        fsdp = sh.auto_fsdp(cfg, p_shapes, mesh)
    p_sh = sh.param_shardings(cfg, mesh, p_shapes, fsdp=fsdp)
    tok_sh = sh.token_sharding(mesh, global_batch)
    capacity = stage2_capacity(global_batch, p)
    fe = _frontend_struct(cfg, global_batch, seq_len)

    def serve_prefill(params, tokens, frontend=None):
        out = ee.serve_batch(params, cfg, spec, tokens, capacity=capacity,
                             frontend_embeds=frontend)
        return {"logits": out["logits"], "exit_mask": out["exit_mask"],
                "n_hard": out["n_hard"], "overflow": out["overflow"]}

    tok = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    args = [p_shapes, tok]
    shards = [p_sh, tok_sh]
    if fe is not None:
        args.append(fe)
        shards.append(NamedSharding(
            mesh, P(sh.batch_spec(mesh, global_batch) or None, None, None)))
    return Cell(name=cfg.name, kind="prefill", step_fn=serve_prefill,
                args=tuple(args), in_shardings=tuple(shards),
                meta={"fsdp": fsdp, "capacity": capacity,
                      "exit_layer": spec.exit_layer})


# ---------------------------------------------------------------------------
# decode cell — stage 1 full batch + stage 2 hard bucket
# ---------------------------------------------------------------------------

def make_decode_cell(cfg: ArchConfig, mesh, *, seq_len: int,
                     global_batch: int, p: float = PAPER_P,
                     spec: Optional[ee.EarlyExitSpec] = None,
                     fsdp: Optional[bool] = None) -> Cell:
    hints.set_mesh(mesh)
    spec = spec or ee.default_spec(cfg)
    p_shapes = ee.ee_param_shapes(cfg, spec)
    if fsdp is None:
        fsdp = sh.auto_fsdp(cfg, p_shapes, mesh)
    p_sh = sh.param_shardings(cfg, mesh, p_shapes, fsdp=fsdp)
    B = global_batch
    C = stage2_capacity(B, p) if B > 1 else 1
    xlen = frontend_len(cfg, seq_len) if cfg.encdec else 0

    c_full_b = T.cache_shapes(cfg, B, seq_len, xlen)
    s1_shapes, _ = ee.split_caches(cfg, spec, c_full_b)
    c_full_c = T.cache_shapes(cfg, C, seq_len, xlen)
    _, s2_shapes = ee.split_caches(cfg, spec, c_full_c)
    s1_sh = sh.cache_shardings(cfg, mesh, s1_shapes)
    s2_sh = sh.cache_shardings(cfg, mesh, s2_shapes)

    def serve_decode(params, tok_b, caches1, slab_idx, caches2, step):
        """One decode step of the two-stage pipeline. ``slab_idx`` is the
        admission-time hard-bucket assignment (request -> slab slot)."""
        h, nc1, exit_logits = ee.stage1_decode(params, cfg, spec, tok_b,
                                               caches1, step)
        exit_mask, pred, conf = ed.decision_and_argmax(exit_logits, spec.c_thr)
        h_slab = jnp.take(h, slab_idx, axis=0)            # (C, 1, d)
        final_logits, nc2 = ee.stage2_decode(params, cfg, spec, h_slab,
                                             caches2, step)
        return ({"exit_logits": exit_logits, "exit_mask": exit_mask,
                 "final_logits": final_logits}, nc1, nc2)

    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    idx = jax.ShapeDtypeStruct((C,), jnp.int32)
    step_s = jax.ShapeDtypeStruct((), jnp.int32)
    args = (p_shapes, tok, s1_shapes, idx, s2_shapes, step_s)
    shards = (p_sh, sh.token_sharding(mesh, B), s1_sh,
              sh.replicated(mesh), s2_sh, sh.replicated(mesh))
    return Cell(name=cfg.name, kind="decode", step_fn=serve_decode,
                args=args, in_shardings=shards, donate=(2, 4),
                meta={"fsdp": fsdp, "capacity": C,
                      "exit_layer": spec.exit_layer})


def make_cell(cfg: ArchConfig, mesh, shape: Dict[str, Any], **kw) -> Cell:
    kind = shape["kind"]
    if kind == "train":
        return make_train_cell(cfg, mesh, seq_len=shape["seq_len"],
                               global_batch=shape["global_batch"], **kw)
    if kind == "prefill":
        return make_prefill_cell(cfg, mesh, seq_len=shape["seq_len"],
                                 global_batch=shape["global_batch"], **kw)
    if kind == "decode":
        return make_decode_cell(cfg, mesh, seq_len=shape["seq_len"],
                                global_batch=shape["global_batch"], **kw)
    raise ValueError(kind)
