import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / collective analyses.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun
[--arch qwen2-7b] [--shape train_4k] [--multi-pod] [--json out.json]``.
The XLA_FLAGS line above precedes every other import (jax locks the device
count at first init); nothing else in the repo sets it globally.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro import compat
from repro.configs.archs import ARCHS, SHAPES, shape_applicable
from repro.launch import hlo as H
from repro.launch import hlo_analysis as HA
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, cell_kw: Optional[dict] = None,
             verbose: bool = True) -> Dict[str, Any]:
    """Lower+compile one cell; return its roofline record."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    t0 = time.time()
    cell = S.make_cell(cfg, mesh, shape, **(cell_kw or {}))
    with mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compat.xla_cost_analysis(compiled)
    text = compiled.as_text()
    # loop-aware analysis: XLA's cost_analysis counts while (lax.scan)
    # bodies ONCE; hlo_analysis multiplies by known trip counts.
    ha = HA.analyze(text)
    coll = {k.removeprefix("coll_"): v for k, v in ha.items()
            if k.startswith("coll_")}
    coll["count"] = ha["collective_count"]
    coll["total"] = ha["coll_total"]
    chips = mesh.size

    rec = {
        "arch": arch, "shape": shape_name, "kind": shape["kind"],
        "mesh": dict(mesh.shape), "chips": chips, "status": "ok",
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "capacity": cell.meta.get("capacity"),
        "exit_layer": cell.meta.get("exit_layer"),
        "fsdp": cell.meta.get("fsdp"),
        "flops": ha["flops"],
        "bytes_accessed": ha["bytes_accessed"],
        "xla_raw": {"flops": float(cost.get("flops", -1)),
                    "bytes_accessed": float(cost.get("bytes accessed", -1))},
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        },
    }
    # cost_analysis on the host backend reports PER-PROGRAM (per-device)
    # numbers; whole-job = per-device * chips for the roofline convention.
    samples = shape["global_batch"]
    rl = H.Roofline(
        name=arch, kind=shape["kind"], chips=chips,
        hlo_flops=rec["flops"] * chips,
        hlo_bytes=rec["bytes_accessed"] * chips,
        coll_bytes_per_chip=coll["total"],
        model_flops=H.model_flops(cfg, shape["kind"], shape["seq_len"],
                                  shape["global_batch"],
                                  exit_layer=cell.meta.get("exit_layer")),
        samples=samples,
    )
    rec["roofline"] = rl.as_dict()
    if verbose:
        m = rec["memory"]
        print(f"[{arch} x {shape_name} x {'multi' if multi_pod else 'single'}]"
              f" ok: lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"args/dev {_gb(m['argument_bytes'])} temp/dev "
              f"{_gb(m['temp_bytes'])} | t_comp {rl.t_compute:.4f}s t_mem "
              f"{rl.t_memory:.4f}s t_coll {rl.t_collective:.4f}s -> "
              f"{rl.bottleneck}-bound, useful-FLOPs {rl.useful_flops_frac:.1%}",
              flush=True)
    return rec


def _gb(x) -> str:
    return f"{x / 1e9:.2f}GB" if isinstance(x, (int, float)) else "n/a"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None, help="write records to this file")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    failures = 0
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for a in archs:
            for s in shapes:
                try:
                    records.append(run_cell(a, s, multi_pod=mp, mesh=mesh))
                except Exception:
                    failures += 1
                    traceback.print_exc()
                    records.append({"arch": a, "shape": s,
                                    "multi_pod": mp, "status": "failed"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} documented skips, "
          f"{failures} FAILED")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
