"""Divisibility-aware sharding planner.

Maps every parameter / optimizer / cache / input leaf to a PartitionSpec on
the production mesh, by leaf NAME (the einsum role decides the axis) with a
hard divisibility check against the actual leaf SHAPE — jax rejects uneven
shards, and several assigned configs have awkward dims (vocab 50280/92553/
256206 not % 16; grok has 8 experts on a 16-way model axis), so every rule
carries an explicit fallback chain:

  column-parallel (d -> X projections)   last dim over "model"
  row-parallel    (X -> d projections)   dim -2 over "model"
  embedding table (V, d)                 V over "model", else REPLICATE
                                         (replicated table beats d-sharding:
                                         d is the unembed contraction, and
                                         sharding it would all-reduce the
                                         (B,S,V) fp32 logits every step)
  MoE experts (E, d, f)                  E over "model" (true EP), else the
                                         ff dim (expert-sliced TP — grok)
  norms / scalars / router               replicated
  FSDP (opt-in)                          additionally shard the largest
                                         remaining dim over "data"

Leading scan (layer-stack) dims are never sharded.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, batch_axes
from repro.models.config import ArchConfig

# leaf-name roles ------------------------------------------------------------
_COL = {"wq", "wk", "wv", "wi", "wi_gate", "wi_up", "w_in", "w_x",
        "w_in_gate", "w_gate", "w_rec_gate", "w_dkv", "w_uk", "w_uv",
        "head", "bq", "bk", "bv", "conv_w", "conv_b"}
_ROW = {"wo", "w_out"}
_MOE = {"e_gate", "e_up", "e_down"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _n_scan_dims(path) -> int:
    """blocks[...] stacks carry one leading layer dim."""
    s = jax.tree_util.keystr(path)
    return 1 if s.startswith("['blocks']") or "['backbone']['blocks']" in s \
        or "['encoder']['blocks']" in s else 0


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def param_spec(path, shape: Tuple[int, ...], mesh, *, fsdp: bool = False
               ) -> P:
    """PartitionSpec for one parameter leaf."""
    m = axis_size(mesh, "model")
    d = axis_size(mesh, "data")
    name = _leaf_name(path)
    lead = _n_scan_dims(path)
    nd = len(shape)
    parts: list = [None] * nd

    def assign(axis_idx: int, mesh_axis: str, size: int) -> bool:
        i = axis_idx if axis_idx >= 0 else nd + axis_idx
        if i >= lead and parts[i] is None and _div(shape[i], size):
            parts[i] = mesh_axis
            return True
        return False

    if name == "table":                      # embedding (V, d)
        assign(-2, "model", m)               # else replicate (see module doc)
    elif name in _MOE and nd - lead == 3:    # (E, d|f, f|d)
        if not assign(-3, "model", m):       # true expert parallel
            # expert-sliced TP: shard the ff dim (dim -1 for gate/up, -2 down)
            assign(-1 if name != "e_down" else -2, "model", m)
    elif name in _COL and nd - lead >= 1:
        assign(-1, "model", m)
    elif name in _ROW and nd - lead >= 2:
        assign(-2, "model", m)
    # else: replicate (norm scales, router, A_log, Lambda, ...)

    if fsdp:
        # shard the largest remaining dim over "data" (ZeRO-3-style layout)
        cands = [(shape[i], i) for i in range(lead, nd)
                 if parts[i] is None and _div(shape[i], d) and shape[i] >= d]
        if cands:
            parts[max(cands)[1]] = "data"
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(cfg: ArchConfig, mesh, shapes, *, fsdp: bool = False):
    """NamedSharding pytree matching a param-shape pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    out = [NamedSharding(mesh, param_spec(p, leaf.shape, mesh, fsdp=fsdp))
           for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def auto_fsdp(cfg: ArchConfig, shapes, mesh, *, hbm_budget_gb: float = 8.0
              ) -> bool:
    """Enable FSDP when TP-only params exceed the per-chip budget."""
    m = axis_size(mesh, "model")
    total = sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
                for leaf in jax.tree.leaves(shapes))
    return (total / m) / 1e9 > hbm_budget_gb


# -- optimizer state (ZeRO-1) -------------------------------------------------

def opt_shardings(cfg: ArchConfig, mesh, param_shapes_tree, *,
                  fsdp: bool = False):
    """AdamWState sharding: moments take the param spec with the largest
    remaining dim additionally sharded over 'data' (ZeRO-1)."""
    from repro.optim.adamw import AdamWState

    d = axis_size(mesh, "data")
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes_tree)

    def moment(path, leaf):
        spec = param_spec(path, leaf.shape, mesh, fsdp=fsdp)
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        lead = _n_scan_dims(path)
        if "data" not in parts:
            cands = [(leaf.shape[i], i) for i in range(lead, len(parts))
                     if parts[i] is None and _div(leaf.shape[i], d)
                     and leaf.shape[i] >= d]
            if cands:
                parts[max(cands)[1]] = "data"
        return NamedSharding(mesh, P(*parts))

    m_sh = jax.tree_util.tree_unflatten(
        treedef, [moment(p, l) for p, l in flat])
    return AdamWState(step=NamedSharding(mesh, P()), m=m_sh, v=m_sh, err=None)


# -- inputs / caches -----------------------------------------------------------

def batch_spec(mesh, global_batch: int) -> Tuple[str, ...]:
    """Largest prefix of ('pod','data') that divides the batch."""
    axes = batch_axes(mesh)
    while axes:
        if _div(global_batch, int(
                jnp.prod(jnp.array([axis_size(mesh, a) for a in axes])))):
            return axes
        axes = axes[:-1]
    return ()


def token_sharding(mesh, global_batch: int) -> NamedSharding:
    bspec = batch_spec(mesh, global_batch)
    return NamedSharding(mesh, P(bspec if bspec else None, None))


def cache_shardings(cfg: ArchConfig, mesh, cache_shapes_tree):
    """Decode-cache sharding: batch over ('pod','data') when divisible; the
    cache TIME axis over 'model' (sequence-parallel cache — softmax stats
    all-reduce over model, the standard long-context decode layout). MLA
    latent/rope and recurrent states follow the same batch rule."""
    m = axis_size(mesh, "model")
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes_tree)

    def one(path, leaf):
        if leaf is None:
            return None
        name = _leaf_name(path)
        lead = 1 if "['blocks']" in jax.tree_util.keystr(path) else 0
        shape = leaf.shape
        nd = len(shape)
        parts: list = [None] * nd
        bdim = lead          # batch dim position
        bspec = batch_spec(mesh, shape[bdim]) if bdim < nd else ()
        if bspec:
            parts[bdim] = bspec
        # time axis: k/v -> -3; latent/k_rope/xk/xv -> -2
        tdim = None
        if name in ("k", "v", "xk", "xv"):
            tdim = nd - 3
        elif name in ("latent", "k_rope"):
            tdim = nd - 2
        if tdim is not None and tdim > bdim and _div(shape[tdim], m):
            parts[tdim] = "model"
        while parts and parts[-1] is None:
            parts.pop()
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_unflatten(treedef,
                                        [one(p, l) for p, l in flat])


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# -- stage submeshes (two-stage EE serving) -----------------------------------

def stage_io_shardable(mesh, global_batch: int) -> bool:
    """Whether a stage submesh can shard its full-rate IO batch over its
    'data' axis (the same divisibility rule as ``batch_spec``). The serve
    driver uses this to decide each StageExecutor's ``shard_io`` — an
    indivisible batch replicates rather than erroring."""
    return bool(batch_spec(mesh, global_batch))
