"""Serving driver: ``PYTHONPATH=src python -m repro.launch.serve
--arch qwen2-1.5b --smoke --requests 256 [--mode decode]``.

``--mode prefill`` (default) builds the device-resident two-stage EE
server (stage 1 full rate, stage 2 bucketed at capacity = ceil((p+slack)·B),
hard samples carried between batches in the device ring buffer) and pushes
batched requests with a controlled hard-fraction q.

``--mode decode`` serves open-loop decode requests (Poisson arrivals at
``--arrival-rate``, default: all at t=0) under a scheduling policy:

  * ``--scheduler sync`` (default): static batch formation over the
    step-synchronous ``DecodeServer`` — full-depth prefill per batch, then
    per-token two-stage decode in lockstep, hard tokens' hidden rows +
    stage-2 KV-cache segment rows through the pytree ring into bucketed
    stage-2 dispatches;
  * ``--scheduler continuous``: the slot-based ``ContinuousScheduler``
    (``runtime/scheduler.py``) — a fixed pool of ``--batch`` decode slots
    with per-slot step counters, backfilled from the admission queue; easy
    samples keep decoding through stage 1 while hard tokens wait in the
    ring for bucketed stage-2 dispatch. Trades the sync policy's bitwise
    batch parity for utilization; per-sample token streams stay identical.

Reports goodput (decode tokens/s), per-request latency percentiles and
per-token stats — the runtime half of the ATHEENA pipeline in both
regimes.

``--controller`` attaches the online drift control plane
(``runtime/controller.py``) to the decode scheduler: when the EWMA of the
realized hard rate q drifts persistently outside ``--controller-band``
around the provisioned ``--p``, C_thr is re-solved online from the rolling
confidence reservoir (and the scheduler's drain policy / live-slot cap
adapt from latency+occupancy feedback); past the re-plan band the Eq. (1)
stage re-plan is reported, and APPLIED under ``--controller-replan``: on a
disaggregated continuous scheduler the full chip re-split executes as a
zero-downtime live migration (``runtime/migration.py`` — quiesce /
snapshot / re-place / resume, rolled back on failure), otherwise the
bucket-capacity half applies alone. The controller's state machine report
and the migration counters (``n_migrations``, ``n_migration_rollbacks``,
``migration_pause_p50_ms/p99_ms``) ride in the output JSON.

Fault injection (chaos testing): set ``REPRO_FAULT_PLAN`` to a plan like
``dispatch@3;transfer@2#transient`` (``point@nth[#transient]`` entries —
see ``runtime/faults.py``) to arm deterministic faults at the runtime's
dispatch/enqueue/transfer/migration boundaries; ``REPRO_FAULT_LOG=<path>``
appends the structured injection/retry/rollback log as JSON lines at
exit.

``--disaggregate`` places the two stages on disjoint submeshes (the paper's
§IV spatial apportionment): stage 1 + the exit kernels on the first chips1
devices, the ring + stage 2 on the next chips2, with ``--chips1/--chips2``
defaulting to the p-proportional split of the local device set. Needs >= 2
devices — on a CPU host export
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` first.

Observability (``runtime/observe.py``; all opt-in, zero-cost when off):
``--metrics-port N`` serves Prometheus text exposition on
``127.0.0.1:N/metrics`` for the whole run (0 = ephemeral port; the CLI
self-scrapes once before exit and asserts the exposition parses);
``--metrics-dump FILE`` writes one exposition snapshot at end of run;
``--spans-out FILE`` / ``--trace-out FILE`` export the per-request span
trees as JSONL / Chrome ``trace_event`` JSON (open the latter in Perfetto
or chrome://tracing); ``--profile-dir DIR`` captures a ``jax.profiler``
trace window for the first ``--profile-ticks`` scheduler ticks. Span
tracing and profiling need a scheduler (decode mode); the metrics flags
work in every mode."""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional

import jax
import numpy as np

from repro.core import early_exit as ee
from repro.core.stage_mesh import StageMeshPlan, stage2_capacity
from repro.launch.mesh import stage_submeshes
from repro.launch.shardings import stage_io_shardable
from repro.models.registry import get_arch, get_smoke, list_archs
from repro.runtime import serve_api
from repro.runtime import serve_loop as SL
from repro.runtime.controller import ControllerConfig, DriftController
from repro.runtime.router import ROUTING_POLICIES, FleetRouter
from repro.runtime.scheduler import Clock, Request, poisson_arrivals
from repro.runtime.stage_executor import StageExecutor, StagePlacement


def make_placement(p: float, batch: int, chips1: Optional[int] = None,
                   chips2: Optional[int] = None,
                   devices=None) -> StagePlacement:
    """Build the disaggregated placement for the serve CLI: explicit chip
    counts when given, otherwise the p-proportional apportionment over the
    local device set. Each stage's IO shards over its submesh 'data' axis
    when the batch divides it (launch.shardings rule)."""
    devs = jax.devices() if devices is None else devices
    plan = StageMeshPlan.resolve(p, len(devs), chips1, chips2)
    m1, m2 = stage_submeshes(plan, devs)
    return StagePlacement(
        StageExecutor(m1, shard_io=stage_io_shardable(m1, batch),
                      name="stage1"),
        StageExecutor(m2, shard_io=stage_io_shardable(m2, batch),
                      name="stage2"))


def _summarized_stats(stats) -> dict:
    """ServeStats.as_dict with the per-dispatch realized_q series reduced
    to a summary (mean + tail) — one entry per pool tick is a drift-signal
    feed, not a CLI report line."""
    d = stats.as_dict()
    series = d.pop("realized_q_series")
    d["realized_q_series_mean"] = (float(np.mean(series)) if series
                                   else 0.0)
    d["realized_q_series_tail"] = series[-8:]
    return d


def _parse_tenant_slos(spec: Optional[str]) -> dict:
    """'web=gold,offline=batch' -> {'web': 'gold', 'offline': 'batch'}."""
    if not spec:
        return {"default": "standard"}
    out = {}
    for pair in spec.split(","):
        tenant, _, slo = pair.partition("=")
        if not tenant or not slo:
            raise SystemExit(f"--tenant-slos entry {pair!r} is not "
                             f"tenant=slo_class")
        out[tenant.strip()] = slo.strip()
    return out


def _setup_observability(args):
    """Build the observability plane for this run, or None when every flag
    is off (the schedulers then carry no event feed at all)."""
    wants = (args.metrics_port is not None or args.metrics_dump
             or args.trace_out or args.spans_out or args.profile_dir)
    if not wants:
        return None
    from repro.runtime import observe
    from repro.runtime.telemetry import EventLog
    registry = observe.MetricsRegistry()
    return {"observe": observe, "registry": registry,
            "tracer": observe.Tracer(),
            "sampler": observe.StatsSampler(registry),
            "make_events": lambda: EventLog(cap=65536),
            "server": None}


def _start_metrics_server(args, obs):
    """Open the background /metrics endpoint for the run's duration."""
    if obs is None or args.metrics_port is None:
        return
    observe = obs["observe"]
    obs["server"] = observe.MetricsServer(
        obs["registry"], obs["sampler"], port=args.metrics_port).start()
    # stderr: stdout carries the one JSON payload consumers parse
    print(f"# metrics: http://127.0.0.1:{obs['server'].port}/metrics",
          file=sys.stderr)


def _finalize_observability(args, obs, expect_sids=None) -> dict:
    """Final sample + self-scrape + exports. Returns the JSON block the
    payload carries under "observability"."""
    observe = obs["observe"]
    registry, sampler, tracer = obs["registry"], obs["sampler"], obs["tracer"]
    sampler.sample()
    out = {}
    srv = obs["server"]
    if srv is not None:
        import urllib.request
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/metrics",
                    timeout=10) as resp:
                text = resp.read().decode()
            # raises on malformed exposition — the CI smoke contract
            out["metrics_scrape_samples"] = len(
                observe.parse_exposition(text))
            out["metrics_port"] = srv.port
        finally:
            srv.stop()
    if args.metrics_dump:
        observe.dump_metrics(registry, args.metrics_dump)
        out["metrics_dump"] = args.metrics_dump
    sampler.close()
    tracer.close()
    comp = tracer.completeness(expect_sids)
    out["spans_complete"] = comp["complete"]
    out["n_spans"] = comp["n_spans"]
    out["n_span_annotations"] = comp["n_annotations"]
    if args.spans_out:
        out["n_span_lines"] = tracer.export_jsonl(args.spans_out)
        out["spans_out"] = args.spans_out
    if args.trace_out:
        out["n_trace_events"] = tracer.export_chrome_trace(args.trace_out)
        out["trace_out"] = args.trace_out
    return out


def _maybe_profile(args, obs, events):
    """Context for the serving loop: a jax.profiler window when
    --profile-dir is set, nullcontext otherwise."""
    import contextlib
    if obs is None or not args.profile_dir:
        return contextlib.nullcontext()
    return obs["observe"].ProfileWindow(args.profile_dir,
                                        n_ticks=args.profile_ticks,
                                        events=events)


def _serve_fleet(args, cfg, spec, params, sc, placement) -> int:
    """Decode serving through a FleetRouter over --replicas continuous
    schedulers sharing one clock; requests cycle over the --tenant-slos
    tenants. Prints the FleetStats schema (per-replica ServeStats
    embedded, q series summarized)."""
    if args.scheduler != "continuous":
        raise SystemExit("--replicas > 1 routes over continuous-scheduler "
                         "replicas; pass --scheduler continuous")
    tenant_slos = _parse_tenant_slos(args.tenant_slos)
    tenants = list(tenant_slos)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.seq), 0, cfg.vocab))
    max_len = args.seq + args.decode_tokens
    clock = Clock()
    obs = _setup_observability(args)
    replicas = [serve_api.build(params, cfg, spec, sc, mode="decode",
                                scheduler="continuous", placement=placement,
                                n_slots=args.batch, max_len=max_len,
                                page_size=args.page_size,
                                n_pages=args.n_pages, clock=clock,
                                events=(obs["make_events"]() if obs
                                        else None))
                for _ in range(args.replicas)]
    router = FleetRouter(replicas, policy=args.routing_policy,
                         provisioned_p=[args.p] * args.replicas)
    if obs is not None:
        for r_i, rep in enumerate(replicas):
            obs["tracer"].attach_scheduler(rep, replica=r_i)
            obs["sampler"].attach_scheduler(rep, replica=r_i)
        obs["tracer"].attach_router(router)
        obs["tracer"].attach_faults()
        obs["sampler"].attach_router(router)
        _start_metrics_server(args, obs)
    arrivals = poisson_arrivals(args.requests, args.arrival_rate, seed=2)
    for i in range(args.requests):
        tenant = tenants[i % len(tenants)]
        router.submit(Request(sample_id=i, prompt=prompts[i],
                              n_tokens=args.decode_tokens,
                              arrival_time=float(arrivals[i]),
                              tenant=tenant,
                              slo_class=tenant_slos[tenant]))
    with _maybe_profile(args, obs, replicas[0].events):
        results = router.run()
    makespan = router.clock.now()
    assert len(results) == args.requests
    assert all(len(v) == args.decode_tokens for v in results.values())
    n_tok = sum(len(v) for v in results.values())
    fleet = router.stats.as_dict()
    fleet["replicas"] = [dict(r, realized_q_series_tail=r.pop(
        "realized_q_series")[-8:]) for r in fleet["replicas"]]
    payload = {"arch": args.arch, "mode": "decode", "scheduler": "fleet",
               "routing_policy": args.routing_policy,
               "n_replicas": args.replicas, "capacity": sc.capacity,
               "n_slots": args.batch, "arrival_rate": args.arrival_rate,
               "goodput_tokens_per_s": n_tok / makespan, **fleet}
    if obs is not None:
        payload["observability"] = _finalize_observability(
            args, obs, expect_sids=set(range(args.requests)))
    print(json.dumps(payload, indent=1, default=float))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="prefill",
                    choices=("prefill", "decode"))
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64,
                    help="request length (prompt length in decode mode)")
    ap.add_argument("--decode-tokens", type=int, default=32,
                    help="tokens to generate per request (decode mode)")
    ap.add_argument("--scheduler", default="sync",
                    choices=("sync", "continuous"),
                    help="decode scheduling policy: static batch formation "
                         "over the step-synchronous server, or the "
                         "slot-based continuous scheduler")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve decode through a FleetRouter over N "
                         "replica schedulers (1 = single-replica, no "
                         "router). Each replica gets its own scheduler; "
                         "all share one clock")
    ap.add_argument("--routing-policy", default="drift_aware",
                    choices=ROUTING_POLICIES,
                    help="fleet routing policy (--replicas > 1): "
                         "round_robin, least_loaded (occupancy + queue "
                         "depth), or drift_aware (match tenant difficulty "
                         "to per-replica provisioned p vs realized q)")
    ap.add_argument("--tenant-slos", default=None,
                    help="comma-separated tenant=slo_class pairs (classes: "
                         "gold/standard/batch), e.g. 'web=gold,batch=batch'."
                         " Requests cycle over the listed tenants; default: "
                         "one 'default' tenant at standard")
    ap.add_argument("--page-size", type=int, default=None,
                    help="enable the paged KV cache with this page size "
                         "(decode modes; seq+decode-tokens must be a "
                         "multiple)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="page-pool capacity in pages (continuous paged "
                         "mode; default: dense-equivalent "
                         "n_slots*max_len/page — shrink it to trade "
                         "admission backpressure for HBM)")
    ap.add_argument("--arrival-rate", type=float, default=float("inf"),
                    help="open-loop Poisson request rate (req/s) for decode "
                         "mode; inf = all requests arrive at t=0")
    ap.add_argument("--p", type=float, default=0.25,
                    help="design-time hard probability (sizes stage 2)")
    ap.add_argument("--c-thr", type=float, default=0.9)
    ap.add_argument("--controller", action="store_true",
                    help="attach the online drift controller (decode "
                         "mode): closed-loop C_thr re-calibration + "
                         "scheduler autoscaling against the provisioned "
                         "--p")
    ap.add_argument("--controller-band", type=float, default=0.05,
                    help="hysteresis band on |EWMA(q) - p| before the "
                         "controller actuates")
    ap.add_argument("--controller-cooldown", type=int, default=8,
                    help="controller visits to hold after an actuation")
    ap.add_argument("--controller-slo-p99", type=float, default=None,
                    help="p99 latency SLO (s) for the autoscaler's "
                         "live-slot occupancy cap (default: no cap "
                         "control)")
    ap.add_argument("--controller-replan", action="store_true",
                    help="APPLY the stage re-plan at discrete re-plan "
                         "points (default: report only): a zero-downtime "
                         "live migration of the full chip split on a "
                         "disaggregated continuous scheduler, else the "
                         "bucket-capacity half alone")
    ap.add_argument("--disaggregate", action="store_true",
                    help="stage 1 / stage 2 on disjoint submeshes")
    ap.add_argument("--chips1", type=int, default=None,
                    help="stage-1 submesh size (default: p-proportional)")
    ap.add_argument("--chips2", type=int, default=None,
                    help="stage-2 submesh size (default: p-proportional)")
    grp = ap.add_argument_group("observability (runtime/observe.py)")
    grp.add_argument("--metrics-port", type=int, default=None,
                     help="serve Prometheus text exposition on "
                          "127.0.0.1:PORT/metrics for the run (0 = "
                          "ephemeral port, printed at startup); the CLI "
                          "self-scrapes once before exit and asserts the "
                          "exposition parses")
    grp.add_argument("--metrics-dump", default=None, metavar="FILE",
                     help="write one Prometheus exposition snapshot to "
                          "FILE at end of run")
    grp.add_argument("--spans-out", default=None, metavar="FILE",
                     help="export per-request span trees + annotations as "
                          "JSONL (decode schedulers)")
    grp.add_argument("--trace-out", default=None, metavar="FILE",
                     help="export the span trees as Chrome trace_event "
                          "JSON — open in Perfetto / chrome://tracing "
                          "(decode schedulers)")
    grp.add_argument("--profile-dir", default=None, metavar="DIR",
                     help="capture a jax.profiler trace window (xprof) "
                          "into DIR")
    grp.add_argument("--profile-ticks", type=int, default=64,
                     help="scheduler ticks to keep the --profile-dir "
                          "window open (default 64)")
    args = ap.parse_args(argv)

    if args.mode == "prefill" and (args.trace_out or args.spans_out
                                   or args.profile_dir):
        raise SystemExit("span tracing / profiling rides the decode "
                         "schedulers' event feed — use --mode decode "
                         "(prefill supports the metrics flags)")

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    spec = ee.default_spec(cfg, c_thr=args.c_thr)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    cap = stage2_capacity(args.batch, args.p)
    sc = SL.ServeConfig(capacity=cap, c_thr=args.c_thr)

    placement = None
    if (args.disaggregate or args.chips1 is not None
            or args.chips2 is not None):
        placement = make_placement(args.p, args.batch, args.chips1,
                                   args.chips2)
        print(f"# {placement}")

    if args.mode == "decode" and args.replicas > 1:
        return _serve_fleet(args, cfg, spec, params, sc, placement)

    if args.mode == "decode":
        prompts = np.asarray(jax.random.randint(
            jax.random.PRNGKey(1), (args.requests, args.seq), 0, cfg.vocab))
        max_len = args.seq + args.decode_tokens
        obs = _setup_observability(args)
        sched = serve_api.build(params, cfg, spec, sc, mode="decode",
                                scheduler=args.scheduler,
                                placement=placement, n_slots=args.batch,
                                max_len=max_len,
                                page_size=args.page_size,
                                n_pages=args.n_pages,
                                events=(obs["make_events"]() if obs
                                        else None))
        if obs is not None:
            obs["tracer"].attach_scheduler(sched)
            obs["tracer"].attach_faults()
            obs["sampler"].attach_scheduler(sched)
            _start_metrics_server(args, obs)
        controller = None
        if args.controller:
            controller = DriftController(ControllerConfig(
                provisioned_p=args.p, target_band=args.controller_band,
                release_band=args.controller_band / 2,
                # keep the escalation band valid (>= target) when the user
                # widens the hysteresis band past the 0.15 default
                replan_band=max(0.15, 3 * args.controller_band),
                cooldown_ticks=args.controller_cooldown,
                latency_slo_p99=args.controller_slo_p99,
                apply_replan=args.controller_replan))
            controller.attach(sched)
        arrivals = poisson_arrivals(args.requests, args.arrival_rate, seed=2)
        for i in range(args.requests):
            sched.submit(Request(sample_id=i, prompt=prompts[i],
                                 n_tokens=args.decode_tokens,
                                 arrival_time=float(arrivals[i])))
        with _maybe_profile(args, obs, sched.events):
            results = sched.run()
        makespan = sched.clock.now()
        assert len(results) == args.requests
        assert all(len(v) == args.decode_tokens for v in results.values())
        n_tok = sum(len(v) for v in results.values())
        stats = _summarized_stats(sched.stats)
        payload = {"arch": args.arch, "mode": "decode",
                   "scheduler": args.scheduler, "capacity": cap,
                   "n_slots": args.batch,
                   "arrival_rate": args.arrival_rate,
                   "goodput_tokens_per_s": n_tok / makespan,
                   **stats}
        if controller is not None:
            payload["controller"] = controller.state.as_dict()
        if obs is not None:
            payload["observability"] = _finalize_observability(
                args, obs, expect_sids=set(range(args.requests)))
        print(json.dumps(payload, indent=1, default=float))
        return 0

    obs = _setup_observability(args)
    server = serve_api.build(params, cfg, spec, sc, mode="prefill",
                             scheduler=None, placement=placement)
    if obs is not None:
        obs["sampler"].attach_scheduler(server)   # stats-only (no events)
        _start_metrics_server(args, obs)
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.seq), 0, cfg.vocab))
    t0 = time.perf_counter()
    results = SL.serve_dataset(server, toks, batch=args.batch)
    dt = time.perf_counter() - t0
    assert len(results) == args.requests
    stats = _summarized_stats(server.stats)
    payload = {"arch": args.arch, "mode": "prefill", "capacity": cap,
               "throughput_samples_per_s": args.requests / dt, **stats}
    if obs is not None:
        payload["observability"] = _finalize_observability(args, obs)
    print(json.dumps(payload, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
