"""Serving driver: ``PYTHONPATH=src python -m repro.launch.serve
--arch qwen2-1.5b --smoke --requests 256``.

Builds the device-resident two-stage EE server (stage 1 full rate, stage 2
bucketed at capacity = ceil((p+slack)·B), hard samples carried between
batches in the device ring buffer), pushes batched requests with a
controlled hard-fraction q, and reports throughput + stage-2 occupancy —
the runtime half of the ATHEENA pipeline."""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import early_exit as ee
from repro.core.stage_mesh import stage2_capacity
from repro.models.registry import get_arch, get_smoke, list_archs
from repro.runtime import serve_loop as SL


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--p", type=float, default=0.25,
                    help="design-time hard probability (sizes stage 2)")
    ap.add_argument("--c-thr", type=float, default=0.9)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    spec = ee.default_spec(cfg, c_thr=args.c_thr)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    cap = stage2_capacity(args.batch, args.p)
    server = SL.build_server(params, cfg, spec,
                             SL.ServeConfig(capacity=cap, c_thr=args.c_thr))

    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.seq), 0, cfg.vocab))
    t0 = time.perf_counter()
    results = SL.serve_dataset(server, toks, batch=args.batch)
    dt = time.perf_counter() - t0
    assert len(results) == args.requests
    stats = server.stats.as_dict()
    print(json.dumps({"arch": args.arch, "capacity": cap,
                      "throughput_samples_per_s": args.requests / dt,
                      **stats}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
