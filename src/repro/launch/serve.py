"""Serving driver: ``PYTHONPATH=src python -m repro.launch.serve
--arch qwen2-1.5b --smoke --requests 256 [--mode decode]``.

``--mode prefill`` (default) builds the device-resident two-stage EE
server (stage 1 full rate, stage 2 bucketed at capacity = ceil((p+slack)·B),
hard samples carried between batches in the device ring buffer) and pushes
batched requests with a controlled hard-fraction q.

``--mode decode`` builds the decode-time ``DecodeServer``: full-depth
prefill of the prompts, then per-token two-stage decode where hard tokens'
hidden rows + stage-2 KV-cache segment rows travel the pytree ring into
bucketed stage-2 dispatches. Reports decode tokens/s + per-token stats —
the runtime half of the ATHEENA pipeline in both regimes.

``--disaggregate`` places the two stages on disjoint submeshes (the paper's
§IV spatial apportionment): stage 1 + the exit kernels on the first chips1
devices, the ring + stage 2 on the next chips2, with ``--chips1/--chips2``
defaulting to the p-proportional split of the local device set. Needs >= 2
devices — on a CPU host export
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` first."""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import numpy as np

from repro.core import early_exit as ee
from repro.core.stage_mesh import StageMeshPlan, stage2_capacity
from repro.launch.mesh import stage_submeshes
from repro.launch.shardings import stage_io_shardable
from repro.models.registry import get_arch, get_smoke, list_archs
from repro.runtime import serve_loop as SL
from repro.runtime.stage_executor import StageExecutor, StagePlacement


def make_placement(p: float, batch: int, chips1: Optional[int] = None,
                   chips2: Optional[int] = None,
                   devices=None) -> StagePlacement:
    """Build the disaggregated placement for the serve CLI: explicit chip
    counts when given, otherwise the p-proportional apportionment over the
    local device set. Each stage's IO shards over its submesh 'data' axis
    when the batch divides it (launch.shardings rule)."""
    devs = jax.devices() if devices is None else devices
    plan = StageMeshPlan.resolve(p, len(devs), chips1, chips2)
    m1, m2 = stage_submeshes(plan, devs)
    return StagePlacement(
        StageExecutor(m1, shard_io=stage_io_shardable(m1, batch),
                      name="stage1"),
        StageExecutor(m2, shard_io=stage_io_shardable(m2, batch),
                      name="stage2"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="prefill",
                    choices=("prefill", "decode"))
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64,
                    help="request length (prompt length in decode mode)")
    ap.add_argument("--decode-tokens", type=int, default=32,
                    help="tokens to generate per request (decode mode)")
    ap.add_argument("--p", type=float, default=0.25,
                    help="design-time hard probability (sizes stage 2)")
    ap.add_argument("--c-thr", type=float, default=0.9)
    ap.add_argument("--disaggregate", action="store_true",
                    help="stage 1 / stage 2 on disjoint submeshes")
    ap.add_argument("--chips1", type=int, default=None,
                    help="stage-1 submesh size (default: p-proportional)")
    ap.add_argument("--chips2", type=int, default=None,
                    help="stage-2 submesh size (default: p-proportional)")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    spec = ee.default_spec(cfg, c_thr=args.c_thr)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec)
    cap = stage2_capacity(args.batch, args.p)
    sc = SL.ServeConfig(capacity=cap, c_thr=args.c_thr)

    placement = None
    if (args.disaggregate or args.chips1 is not None
            or args.chips2 is not None):
        placement = make_placement(args.p, args.batch, args.chips1,
                                   args.chips2)
        print(f"# {placement}")

    if args.mode == "decode":
        server = SL.build_decode_server(params, cfg, spec, sc, placement)
        prompts = np.asarray(jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.seq), 0, cfg.vocab))
        t0 = time.perf_counter()
        out = server.generate(prompts, args.decode_tokens)
        dt = time.perf_counter() - t0
        assert out["tokens"].shape == (args.batch, args.decode_tokens)
        n_decode = args.batch * (args.decode_tokens - 1)
        print(json.dumps({"arch": args.arch, "mode": "decode",
                          "capacity": cap,
                          "decode_tokens_per_s": n_decode / dt,
                          **server.stats.as_dict()}, indent=1))
        return 0

    server = SL.build_server(params, cfg, spec, sc, placement)
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.seq), 0, cfg.vocab))
    t0 = time.perf_counter()
    results = SL.serve_dataset(server, toks, batch=args.batch)
    dt = time.perf_counter() - t0
    assert len(results) == args.requests
    stats = server.stats.as_dict()
    print(json.dumps({"arch": args.arch, "mode": "prefill", "capacity": cap,
                      "throughput_samples_per_s": args.requests / dt,
                      **stats}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
