"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; only launch/dryrun.py forces the 512 host-device platform.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (degraded/elastic shapes, e.g. (15, 16))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def stage_submeshes(plan, devices=None):
    """Two disjoint (data, model) submeshes for a two-stage EE deployment
    (core.stage_mesh.StageMeshPlan), defaulting to the local device set —
    the launch-layer entry the serve driver and examples build their
    ``StagePlacement`` from."""
    from repro.core.stage_mesh import make_stage_meshes
    return make_stage_meshes(jax.devices() if devices is None else devices,
                             plan)


def batch_axes(mesh) -> Tuple[str, ...]:
    """Axes the global batch shards over: ('pod','data') when a pod axis
    exists, else ('data',)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
