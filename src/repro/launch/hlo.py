"""HLO text analysis: collective bytes + roofline terms from a compiled
artifact. No jax device state touched here — safe to import anywhere.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e hardware constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shaped array literal, e.g.  bf16[16,4096,1536]{2,1,0}
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*(?:e\dm\d(?:fn)?)?)\[([\d,]*)\]")
# a collective op line: "%name = <result type> <op>(" — -start variants
# counted, -done skipped (same transfer)
_OP_RE = re.compile(
    r"=\s*(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_DONE_RE = re.compile(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)-done\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes per collective kind, summed over ops (OUTPUT shape convention —
    the payload a chip receives). HLO from compiled.as_text() is already
    per-device partitioned, so shapes are per-chip."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if _DONE_RE.search(line):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class Roofline:
    """Three-term roofline for one compiled (arch x shape x mesh) cell.
    Terms are SECONDS for one step of the lowered program."""
    name: str
    kind: str
    chips: int
    hlo_flops: float                 # whole-program FLOPs (all chips)
    hlo_bytes: float                 # whole-program HBM traffic (all chips)
    coll_bytes_per_chip: float       # per-chip collective payload
    model_flops: float = 0.0         # 6*N*D useful FLOPs
    samples: int = 0
    extra: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilisation at the roofline bound (the score): useful
        FLOPs / (chips * peak * bound-time)."""
        t = self.t_bound
        return (self.model_flops / (self.chips * PEAK_FLOPS * t)) if t else 0.0

    @property
    def throughput(self) -> float:
        return self.samples / self.t_bound if self.t_bound else 0.0

    def as_dict(self) -> dict:
        return {
            "name": self.name, "kind": self.kind, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu_bound": self.mfu_bound, "samples": self.samples,
            "throughput": self.throughput, **self.extra,
        }


def model_flops(cfg, kind: str, seq_len: int, batch: int,
                exit_layer: Optional[int] = None, p: float = 0.25) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful-FLOPs convention.
    train: 6ND. prefill: 2ND. decode: 2N per token. For EE serving cells,
    stage-2 params count only for the hard fraction p (that IS the paper's
    saving); for train all layers count (joint loss)."""
    from repro.core.perf_model import stage_params_bytes
    n_all = stage_params_bytes(cfg, 0, cfg.n_layers) / 2.0      # param count
    if cfg.moe:
        m = cfg.moe
        # active fraction of expert params
        e_frac = (m.top_k + m.n_shared) / (m.n_experts + m.n_shared)
        ep = 3 * cfg.d_model * m.d_ff_expert * (m.n_experts + m.n_shared) \
            * (cfg.n_layers - cfg.first_k_dense)
        n_act = n_all - ep * (1 - e_frac)
    else:
        n_act = n_all
    if kind == "train":
        return 6.0 * n_act * batch * seq_len
    k = exit_layer if exit_layer is not None else cfg.n_layers // 2
    n1 = stage_params_bytes(cfg, 0, k) / 2.0
    n2 = n_all - n1
    if cfg.moe:
        n1 *= n_act / n_all
        n2 *= n_act / n_all
    tokens = batch * (seq_len if kind == "prefill" else 1)
    return 2.0 * tokens * (n1 + p * n2)
