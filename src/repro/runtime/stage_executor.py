"""Placement-aware stage execution: WHERE a stage's params live, HOW its
inputs/outputs are sharded there, and HOW work reaches it.

ATHEENA's core move is spatial — both network stages resident at once, each
on its own slice of the fabric, resources apportioned by the exit
probability p (paper §IV). ``StageExecutor`` is the multi-accelerator
analogue of one stage's floorplan region: it owns a submesh (or the
process-default device), places that stage's parameter slice and IO there,
and moves pytrees across the stage boundary with ``jax.device_put`` across
shardings — a device-to-device transfer, never a host round-trip.

``StagePlacement`` pairs the two executors and is what the servers in
``runtime/serve_loop.py`` take: single-device serving is the DEGENERATE
placement (no mesh, every ``place`` an identity), not a separate code path,
so the disaggregated and single-device servers share one hot loop and stay
bitwise identical.

IO sharding: an executor built with ``shard_io=True`` (the default for
mesh-backed executors) spreads batch-leading tensors over its submesh's
``data`` axis when the leading dim divides it, falling back to replication
per leaf otherwise (hard-sample slabs have capacity-sized leading dims that
rarely divide dp). Parameters are placed replicated over the submesh —
tensor-parallel placement within a stage rides the same ``param_spec``
machinery (launch/shardings.py) and is left to the caller via ``place``'s
``spec`` argument.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.stage_mesh import StageMeshPlan, make_stage_meshes
from repro.runtime import faults


class StageExecutor:
    """One stage's placement + dispatch context.

    mesh=None is the degenerate single-device executor: ``place`` returns
    its argument untouched (no transfer, no commitment), so servers built
    on it behave byte-for-byte like the pre-placement code.
    """

    def __init__(self, mesh: Optional[Mesh] = None, *, shard_io: bool = True,
                 name: str = "stage"):
        self.mesh = mesh
        self.shard_io = shard_io
        self.name = name

    # -- introspection -------------------------------------------------------

    @property
    def devices(self) -> Tuple:
        if self.mesh is None:
            return ()
        return tuple(self.mesh.devices.flat)

    @property
    def n_devices(self) -> int:
        return max(1, len(self.devices))

    def __repr__(self) -> str:
        if self.mesh is None:
            return f"StageExecutor({self.name}: default device)"
        return (f"StageExecutor({self.name}: {self.n_devices} devices "
                f"{sorted(d.id for d in self.devices)}, "
                f"shape {dict(self.mesh.shape)})")

    # -- shardings -----------------------------------------------------------

    def sharding(self, spec: P = P()) -> Optional[NamedSharding]:
        """NamedSharding on this stage's submesh (None when degenerate)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)

    def _io_spec(self, lead: int) -> P:
        """Batch-leading IO spec: over 'data' when the leading dim divides
        it, replicated otherwise."""
        if not self.shard_io:
            return P()
        dp = self.mesh.shape.get("data", 1)
        return P("data") if dp > 1 and lead % dp == 0 else P()

    # -- placement / transfer ------------------------------------------------

    def _transfer(self, tree, shard_of):
        """The one stage-boundary transfer path: a named ``transfer`` fault
        point followed by the ``jax.device_put``, retried with backoff so a
        transient hop failure never surfaces to the request stream. The
        fault point sits INSIDE the retried call — device_put is free of
        side effects until it returns, so a retried transfer re-runs
        cleanly."""
        def hop():
            faults.fault_point("transfer")
            return jax.tree.map(
                lambda x: jax.device_put(x, shard_of(x)), tree)
        return faults.retry(hop, what=f"transfer:{self.name}")

    def place(self, tree, spec: P = P()):
        """Commit a pytree onto this stage (replicated by default). Cross-
        executor calls ARE the stage-boundary transfer: ``jax.device_put``
        onto a sharding of a disjoint submesh moves the bytes device-to-
        device. Degenerate executors return the tree untouched."""
        if self.mesh is None:
            return tree
        sh = self.sharding(spec)
        return self._transfer(tree, lambda x: sh)

    def place_io(self, tree):
        """Commit batch-leading IO tensors (tokens, id lanes, slabs, ring
        payloads) onto this stage, sharding axis 0 over 'data' where it
        divides — per leaf, so a capacity-sized slab that doesn't divide dp
        replicates while the request batch shards."""
        if self.mesh is None:
            return tree
        return self._transfer(
            tree,
            lambda x: self.sharding(
                self._io_spec(x.shape[0]) if np.ndim(x) else P()))


class StagePlacement:
    """The two-stage deployment: stage 1 (full-rate, exit decision) on one
    executor, stage 2 (hard samples, ring + buckets) on the other."""

    def __init__(self, ex1: Optional[StageExecutor] = None,
                 ex2: Optional[StageExecutor] = None):
        self.ex1 = ex1 if ex1 is not None else StageExecutor(name="stage1")
        self.ex2 = ex2 if ex2 is not None else StageExecutor(name="stage2")

    @property
    def disaggregated(self) -> bool:
        return self.ex1.mesh is not None or self.ex2.mesh is not None

    def __repr__(self) -> str:
        return f"StagePlacement({self.ex1!r}, {self.ex2!r})"

    @classmethod
    def single_device(cls) -> "StagePlacement":
        """The degenerate placement every ``build_*`` factory defaults to."""
        return cls()

    @classmethod
    def from_plan(cls, plan: StageMeshPlan, devices=None, *,
                  shard_io: bool = True) -> "StagePlacement":
        """Carve disjoint submeshes for a StageMeshPlan (chips apportioned
        by p via the TAP design) out of ``devices`` (default: all local)."""
        devs = jax.devices() if devices is None else devices
        m1, m2 = make_stage_meshes(devs, plan)
        return cls(StageExecutor(m1, shard_io=shard_io, name="stage1"),
                   StageExecutor(m2, shard_io=shard_io, name="stage2"))

    @classmethod
    def from_design(cls, design, devices=None, *,
                    shard_io: bool = True) -> "StagePlacement":
        """Straight from a TAP ``CombinedDesign`` (core/tap.combine or
        dse.atheena_optimize_lm): extract the StageMeshPlan and carve."""
        return cls.from_plan(StageMeshPlan.from_design(design),
                             devices, shard_io=shard_io)
