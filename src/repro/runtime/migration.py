"""Zero-downtime live migration: apply a new stage plan to a RUNNING
``ContinuousScheduler`` with no dropped requests.

ATHEENA sizes the two-stage split offline for a measured exit probability
p; PR 5's drift controller re-solves that split online but could only
*report* it (plus the bucket-capacity half). This module makes the re-plan
real: a compensating state machine that walks a live slot pool from one
``StagePlacement`` (chip split, stage callables, bucket capacity) to
another between two scheduler loop iterations, so re-planning — and its
failure twin, device-loss degradation — is a pause measured in
milliseconds instead of a restart measured in minutes.

The state machine (each stage pushes a compensation; any failure unwinds
the stack LIFO and serving resumes on the OLD placement):

    QUIESCE   close admission; drain every in-flight ring bucket (retried,
              bounded by ``quiesce_timeout_s``); harvest every pending
              device result. Post-state: no parked slot, empty ring, empty
              pending window — the pool is at a shape-change-safe point.
    SNAPSHOT  capture the scheduler's full mutable state: *references* to
              the device arrays (jax.Arrays are immutable and nothing
              donates them between here and RESUME, so refs ARE a
              consistent, zero-copy snapshot) plus copies of the host-side
              slot metadata and queues.
    RE-PLACE  swap in the new stage callables (``fns_factory``/-provided
              ``DecodeFns`` re-slice params per ``ee.split_params`` onto
              the new submeshes), rebuild the ring at the new capacity on
              the new stage-2 executor, and ``jax.device_put`` the slot
              lanes / pooled stage-1 cache / stage-2 row store under the
              new placement's NamedShardings (``elastic.relayout``'s move,
              applied to live serving state).
    RESUME    re-open admission and record the measured pause
              (admission-closed -> admission-reopened wall time) in
              ``ServeStats.migration_pauses_ms``.

Correctness contract (tests/test_migration.py): per-sample token streams
are bitwise-equal to an unmigrated run across every migration — per-row
computations are batch- and placement-independent, and the quiesce point
guarantees no row's home changes shape under an in-flight bucket. A rolled
back migration restores byte-identical scheduler state.

Device loss rides the same machine: ``migrate_on_device_loss`` re-plans
the surviving chips (p-proportional, or the caller's Eq. (1) re-solve),
degrades the placement via ``elastic.degrade_placement``, rebuilds the
stage callables, and arms the migrator — losing a stage-2 chip degrades
throughput instead of crashing the server.
"""
from __future__ import annotations

import copy
import math
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from repro.core.stage_mesh import StageMeshPlan
from repro.runtime import elastic, faults
from repro.runtime.scheduler import (ContinuousScheduler, RingQueue,
                                     ServeConfig, _PARKED)
from repro.runtime.stage_executor import StagePlacement


class MigrationError(RuntimeError):
    """A migration stage failed. The migrator has already rolled back to
    the pre-migration placement (``__cause__`` holds the stage failure);
    serving continues on the old plan."""


class QuiesceTimeout(MigrationError):
    """QUIESCE could not drain the in-flight ring within the bounded
    wait — the pool never reached a shape-change-safe point."""


@dataclass(frozen=True)
class MigrationPlan:
    """What to migrate TO. Every field is optional — ``None`` keeps the
    scheduler's current value — so a capacity-only re-size, a pure chip
    re-split, and a full re-plan are all the same plan type.

    ``fns`` must be built against ``placement`` (the stage callables close
    over param slices placed on its submeshes); ``capacity`` is clamped to
    [1, n_slots] like ``request_capacity``. ``pause_budget_ms`` is the
    zero-downtime budget: exceeding it is *recorded* (an over-budget pause
    is an SLO violation, not a correctness failure — the bench gates it).
    """
    placement: Optional[StagePlacement] = None
    fns: Optional[object] = None
    capacity: Optional[int] = None
    pause_budget_ms: float = math.inf
    quiesce_timeout_s: float = 30.0
    reason: str = "replan"

    def __post_init__(self):
        if self.fns is not None and self.placement is None:
            raise ValueError("a MigrationPlan with new stage fns must name "
                             "the placement they were built against")
        if self.quiesce_timeout_s <= 0:
            raise ValueError(f"quiesce_timeout_s must be > 0, got "
                             f"{self.quiesce_timeout_s}")


# device-state attributes re-placed onto the new submeshes: (attr, stage,
# io) — io=True lanes shard batch-leading dims over 'data'; the pooled
# stage-1 cache re-places replicated (its block leaves carry superblock
# leading axes that must NOT shard over the batch axis rule)
_DEVICE_STATE: Tuple[Tuple[str, int, bool], ...] = (
    ("_tok", 1, True), ("_pos", 1, True), ("_active_lane", 1, True),
    ("_start_lane", 1, True), ("_budget_lane", 1, True),
    ("_c1", 1, False), ("_rows", 2, True),
    # the paged stage-2 page pool (None on dense schedulers / cold pools —
    # the relayout loop skips None attrs); replicated like _c1: its leaves
    # lead with page/superblock axes, never the batch axis
    ("_pool", 2, False),
)

# host-side mutable containers snapshotted by shallow copy (``queue`` is a
# serve_api.RequestQueue, which defines ``__copy__`` to clone its deque +
# sid set together)
_HOST_STATE = ("_sid", "_emitted", "_budget", "_state", "_free",
               "_parked_fifo", "_pending", "queue", "results",
               "_slot_pages", "_slot_len")


class LiveMigrator:
    """One migration attempt over a running scheduler. Single-shot: build,
    ``run()``, discard. On success the scheduler is serving on the new
    plan; on failure it is serving on the old one (byte-identical state)
    and ``MigrationError`` is raised with the stage failure as cause."""

    def __init__(self, sched: ContinuousScheduler, plan: MigrationPlan):
        self.sched = sched
        self.plan = plan
        self._compensations: List[Tuple[str, Callable[[], None]]] = []
        self.pause_ms: Optional[float] = None

    # -- the stages ----------------------------------------------------------

    def _quiesce(self) -> None:
        s = self.sched
        s._admission_open = False
        self._compensations.append(
            ("reopen-admission",
             lambda: setattr(s, "_admission_open", True)))
        faults.fault_point("migrate:quiesce")
        deadline = time.perf_counter() + self.plan.quiesce_timeout_s
        # drain every in-flight bucket: real dispatches (their tokens are
        # emitted normally and are NOT rolled back), retried on transient
        # faults like any other drain
        while s.ring.count > 0:
            if time.perf_counter() >= deadline:
                raise QuiesceTimeout(
                    f"ring still holds {s.ring.count} rows after "
                    f"{self.plan.quiesce_timeout_s:.1f}s — cannot reach a "
                    f"shape-change-safe point")
            faults.retry(s._dispatch_bucket, what="quiesce-drain")
        while s._pending:
            s._harvest_one()
        assert not any(st == _PARKED for st in s._state), \
            "quiesced with parked slots despite an empty ring"

    def _snapshot(self) -> None:
        faults.fault_point("migrate:snapshot")
        s = self.sched
        snap: dict = {}
        # device arrays: refs are the snapshot (immutable; no donation can
        # touch them before RESUME because no tick runs mid-migration and
        # RE-PLACE only issues non-donating device_put)
        for attr, _stage, _io in _DEVICE_STATE:
            snap[attr] = getattr(s, attr)
        for attr in _HOST_STATE:
            val = getattr(s, attr)
            snap[attr] = copy.copy(val)      # shallow copy, same container
        for attr in ("fns", "placement", "ex1", "ex2", "sc", "ring",
                     "c_thr", "eager_drain_below", "active_cap"):
            snap[attr] = getattr(s, attr)
        chips = (s.stats.stage1_chips, s.stats.stage2_chips)
        # the page allocator's free list: an EXACT state capture (its own
        # defensive-copy snapshot — the lane is donated by frees, so a bare
        # ref would not survive post-rollback serving)
        alloc = getattr(s, "_alloc", None)
        alloc_snap = alloc.snapshot() if alloc is not None else None

        def restore():
            for attr, val in snap.items():
                setattr(s, attr, val)
            s.stats.stage1_chips, s.stats.stage2_chips = chips
            if alloc is not None:
                alloc.restore(alloc_snap)
        self._compensations.append(("restore-snapshot", restore))

    def _replace(self) -> None:
        faults.fault_point("migrate:replace")
        s, plan = self.sched, self.plan
        new_pl = plan.placement if plan.placement is not None else s.placement
        new_fns = plan.fns if plan.fns is not None else s.fns
        if getattr(s, "_paged", False) and (
                getattr(new_fns, "s2_paged", None) is None
                or getattr(new_fns, "page_size", None) != s.page_size):
            raise MigrationError(
                "a paged scheduler can only migrate onto stage fns built "
                f"with the same page_size={s.page_size} "
                "(decode_stage_fns(page_size=...)) — the live page pool's "
                "layout is not convertible mid-serve")
        cap = (s.sc.capacity if plan.capacity is None
               else max(1, min(int(plan.capacity), s.n_slots)))
        new_sc = ServeConfig(capacity=cap, queue_depth=s.sc.queue_depth,
                             c_thr=s.sc.c_thr, max_pending=s.sc.max_pending,
                             harvest_timeout_s=s.sc.harvest_timeout_s)
        s.fns = new_fns
        s.placement = new_pl
        s.ex1, s.ex2 = new_pl.ex1, new_pl.ex2
        s.sc = new_sc
        # fresh ring on the new stage-2 executor at the new capacity (the
        # quiesced ring is empty; the buffer re-allocates on next enqueue)
        s.ring = RingQueue(new_sc, s.ex2, s.stats)
        # re-lay-out live device state under the new placement's shardings
        # — the elastic.relayout move applied to serving state. Skipped
        # when the pool is cold (nothing admitted yet).
        if s._c1 is not None:
            for attr, stage, io in _DEVICE_STATE:
                val = getattr(s, attr)
                if val is None:              # e.g. _pool on a dense pool
                    continue
                ex = s.ex1 if stage == 1 else s.ex2
                put = ex.place_io if io else ex.place
                setattr(s, attr,
                        faults.retry(put, val, what=f"relayout:{attr}"))
            alloc = getattr(s, "_alloc", None)
            if alloc is not None:
                alloc.relayout(lambda x: faults.retry(
                    s.ex2.place, x, what="relayout:_alloc"))
        s.stats.record_placement(new_pl)

    def _resume(self, t0: float) -> None:
        faults.fault_point("migrate:resume")
        s = self.sched
        s._admission_open = True
        self.pause_ms = (time.perf_counter() - t0) * 1e3
        s.stats.record_migration(self.pause_ms)
        faults.LOG.emit("migration", reason=self.plan.reason,
                        pause_ms=self.pause_ms,
                        capacity=s.sc.capacity,
                        stage1_chips=s.stats.stage1_chips,
                        stage2_chips=s.stats.stage2_chips,
                        over_budget=bool(
                            self.pause_ms > self.plan.pause_budget_ms))

    # -- driver --------------------------------------------------------------

    def run(self) -> float:
        """Execute QUIESCE -> SNAPSHOT -> RE-PLACE -> RESUME. Returns the
        measured pause in ms; raises ``MigrationError`` after a clean
        rollback on any stage failure."""
        t0 = time.perf_counter()
        stage = "quiesce"
        try:
            self._stage_event("quiesce")
            self._quiesce()
            stage = "snapshot"
            self._stage_event("snapshot")
            self._snapshot()
            stage = "replace"
            self._stage_event("replace")
            self._replace()
            stage = "resume"
            self._stage_event("resume")
            self._resume(t0)
            self._stage_event("done", pause_ms=self.pause_ms)
            return self.pause_ms
        except BaseException as exc:
            self._rollback(stage, exc)
            if isinstance(exc, MigrationError):
                raise
            raise MigrationError(
                f"migration ({self.plan.reason}) failed in {stage.upper()}: "
                f"{exc}") from exc

    def _rollback(self, stage: str, exc: BaseException) -> None:
        """Unwind the compensation stack LIFO: the snapshot restore (when
        taken) rewinds every RE-PLACE mutation to the captured refs, then
        admission re-opens. Compensations are pure ref/flag restores — no
        device work, nothing that can itself fail."""
        for _name, comp in reversed(self._compensations):
            comp()
        self._compensations.clear()
        self.sched.stats.record_migration_rollback()
        self._stage_event("rollback", failed_stage=stage)
        faults.LOG.emit("migration_rollback", reason=self.plan.reason,
                        failed_stage=stage, error=str(exc))

    def _stage_event(self, stage: str, **fields) -> None:
        """Annotate the scheduler's request-lifecycle feed (when wired)
        with the migration state machine's transitions — the tracer
        renders them as control-track instants alongside the request
        spans they pause."""
        ev = getattr(self.sched, "events", None)
        if ev is not None:
            ev.emit(f"migrate_{stage}", reason=self.plan.reason, **fields)


def migrate_on_device_loss(sched: ContinuousScheduler, failed,
                           q: Optional[float] = None,
                           pause_budget_ms: float = math.inf) -> None:
    """Degrade a running disaggregated scheduler after losing devices:
    re-split the SURVIVING chips (p-proportional at the observed hard rate
    ``q``, default the provisioned/realized rate), rebuild the stage
    callables against the degraded placement via the scheduler's
    ``fns_factory``, and arm a live migration — throughput degrades, the
    server survives.

    ``failed`` is a set of failed device *ids* (or device objects). The
    migration applies at the scheduler's next discrete re-plan point.
    """
    if sched.fns_factory is None:
        raise MigrationError(
            "device-loss degradation needs a fns_factory to rebuild stage "
            "callables on the surviving placement")
    devs = list(sched.ex1.devices) + list(sched.ex2.devices)
    if not devs:
        raise MigrationError("single-device placement has no chips to lose")
    failed_ids = {getattr(d, "id", d) for d in failed}
    failed_idx = [i for i, d in enumerate(devs) if d.id in failed_ids]
    survivors = len(devs) - len(failed_idx)
    if survivors < 2:
        raise MigrationError(
            f"{survivors} surviving device(s) cannot host a disaggregated "
            f"two-stage split — fall back to single-device serving")
    if q is None:
        st = sched.stats
        q = st.provisioned_p if st.provisioned_p is not None \
            else max(st.realized_q, 0.01)
    plan = StageMeshPlan.proportional(min(max(float(q), 0.01), 1.0),
                                      survivors)
    new_pl = elastic.degrade_placement(devs, failed_idx, plan)
    sched.request_migration(MigrationPlan(
        placement=new_pl, fns=sched.fns_factory(new_pl),
        pause_budget_ms=pause_budget_ms,
        reason=f"device-loss:{sorted(failed_ids)}"))
