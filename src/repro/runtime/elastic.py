"""Elastic re-meshing: re-plan a deployment for a degraded device set or a
drifted exit rate.

When a node fails mid-serve, the stage-mesh apportionment is re-derived for
the surviving chip count from the SAME TAP curves (no re-profiling) and the
checkpoint restores onto the new mesh — param shardings are re-laid-out by
jax.device_put under the new NamedSharding. The dry-run proves the degraded
plan compiles (tests/test_elastic).

``replan_rate`` is the drift analogue: same chips, but the Eq. (1)
combination re-run at the OBSERVED hard rate q instead of the provisioned
p — the stage re-planning actuator of the online drift control plane
(``runtime/controller.py``), reached when realized q drifts beyond what
threshold re-calibration alone can correct.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core import tap as T
from repro.core.stage_mesh import StageMeshPlan, make_stage_meshes


@dataclass(frozen=True)
class ElasticPlan:
    chips_before: int
    chips_after: int
    design: T.CombinedDesign
    throughput_before: float
    throughput_after: float

    @property
    def degradation(self) -> float:
        return self.throughput_after / max(self.throughput_before, 1e-12)


def replan(tap1: T.TAPFunction, tap2: T.TAPFunction, p: float,
           chips_before: int, chips_after: int,
           hbm_per_chip_gb: float = 16.0) -> ElasticPlan:
    """Re-run the Eq. (1) combination at the degraded chip budget."""
    before = T.combine(tap1, tap2, p,
                       budget=(chips_before, chips_before * hbm_per_chip_gb))
    after = T.combine(tap1, tap2, p,
                      budget=(chips_after, chips_after * hbm_per_chip_gb))
    if after is None:
        raise RuntimeError(
            f"no feasible design at {chips_after} chips — shed load or "
            f"shrink capacity")
    return ElasticPlan(
        chips_before=chips_before, chips_after=chips_after, design=after,
        throughput_before=before.design_throughput if before else 0.0,
        throughput_after=after.design_throughput)


def replan_rate(tap1: T.TAPFunction, tap2: T.TAPFunction, p: float,
                q: float, chips: int,
                hbm_per_chip_gb: float = 16.0) -> ElasticPlan:
    """Re-run the Eq. (1) combination at the OBSERVED hard rate ``q`` under
    the same chip budget. ``throughput_before`` is what the p-provisioned
    design actually sustains at q (the Fig. 4 off-design band),
    ``throughput_after`` what the q-matched re-plan sustains — so
    ``degradation`` > 1 reads as the throughput the re-plan recovers."""
    before = T.combine(tap1, tap2, p, budget=(chips, chips * hbm_per_chip_gb))
    after = T.combine(tap1, tap2, q, budget=(chips, chips * hbm_per_chip_gb))
    if after is None:
        raise RuntimeError(
            f"no feasible design at q={q} under {chips} chips — shed load "
            f"or shrink capacity")
    return ElasticPlan(
        chips_before=chips, chips_after=chips, design=after,
        throughput_before=before.throughput_at(q) if before else 0.0,
        throughput_after=after.throughput_at(q))


def degrade_mesh(devices: Sequence, failed: Sequence[int],
                 plan: StageMeshPlan) -> Tuple[jax.sharding.Mesh, ...]:
    """Drop failed device indices and rebuild stage submeshes from the
    survivors (caller re-plans chips1/chips2 first via ``replan``)."""
    alive = [d for i, d in enumerate(devices) if i not in set(failed)]
    return make_stage_meshes(np.array(alive, dtype=object), plan)


def degrade_placement(devices: Sequence, failed: Sequence[int],
                      plan: StageMeshPlan, *, shard_io: bool = True):
    """Device-loss analogue of ``StagePlacement.from_plan``: drop failed
    device indices and carve the re-planned stage submeshes out of the
    survivors. This is the placement half of device-loss degradation — the
    live migrator (``runtime/migration.py``) re-places the running pool
    onto it so a lost chip degrades throughput instead of crashing the
    server."""
    from repro.runtime.stage_executor import StageExecutor, StagePlacement
    m1, m2 = degrade_mesh(devices, failed, plan)
    return StagePlacement(
        StageExecutor(m1, shard_io=shard_io, name="stage1"),
        StageExecutor(m2, shard_io=shard_io, name="stage2"))


def relayout(tree, shardings):
    """Move a checkpoint pytree onto a (new) sharding pytree."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
