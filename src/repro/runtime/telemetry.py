"""Serving telemetry: the SENSE/FILTER layer of the drift control plane.

ATHEENA provisions the stage mesh for a *measured* exit probability p, but
the realized hard rate q drifts with the live input distribution. This
module owns the filtered views of the serving signals the controller
(``runtime/controller.py``) consumes:

  * ``ewma`` — the one definition of the windowed exponentially-weighted
    realized-q average. ``ServeStats.realized_q_ewma`` and the drift
    benchmarks call the same function, so "the EWMA of realized q" means
    exactly one thing across the repo (controller hysteresis, the
    ``q_drift`` field in ``ServeStats.as_dict`` and the
    ``serve_drift`` convergence gate all agree).
  * ``ConfidenceReservoir`` — a rolling window of recent stage-1
    max-softmax confidences: the ONLINE calibration set. Offline, C_thr is
    the (1 - p)-quantile of a profiling set; online, the reservoir is that
    profiling set, continuously refreshed, so re-solving the quantile
    steers the realized exit rate back to the provisioned p under the
    *current* input distribution.
  * ``ControlWindow`` — per-actuation-window counters (decisions, hard
    tokens, stalls, bucket fill) computed as deltas between controller
    visits, so actuation decisions see the RECENT regime rather than
    lifetime averages that an old regime dominates.

  * ``EventLog`` — a bounded, monotonically-sequenced structured event
    buffer for control-plane occurrences that are *discrete* rather than
    windowed: fault injections, retries, migration stage transitions,
    rollbacks. The fault layer (``runtime/faults.py``) and the migrator
    (``runtime/migration.py``) both write here; the CI chaos job flushes
    it as the fault-log artifact.

Everything here is host-side numpy over scalars the hot loops already
sync; sensing adds no device round-trips of its own.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Iterable, Optional

import numpy as np

# Window bound for the drift view: the re-planning signal cares about
# *persistent* drift over the recent past, and an EWMA over an unbounded
# series would make old regimes haunt the estimate forever (besides being
# O(n) to fold). 256 dispatches is minutes of serving at any real tick
# rate and a few seconds on the CPU benches.
DRIFT_WINDOW = 256

# Default smoothing for the drift filter. At alpha = 0.1 a step change in
# q reaches ~65% of its new value in 10 dispatches — fast enough to catch
# a phase change within one controller persistence window, slow enough
# that one weird bucket doesn't trip the hysteresis band.
DRIFT_ALPHA = 0.1


def ewma(series: Iterable[float], alpha: float = DRIFT_ALPHA,
         window: int = DRIFT_WINDOW) -> float:
    """Exponentially-weighted moving average over the LAST ``window``
    entries of ``series`` (0.0 when empty). The single shared definition of
    'the EWMA of realized q' — see the module docstring."""
    tail = list(series)[-window:] if window else list(series)
    v: Optional[float] = None
    for x in tail:
        v = float(x) if v is None else alpha * float(x) + (1.0 - alpha) * v
    return 0.0 if v is None else v


class ConfidenceReservoir:
    """Rolling reservoir of recent stage-1 exit-head confidences — the
    online calibration set for threshold re-solving. Bounded (FIFO
    overwrite), so long-running streams keep O(size) memory and the
    quantile always reflects the recent input distribution."""

    def __init__(self, size: int = 4096):
        if size < 1:
            raise ValueError(f"reservoir size must be >= 1, got {size}")
        self.size = size
        self._buf: Deque[float] = deque(maxlen=size)

    def extend(self, confidences) -> None:
        arr = np.asarray(confidences, np.float32).reshape(-1)
        self._buf.extend(float(c) for c in arr)

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def full(self) -> bool:
        return len(self._buf) == self.size

    def snapshot(self) -> np.ndarray:
        """The current calibration set, oldest first."""
        return np.asarray(self._buf, np.float32)

    def clear(self) -> None:
        self._buf.clear()


class EventLog:
    """Bounded structured event buffer (FIFO overwrite past ``cap``).

    Each event is a dict with a monotonically increasing ``seq``, a wall
    timestamp ``t``, an ``event`` tag, and arbitrary keyword fields. The
    sequence number keeps ordering meaningful even after old events fall
    off the deque, and survives ``clear()`` so flushed chunks of one
    process's log never renumber. ``n_dropped`` counts events lost to the
    cap (FIFO overwrite) — a gap between ``seq`` extremes and ``len``
    larger than ``n_dropped`` means someone ``clear()``-ed in between.

    ``subscribe`` registers a streaming callback invoked synchronously on
    every ``emit`` AFTER the event is buffered — the fleet router's
    per-request event feed rides this. Subscribers must be cheap and must
    not raise (an exception propagates to the emitter — there is no
    swallow-and-continue, because a silently dead feed is worse than a
    loud one).
    """

    def __init__(self, cap: int = 1024):
        if cap < 1:
            raise ValueError(f"event log cap must be >= 1, got {cap}")
        self.cap = cap
        self._buf: Deque[dict] = deque(maxlen=cap)
        self._seq = 0
        self._subs: list = []
        self._subs_t: tuple = ()     # emit iterates this frozen snapshot —
        self.n_dropped = 0           # no per-event list copy on the hot path

    def subscribe(self, fn) -> "callable":
        """Register ``fn(event_dict)`` to observe every future emit.
        Returns ``fn`` (decorator-friendly). A (un)subscribe during an
        in-flight emit takes effect from the NEXT emit."""
        self._subs.append(fn)
        self._subs_t = tuple(self._subs)
        return fn

    def unsubscribe(self, fn) -> None:
        self._subs.remove(fn)
        self._subs_t = tuple(self._subs)

    def emit(self, event: str, **fields) -> dict:
        self._seq += 1
        ev = {"seq": self._seq, "t": time.time(), "event": event, **fields}
        if len(self._buf) == self.cap:
            self.n_dropped += 1
        self._buf.append(ev)
        for fn in self._subs_t:
            fn(ev)
        return ev

    def __len__(self) -> int:
        return len(self._buf)

    def as_list(self) -> list:
        """Snapshot of the retained events, oldest first."""
        return list(self._buf)

    def tail(self, n: int = 10) -> list:
        return list(self._buf)[-n:]

    def clear(self) -> None:
        """Drop retained events (``seq`` keeps counting)."""
        self._buf.clear()


class ControlWindow:
    """Windowed counter deltas between controller visits.

    The controller acts on the CURRENT regime; lifetime stats (what
    ``ServeStats`` accumulates) average over every regime seen since boot.
    ``observe``/``observe_counters`` fold one tick/batch in; the aggregate
    properties (and ``as_dict``) read the open window, and ``reset``
    starts the next one (counter high-water marks persist across
    resets)."""

    def __init__(self):
        # high-water marks of the lifetime counters survive reset():
        # deltas are vs the previous VISIT, not vs window start
        self._hw_stalls = 0
        self._hw_buckets = 0
        self._hw_fill = 0.0
        self.reset()

    def reset(self) -> None:
        self.ticks = 0
        self.decisions = 0
        self.hard = 0
        self.stalls = 0
        self.buckets = 0
        self.bucket_fill = 0.0

    def observe(self, n_decisions: int, n_hard: int) -> None:
        self.ticks += 1
        self.decisions += int(n_decisions)
        self.hard += int(n_hard)

    def observe_counters(self, n_stalls: int, n_buckets: int,
                         bucket_fill_sum: float) -> None:
        """Fold lifetime counters in as deltas vs the previous visit (the
        caller passes the CURRENT lifetime values; this keeps its own
        high-water marks)."""
        self.stalls += max(0, int(n_stalls) - self._hw_stalls)
        self.buckets += max(0, int(n_buckets) - self._hw_buckets)
        self.bucket_fill += max(0.0, float(bucket_fill_sum) - self._hw_fill)
        self._hw_stalls = int(n_stalls)
        self._hw_buckets = int(n_buckets)
        self._hw_fill = float(bucket_fill_sum)

    @property
    def q(self) -> float:
        """Realized hard rate within this window."""
        return self.hard / self.decisions if self.decisions else 0.0

    @property
    def mean_active(self) -> float:
        """Mean decisions per tick = mean live slots doing stage-1 work."""
        return self.decisions / self.ticks if self.ticks else 0.0

    @property
    def stall_rate(self) -> float:
        """Backpressure stalls per tick within the window."""
        return self.stalls / self.ticks if self.ticks else 0.0

    @property
    def mean_bucket_fill(self) -> float:
        return self.bucket_fill / self.buckets if self.buckets else 0.0

    def as_dict(self) -> dict:
        return {"ticks": self.ticks, "decisions": self.decisions,
                "q": self.q, "mean_active": self.mean_active,
                "stall_rate": self.stall_rate,
                "mean_bucket_fill": self.mean_bucket_fill}
