"""Online drift control plane: closed-loop threshold re-calibration,
scheduler autoscaling, and elastic stage re-planning.

ATHEENA provisions hardware for a *measured* exit probability p (paper
§IV); in a live deployment the realized hard rate q drifts with the input
distribution, silently invalidating the provisioned design — the runtime
threshold/hardware co-adaptation HAPI (Laskaridis et al., 2020) and the
adaptive-inference survey identify as the piece offline DSE cannot cover.
``DriftController`` closes the loop over the serving telemetry the
schedulers already sync:

    SENSE ──> FILTER ──> HYSTERESIS ──> ACTUATE
      │          │            │             │
      │          │            │             ├─ 1. threshold re-calibration:
      │          │            │             │    re-solve C_thr as the
      │          │            │             │    (1-p)-quantile of the
      │          │            │             │    rolling confidence
      │          │            │             │    reservoir (bounded step)
      │          │            │             ├─ 2. scheduler autoscaling:
      │          │            │             │    live-slot occupancy cap +
      │          │            │             │    eager-drain / bucket-drain
      │          │            │             │    policy from latency and
      │          │            │             │    occupancy feedback
      │          │            │             └─ 3. stage re-planning: Eq. (1)
      │          │            │                  re-combined at the observed
      │          │            │                  q (elastic.replan_rate /
      │          │            │                  proportional split); report,
      │          │            │                  or apply — a full live
      │          │            │                  chip-re-split migration
      │          │            │                  (runtime/migration.py) when
      │          │            │                  the scheduler can rebuild
      │          │            │                  its stage fns, else the
      │          │            │                  bucket-capacity half
      │          │            └─ |EWMA(q) - p| must exceed the band for
      │          │               ``persistence_ticks`` consecutive visits;
      │          │               re-arm only below the release band
      │          └─ windowed EWMA of the per-dispatch q series
      │             (ServeStats.realized_q_ewma — telemetry.ewma)
      └─ per-tick (n_decisions, n_hard, live-row confidences): scalars the
         hot loops fetch anyway, so sensing costs no extra syncs

Actuation discipline — what makes this safe to leave attached:

  * **warmup**: nothing actuates before ``min_decisions`` decisions have
    been sensed (a threshold solved from ten samples is noise);
  * **hysteresis**: drift must *persist* (band + streak), so a single
    hairy bucket never re-aims the threshold;
  * **cooldown**: after any actuation the controller holds for
    ``cooldown_ticks`` visits, letting the plant respond before it is
    measured again (the EWMA lags the threshold change);
  * **bounded steps**: one actuation moves C_thr at most
    ``max_thr_step``, the occupancy cap and drain policy by one slot —
    persistent drift converges over a few actuations, transient noise
    cannot slam the operating point;
  * **no steady-state recompiles**: C_thr is a traced argument, the cap
    and drain policy are host-side ints. Only the re-plan actuator's
    bucket re-size compiles a new drain program, and only at a discrete
    re-plan point (empty ring).

Everything degrades to PR-4 behavior when no controller is attached: the
schedulers' control fields keep their constructor values and the hot loops
are byte-for-byte the uncontrolled ones (enforced by the unchanged parity
tests).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

import numpy as np

from repro.core import exit_decision as ed
from repro.core.stage_mesh import StageMeshPlan, stage2_capacity
from repro.runtime import elastic
from repro.runtime.telemetry import ConfidenceReservoir, ControlWindow

# state-machine phases
WARMUP, STEADY, CORRECTING, COOLDOWN = ("warmup", "steady", "correcting",
                                        "cooldown")


@dataclass
class ControllerConfig:
    """Tuning knobs for one control loop. The defaults are deliberately
    conservative: a controller that actuates rarely and in small steps is
    one an operator can leave attached."""
    provisioned_p: float                 # the rate the stage mesh was sized for
    target_band: float = 0.05            # hysteresis enter band on |EWMA(q)-p|
    release_band: float = 0.02           # re-arm band (must be < target_band)
    replan_band: float = 0.15            # beyond this, thresholding alone
                                         # cannot correct -> stage re-plan
    min_decisions: int = 64              # warmup: sense this much before acting
    persistence_ticks: int = 3           # drift must persist this many visits
    cooldown_ticks: int = 8              # hold after any actuation
    max_thr_step: float = 0.1            # bounded |ΔC_thr| per actuation
    reservoir_size: int = 2048           # rolling confidence window
    min_reservoir: int = 64              # don't re-solve a quantile on less
    # actuator enables
    recalibrate: bool = True
    autoscale: bool = True
    replan: bool = True                  # report re-plans
    apply_replan: bool = False           # ...and apply the capacity half
    # autoscaler feedback targets
    latency_slo_p99: Optional[float] = None   # seconds; None = no cap control
    min_active_cap: int = 1
    autoscale_every: int = 16            # visits between autoscaler passes
    starvation_fill: float = 0.5         # bucket-fill floor before the drain
                                         # policy trades fill for latency
    latency_window: int = 64             # SLO feedback looks at the last N
                                         # finished requests, not lifetime

    def __post_init__(self):
        if not 0.0 < self.provisioned_p <= 1.0:
            raise ValueError(f"provisioned_p must be in (0, 1], got "
                             f"{self.provisioned_p}")
        if self.release_band >= self.target_band:
            raise ValueError(
                f"release_band ({self.release_band}) must be strictly inside "
                f"target_band ({self.target_band}) — equal bands would chatter")
        if self.replan_band < self.target_band:
            raise ValueError(
                f"replan_band ({self.replan_band}) must be >= target_band "
                f"({self.target_band}) — re-planning is the escalation")
        if self.max_thr_step <= 0.0:
            raise ValueError(f"max_thr_step must be > 0, got "
                             f"{self.max_thr_step}")
        if self.persistence_ticks < 1 or self.cooldown_ticks < 0:
            raise ValueError("persistence_ticks >= 1 and cooldown_ticks >= 0 "
                             "required")


@dataclass
class ControllerState:
    """Everything the loop knows, reportable: phase, the filtered drift,
    actuation counters, and a bounded action log (what changed, when, why
    — the audit trail a drifting deployment gets asked for)."""
    phase: str = WARMUP
    ticks: int = 0
    decisions_seen: int = 0
    drift_streak: int = 0
    cooldown_left: int = 0
    q_ewma: float = 0.0
    drift: float = 0.0
    c_thr: Optional[float] = None
    n_recalibrations: int = 0
    n_autoscale_events: int = 0
    n_replans: int = 0
    recommended_plan: Optional[StageMeshPlan] = None
    actions: List[dict] = field(default_factory=list)

    _ACTION_CAP = 256                    # bounded audit log

    def log(self, kind: str, **detail) -> None:
        self.actions.append({"tick": self.ticks, "kind": kind, **detail})
        if len(self.actions) > self._ACTION_CAP:
            del self.actions[: len(self.actions) - self._ACTION_CAP]

    def as_dict(self) -> dict:
        plan = self.recommended_plan
        return {"phase": self.phase, "ticks": self.ticks,
                "decisions_seen": self.decisions_seen,
                "q_ewma": self.q_ewma, "drift": self.drift,
                "c_thr": self.c_thr,
                "n_recalibrations": self.n_recalibrations,
                "n_autoscale_events": self.n_autoscale_events,
                "n_replans": self.n_replans,
                "recommended_plan": (None if plan is None else
                                     {"chips1": plan.chips1,
                                      "chips2": plan.chips2}),
                "actions_tail": self.actions[-8:]}


class DriftController:
    """The closed loop. Attach to a scheduler (``attach``), and the
    scheduler's hot loop calls ``on_tick`` once per pool tick (continuous)
    or per static batch (sync) with the scalars it synced anyway.

    Actuators are duck-typed against the scheduler's control surface:
    whatever the policy exposes is driven (``set_c_thr`` everywhere;
    ``set_active_cap``/``set_eager_drain_below``/``request_capacity`` on
    the continuous scheduler), the rest is skipped — so one controller
    drives both policies without either growing a fake interface.

    ``taps`` (optional) are the profiled (stage-1, stage-2) TAP curves and
    ``chips`` the deployment budget: with them the re-plan actuator runs
    the real Eq. (1) re-combination (``elastic.replan_rate``); without,
    it falls back to the p-proportional chip split when the placement
    spans enough devices, else reports the drift with no plan.
    """

    # bounded (n_decisions, n_hard) per-visit history: lets callers compute
    # a decision-WEIGHTED realized q over any trailing span (per-tick q is
    # occupancy-biased — a drain-down tick with one live slot votes 0 or 1)
    HISTORY_CAP = 1024

    def __init__(self, cfg: ControllerConfig,
                 taps: Optional[Tuple] = None, chips: Optional[int] = None):
        self.cfg = cfg
        self.state = ControllerState()
        self.reservoir = ConfidenceReservoir(cfg.reservoir_size)
        self.window = ControlWindow()
        self.history: Deque[Tuple[int, int]] = deque(maxlen=self.HISTORY_CAP)
        self.taps = taps
        self.chips = chips

    def realized_q_tail(self, min_decisions: int = 256) -> float:
        """Decision-weighted realized q over the most recent visits
        spanning at least ``min_decisions`` decisions — the settled
        operating point (what the ±band acceptance bar measures)."""
        dec = hard = 0
        for d, h in reversed(self.history):
            dec += d
            hard += h
            if dec >= min_decisions:
                break
        return hard / dec if dec else 0.0

    # -- wiring --------------------------------------------------------------

    def attach(self, sched):
        """Wire this controller into a scheduler: the scheduler's hot loop
        starts calling ``on_tick``, its stats gain the provisioned p (the
        windowed ``q_drift`` view), and — on the sync policy — the
        underlying server's confidence sink feeds the reservoir. Returns
        the scheduler for chaining."""
        sched.controller = self
        sched.stats.provisioned_p = self.cfg.provisioned_p
        self.state.c_thr = float(self._current_thr(sched))
        server = getattr(sched, "server", None)
        if server is not None and hasattr(server, "conf_sink"):
            server.conf_sink = self.reservoir
        return sched

    @staticmethod
    def _current_thr(sched) -> float:
        thr = getattr(sched, "c_thr", None)
        if thr is None:
            thr = sched.server.c_thr
        return thr

    # -- the loop ------------------------------------------------------------

    def on_tick(self, sched, n_decisions: int, n_hard: int,
                confidences=None) -> None:
        """One controller visit: sense the tick, refresh the filter, walk
        the hysteresis state machine, maybe actuate."""
        st, cfg = self.state, self.cfg
        st.ticks += 1
        st.decisions_seen += int(n_decisions)
        self.history.append((int(n_decisions), int(n_hard)))
        self.window.observe(n_decisions, n_hard)
        stats = sched.stats
        self.window.observe_counters(stats.n_stalls, stats.n_buckets,
                                     stats.bucket_fill_sum)
        if confidences is not None and len(confidences):
            self.reservoir.extend(confidences)

        # FILTER: the shared windowed-EWMA drift view on ServeStats
        st.q_ewma = stats.realized_q_ewma
        st.drift = st.q_ewma - cfg.provisioned_p

        if st.decisions_seen < cfg.min_decisions:
            st.phase = WARMUP
            return
        if st.cooldown_left > 0:
            st.cooldown_left -= 1
            st.phase = COOLDOWN
        else:
            # HYSTERESIS: enter on persistent excursion past target_band,
            # re-arm only once the drift falls back inside release_band
            if abs(st.drift) > cfg.target_band:
                st.drift_streak += 1
            elif abs(st.drift) < cfg.release_band:
                st.drift_streak = 0
                st.phase = STEADY
            if st.drift_streak >= cfg.persistence_ticks:
                st.phase = CORRECTING
                self._actuate_drift(sched)
                st.drift_streak = 0
                st.cooldown_left = cfg.cooldown_ticks

        # the autoscaler runs on its own cadence and feedback (latency +
        # occupancy, not q-drift), but shares the actuation discipline
        if (cfg.autoscale and st.ticks % cfg.autoscale_every == 0
                and st.decisions_seen >= cfg.min_decisions):
            self._autoscale(sched)
            self.window.reset()

    # -- actuator 1 + 3: drift correction ------------------------------------

    def _actuate_drift(self, sched) -> None:
        """Past the target band: re-calibrate the threshold. Past the
        re-plan band: thresholding alone cannot correct — escalate to the
        Eq. (1) stage re-plan as well."""
        cfg, st = self.cfg, self.state
        if abs(st.drift) >= cfg.replan_band and cfg.replan:
            self._replan(sched)
        if cfg.recalibrate:
            self._recalibrate(sched)

    def _recalibrate(self, sched) -> None:
        """Re-solve C_thr from the rolling reservoir so the realized exit
        rate is steered back to (1 - p) under the CURRENT distribution —
        bounded to ``max_thr_step`` per actuation."""
        cfg, st = self.cfg, self.state
        if len(self.reservoir) < cfg.min_reservoir:
            st.log("recalibrate_skipped", reason="reservoir",
                   n=len(self.reservoir))
            return
        target = ed.calibrate_threshold(self.reservoir.snapshot(),
                                        target_exit_rate=1.0
                                        - cfg.provisioned_p)
        prev = st.c_thr if st.c_thr is not None else self._current_thr(sched)
        step = max(-cfg.max_thr_step, min(cfg.max_thr_step, target - prev))
        new = prev + step
        if new == prev:
            return
        sched.set_c_thr(new)
        st.c_thr = new
        st.n_recalibrations += 1
        st.log("recalibrate", c_thr=new, solved=float(target),
               drift=st.drift, clipped=bool(new != target))

    def _replan(self, sched) -> None:
        """Stage re-plan at the observed q: the real Eq. (1) re-combination
        when TAP curves are in hand, else the p-proportional split over the
        current chip count. Under ``apply_replan`` the re-plan is APPLIED:
        a full live migration (chip re-split + stage-fns rebuild + bucket
        re-size through ``runtime.migration.LiveMigrator``) when the
        scheduler can rebuild its stage callables against a new placement
        (``fns_factory``) and the placement is disaggregated; otherwise
        the bucket-capacity half via ``request_capacity``. Either applies
        only at a discrete re-plan point."""
        cfg, st = self.cfg, self.state
        q = min(max(st.q_ewma, 0.01), 1.0)
        plan = None
        if self.taps is not None and self.chips is not None:
            ep = elastic.replan_rate(self.taps[0], self.taps[1],
                                     cfg.provisioned_p, q, self.chips)
            plan = StageMeshPlan.from_chips(
                int(ep.design.stage1.resources[0]),
                int(ep.design.stage2.resources[0]))
            recovered = ep.degradation
        else:
            recovered = None
            placement = getattr(sched, "placement", None)
            if placement is not None and placement.disaggregated:
                n_dev = (sched.stats.stage1_chips
                         + sched.stats.stage2_chips)
                plan = StageMeshPlan.proportional(q, n_dev)
        st.recommended_plan = plan
        st.n_replans += 1
        applied = None
        if cfg.apply_replan:
            cap = (stage2_capacity(sched.n_slots, q, multiple=1)
                   if hasattr(sched, "n_slots") else None)
            factory = getattr(sched, "fns_factory", None)
            placement = getattr(sched, "placement", None)
            if (plan is not None and factory is not None
                    and hasattr(sched, "request_migration")
                    and placement is not None and placement.disaggregated):
                # full chip re-split: carve the re-planned submeshes out of
                # the SAME device set the current placement occupies and
                # hand the migrator placement + rebuilt fns + capacity
                from repro.runtime.migration import MigrationPlan
                devs = (list(placement.ex1.devices)
                        + list(placement.ex2.devices))
                new_pl = type(placement).from_plan(plan, devs)
                sched.request_migration(MigrationPlan(
                    placement=new_pl, fns=factory(new_pl), capacity=cap,
                    reason=f"controller-replan:q={q:.3f}"))
                applied = "migration"
            elif cap is not None and hasattr(sched, "request_capacity"):
                sched.request_capacity(cap)
                applied = "capacity"
        st.log("replan", q=q,
               plan=(None if plan is None else (plan.chips1, plan.chips2)),
               recovered_throughput_ratio=recovered, applied=applied)

    # -- actuator 2: autoscaling ---------------------------------------------

    def _autoscale(self, sched) -> None:
        """Occupancy/latency feedback over the last control window, one
        bounded step per pass:

          * starved pool (live slots below the bucket size) with healthy
            fill -> raise ``eager_drain_below``: partial buckets beat a
            starved stage 1;
          * rich pool with thin buckets (fill under ``starvation_fill``)
            -> lower it: bucket bubbles waste the provisioned stage 2;
          * p99 latency over the SLO -> shrink the live-occupancy cap
            (admission-side, by attrition) — queueing delay is traded for
            utilization; back under the SLO with no backpressure stalls ->
            grow it back toward the pool size.
        """
        st = self.state
        win = self.window
        if win.ticks == 0:
            return
        changed = {}
        cap_bucket = getattr(getattr(sched, "sc", None), "capacity", None)
        eager = getattr(sched, "eager_drain_below", None)
        if eager is not None and cap_bucket:
            if (win.mean_active < cap_bucket
                    and win.mean_bucket_fill >= self.cfg.starvation_fill
                    and eager < cap_bucket):
                sched.set_eager_drain_below(eager + 1)
                changed["eager_drain_below"] = eager + 1
            elif (win.mean_active >= cap_bucket
                  and 0 < win.mean_bucket_fill < self.cfg.starvation_fill
                  and eager > 0):
                sched.set_eager_drain_below(eager - 1)
                changed["eager_drain_below"] = eager - 1
        slo = self.cfg.latency_slo_p99
        if slo is not None and hasattr(sched, "set_active_cap"):
            # WINDOWED p99 — over the last latency_window finishes, not the
            # lifetime reservoir: a transient overload must age out of the
            # feedback signal or the cap ratchets down and never recovers
            p99 = self._recent_p99(sched.stats)
            cap = sched.active_cap
            if p99 is None:
                pass                     # no new evidence: hold the cap
            elif p99 > slo and cap > self.cfg.min_active_cap:
                sched.set_active_cap(cap - 1)
                changed["active_cap"] = cap - 1
            elif (p99 <= slo and win.stall_rate == 0.0
                  and cap < sched.n_slots):
                sched.set_active_cap(cap + 1)
                changed["active_cap"] = cap + 1
        if changed:
            st.n_autoscale_events += 1
            st.log("autoscale", window=win.as_dict(), **changed)

    def _recent_p99(self, stats) -> Optional[float]:
        """p99 over the most recent ``latency_window`` finished requests
        (None when nothing has finished yet)."""
        lat = stats.latencies
        n = len(lat)
        if n == 0:
            return None
        k = self.cfg.latency_window
        tail = list(itertools.islice(lat, max(0, n - k), n))
        return float(np.percentile(np.asarray(tail), 99.0))
