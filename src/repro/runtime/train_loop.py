"""Fault-tolerant training loop.

train_step = joint BranchyNet EE loss (all samples traverse all layers at
training time — the stage split is a *serving* feature, matching the paper
where training happens offline) + AdamW. The loop provides:

  - periodic async checkpoints (atomic commit protocol, checkpoint/ckpt.py);
  - restore-on-start: resumes from the newest committed step, replaying the
    deterministic data stream from there (bit-exact — tested);
  - failure injection: ``fail_at_step`` raises mid-run to exercise restart;
  - straggler mitigation: data fetches run under a timeout with re-issue.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as CK
from repro.core import early_exit as ee
from repro.core import losses
from repro.data import pipeline as dp
from repro.models.config import ArchConfig
from repro.optim import adamw


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    fail_at_step: Optional[int] = None          # failure injection
    fetch_timeout_s: float = 30.0
    straggler: dp.StragglerModel = field(
        default_factory=lambda: dp.StragglerModel(0.0))
    optim: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)


class InjectedFailure(RuntimeError):
    pass


def make_train_step(cfg: ArchConfig, spec: ee.EarlyExitSpec,
                    opt: adamw.AdamWConfig, *, donate: bool = True):
    """Jitted (params, opt_state, tokens, labels) -> (params, opt_state,
    metrics). The EE joint loss backpropagates through both heads."""

    def loss_fn(params, tokens, labels):
        eh, fh, aux = ee.forward_train(params, cfg, spec, tokens)
        loss, parts = losses.branchynet_joint_loss(
            params, cfg, eh, fh, labels, spec.loss_weights, aux=aux)
        return loss, parts

    def step(params, opt_state, tokens, labels):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, tokens, labels)
        params, opt_state, om = adamw.update(opt, opt_state, params, grads)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    kw = {"donate_argnums": (0, 1)} if donate else {}
    return jax.jit(step, **kw)


def train(cfg: ArchConfig, spec: ee.EarlyExitSpec, tc: TrainConfig, *,
          stream_spec: dp.LMStreamSpec, seed: int = 0,
          on_step: Optional[Callable[[int, dict], None]] = None) -> dict:
    """Run (or resume) training. Returns final {params, opt_state, step,
    history}. Restores from tc.ckpt_dir when a committed step exists."""
    key = jax.random.PRNGKey(seed)
    params = ee.init_ee_params(key, cfg, spec)
    opt_state = adamw.init(tc.optim, params)

    start = 0
    latest = CK.latest_step(tc.ckpt_dir)
    if latest is not None:
        state = CK.restore(tc.ckpt_dir, latest,
                           {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        opt_state = adamw.AdamWState(*opt_state.values()) if isinstance(
            opt_state, dict) else opt_state
        start = latest
    step_fn = make_train_step(cfg, spec, tc.optim)
    ckpt = CK.AsyncCheckpointer(tc.ckpt_dir, keep=tc.keep)
    history = []

    for t in range(start, tc.steps):
        def fetch(t=t):
            tc.straggler.maybe_stall()
            return dp.lm_batch(stream_spec, t)

        (tokens, labels), timed_out = dp.fetch_with_timeout(
            fetch, timeout_s=tc.fetch_timeout_s,
            backup=lambda t=t: dp.lm_batch(stream_spec, t))
        params, opt_state, metrics = step_fn(
            params, opt_state, jnp.asarray(tokens), jnp.asarray(labels))

        if tc.fail_at_step is not None and t == tc.fail_at_step:
            ckpt.wait()
            raise InjectedFailure(f"injected failure at step {t}")

        if (t + 1) % tc.ckpt_every == 0 or t + 1 == tc.steps:
            ckpt.save_async(t + 1, {"params": params, "opt": opt_state},
                            extra={"timed_out": bool(timed_out)})
        if (t + 1) % tc.log_every == 0 or t + 1 == tc.steps:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": t + 1, **m})
            if on_step:
                on_step(t + 1, m)
    ckpt.wait()
    return {"params": params, "opt_state": opt_state, "step": tc.steps,
            "history": history}


def train_with_restarts(cfg: ArchConfig, spec: ee.EarlyExitSpec,
                        tc: TrainConfig, *, stream_spec: dp.LMStreamSpec,
                        max_restarts: int = 3, seed: int = 0) -> dict:
    """Supervisor: rerun ``train`` across injected/real failures. After the
    first failure the injection is disarmed (the node is 'replaced')."""
    attempts = 0
    while True:
        try:
            out = train(cfg, spec, tc, stream_spec=stream_spec, seed=seed)
            out["restarts"] = attempts
            return out
        except InjectedFailure:
            attempts += 1
            if attempts > max_restarts:
                raise
            tc.fail_at_step = None           # node replaced; resume from ckpt
