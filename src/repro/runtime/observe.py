"""Observability plane for the early-exit serving fleet.

Three layers, all zero-cost when unused:

1. **Request-span tracing** — :class:`Tracer` subscribes to the scheduler /
   router / fault ``EventLog`` feeds and assembles per-request span trees
   (submit -> queue-wait -> admit -> decode -> stage-2 park episodes ->
   finish, with route/preempt/migrate/fault instants as annotations).
   Export as JSONL (one span or annotation per line) or as Chrome
   ``trace_event`` JSON so a whole fleet run opens in Perfetto /
   ``chrome://tracing``.

2. **Metrics export** — :class:`MetricsRegistry` with a FROZEN name+label
   schema (:data:`METRICS_SCHEMA`, key set locked in tests like the
   ServeStats v3 dict), fed by :class:`StatsSampler` over ``ServeStats`` /
   ``FleetStats`` plus kernel-backend resolution and jit-cache counters.
   Prometheus text exposition via :func:`MetricsRegistry.exposition`, a
   zero-dependency stdlib HTTP endpoint (:class:`MetricsServer`) and a
   one-shot :func:`dump_metrics` file mode.

3. **Profiler hooks** — :func:`annotate` wraps host-side hot sections in
   ``jax.profiler.TraceAnnotation`` (only while a :class:`ProfileWindow`
   is active; a shared nullcontext otherwise), and :class:`ProfileWindow`
   opens an opt-in ``jax.profiler`` trace capture for N scheduler ticks
   so TPU runs produce attributable xprof timelines. The jitted bodies
   themselves carry ``jax.named_scope`` labels (trace-time metadata,
   zero runtime cost).

The tracing layer never touches device values: it rides the host-side
event feed the scheduler already maintains, so token streams are bitwise
unchanged with observability on, and the overhead gate in
``benchmarks/serve_observed.py`` holds goodput at >= 0.95x unobserved.
"""

from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Dict, List, Optional, Tuple

__all__ = [
    "Tracer", "MetricsRegistry", "StatsSampler", "MetricsServer",
    "ProfileWindow", "METRICS_SCHEMA", "annotate", "profiling_active",
    "parse_exposition", "dump_metrics", "export_events_jsonl",
    "jit_cache_entries",
]


# ---------------------------------------------------------------------------
# Layer 1: request-span tracing
# ---------------------------------------------------------------------------

# Synthetic Chrome "thread" ids for non-request tracks.
_TID_SCHED = 1_000_000   # scheduler tick / bucket track
_TID_CTRL = 1_000_001    # migration / fault / router control track


_EV_STRIP = ("seq", "t", "event", "sid")


def _ev_args(ev: dict) -> dict:
    """An event's payload fields (everything but the envelope keys)."""
    return {k: v for k, v in ev.items() if k not in _EV_STRIP}


class Tracer:
    """Assembles per-request span trees from ``EventLog`` feeds.

    Attach to any number of scheduler and router feeds; events arrive
    synchronously (the ``EventLog`` subscriber contract) so assembly is
    single-threaded with the emitter. Spans close in wall-clock time
    (``ev["t"]``); the scheduler's logical clock never leaks into traces.

    Span kinds per request ``sid``:

    - ``request``    submit -> finish (the root; exactly one per sid)
    - ``queue_wait`` submit -> admit
    - ``decode``     admit -> finish
    - ``stage2_wait`` park -> bucket dispatch (zero or more episodes)

    Annotations (instants): ``route``, ``preempt``, ``requeue``, ``tick``,
    ``bucket``, ``migrate_*``, ``inject``/``retry`` fault events, and any
    unrecognized tag (kept, never dropped, so feeds stay lossless).
    """

    def __init__(self):
        # Hot-path storage is tuples referencing the ALREADY-allocated event
        # dicts, not fresh per-span dicts: the assembly callback runs inside
        # the scheduler's emit, and every container allocated there feeds
        # gc generations that then rescan the whole retained trace during
        # the serving run. Dict views materialize lazily via the ``spans`` /
        # ``annotations`` properties (export time, off the hot path).
        self._span_rows: List[tuple] = []      # (name, sid, t0, t1, rep, pay)
        self._ann_rows: List[tuple] = []       # (name, sid, t, rep, tid, ev)
        self._open: Dict[object, dict] = {}    # sid -> open-state record
        self._done: set = set()                # sids with closed roots
        self._orphans: set = set()             # events for never-submitted sids
        self._feeds: List[tuple] = []          # (log, callback)
        self._lock = threading.Lock()

    # -- feed attachment ----------------------------------------------------

    def attach(self, log, *, replica: int = 0):
        """Subscribe to an ``EventLog``; events are labeled ``replica``."""
        cb = lambda ev, _r=replica: self.on_event(ev, _r)  # noqa: E731
        log.subscribe(cb)
        self._feeds.append((log, cb))
        return self

    def attach_scheduler(self, sched, *, replica: int = 0):
        """Attach a scheduler's event feed (requires ``events=`` wiring)."""
        if getattr(sched, "events", None) is None:
            raise ValueError("scheduler has no event feed: build it with "
                             "events=EventLog(...) to trace it")
        return self.attach(sched.events, replica=replica)

    def attach_router(self, router, *, replica: int = -1):
        """Attach a ``FleetRouter``'s feed (route/preempt instants; the
        router's submit seeds the root span before any replica sees it)."""
        return self.attach(router.events, replica=replica)

    def attach_faults(self, log=None, *, replica: int = -1):
        """Attach the fault-injection log (``faults.LOG`` by default)."""
        if log is None:
            from repro.runtime import faults
            log = faults.LOG
        return self.attach(log, replica=replica)

    def close(self) -> None:
        """Unsubscribe from every attached feed."""
        for log, cb in self._feeds:
            try:
                log.unsubscribe(cb)
            except ValueError:
                pass
        self._feeds = []

    # -- assembly -----------------------------------------------------------

    def on_event(self, ev: dict, replica: int = 0) -> None:
        with self._lock:
            self._on_event(ev, replica)

    def _on_event(self, ev: dict, replica: int) -> None:
        tag = ev.get("event")
        t = ev["t"]
        sid = ev.get("sid")
        if tag == "submit":
            st = self._open.get(sid)
            if st is None and sid not in self._done:
                # Router and scheduler both emit submit; first one wins so
                # the root covers the full fleet-level lifetime.
                self._open[sid] = {"t_submit": t, "t_admit": None,
                                   "t_park": None, "replica": replica,
                                   "parks": 0, "ev": ev}
            return
        if tag == "admit":
            st = self._need(sid, t, replica)
            if st is None:
                return
            st["replica"] = replica
            if st["t_admit"] is None:
                self._span_rows.append(
                    ("queue_wait", sid, st["t_submit"], t, replica, None))
                st["t_admit"] = t
                st["slot"] = ev.get("slot")
            return
        if tag == "park":
            # batched: one event per tick carrying every newly parked sid
            for s in ev.get("sids", () if sid is None else (sid,)):
                st = self._need(s, t, replica)
                if st is not None and st["t_park"] is None:
                    st["t_park"] = t
            return
        if tag == "bucket":
            for s in ev.get("sids", ()):
                st = self._open.get(s)
                if st is not None and st["t_park"] is not None:
                    self._span_rows.append(
                        ("stage2_wait", s, st["t_park"], t, st["replica"],
                         ev.get("take")))
                    st["t_park"] = None
                    st["parks"] += 1
            self._ann_rows.append(("bucket", None, t, replica, _TID_SCHED,
                                   ev))
            return
        if tag == "finish":
            st = self._need(sid, t, replica)
            if st is None:
                return
            if st["t_park"] is not None:    # parked at finish: close episode
                self._span_rows.append(
                    ("stage2_wait", sid, st["t_park"], t, st["replica"],
                     None))
                st["parks"] += 1
            t_admit = st["t_admit"] if st["t_admit"] is not None else t
            self._span_rows.append(
                ("decode", sid, t_admit, t, st["replica"], st["parks"]))
            self._span_rows.append(
                ("request", sid, st["t_submit"], t, st["replica"],
                 (st["ev"], ev)))
            del self._open[sid]
            self._done.add(sid)
            return
        if tag == "tick":
            self._ann_rows.append(("tick", None, t, replica, _TID_SCHED, ev))
            return
        # route / preempt / requeue / degrade / restore / migrate_* /
        # inject / retry / anything future: keep as an annotation.
        self._ann_rows.append(
            (tag, sid, t, replica, _TID_CTRL if sid is None else None, ev))

    def _need(self, sid, t, replica) -> Optional[dict]:
        st = self._open.get(sid)
        if st is None:
            if sid not in self._done:
                self._orphans.add(sid)
            return None
        return st

    # -- materialized views (export time, off the hot path) -----------------

    @property
    def spans(self) -> List[dict]:
        out = []
        for name, sid, t0, t1, replica, payload in self._span_rows:
            if name == "request":
                sub_ev, fin_ev = payload
                args = _ev_args(sub_ev)
                for k in ("n_decisions", "n_hard"):
                    if k in fin_ev:
                        args[k] = fin_ev[k]
            elif name == "decode":
                args = {"n_parks": payload}
            elif name == "stage2_wait" and payload is not None:
                args = {"take": payload}
            else:
                args = {}
            out.append({"kind": "span", "name": name, "sid": sid,
                        "replica": replica, "t0": t0, "t1": t1, "args": args})
        return out

    @property
    def annotations(self) -> List[dict]:
        return [{"kind": "instant", "name": name, "sid": sid,
                 "replica": replica, "t": t, "tid": tid,
                 "args": _ev_args(ev)}
                for name, sid, t, replica, tid, ev in self._ann_rows]

    # -- completeness -------------------------------------------------------

    def finished_sids(self) -> set:
        return set(self._done)

    def open_sids(self) -> set:
        return set(self._open)

    def orphan_sids(self) -> set:
        return set(self._orphans)

    def completeness(self, expect_sids=None) -> dict:
        """Structural audit of the assembled trees.

        Every finished request must have exactly one ``request`` root, all
        its other spans nested inside the root interval, no orphan events,
        and (when ``expect_sids`` is given) cover exactly that id set.
        """
        roots: Dict[object, List[tuple]] = {}
        children: Dict[object, List[tuple]] = {}
        for row in self._span_rows:
            (roots if row[0] == "request" else children).setdefault(
                row[1], []).append(row)
        bad_roots = sorted(str(s) for s, r in roots.items() if len(r) != 1)
        missing = sorted(str(s) for s in self._done if s not in roots)
        nested = True
        for sid, kids in children.items():
            r = roots.get(sid)
            if r is None:
                nested = False
                continue
            lo, hi = r[0][2], r[0][3]
            for k in kids:
                if not (lo <= k[2] <= k[3] <= hi):
                    nested = False
        uncovered = []
        if expect_sids is not None:
            uncovered = sorted(str(s) for s in expect_sids
                               if s not in self._done)
        complete = (not bad_roots and not missing and nested
                    and not self._orphans and not self._open
                    and not uncovered)
        return {"complete": complete, "n_finished": len(self._done),
                "n_spans": len(self._span_rows),
                "n_annotations": len(self._ann_rows),
                "open": sorted(str(s) for s in self._open),
                "orphans": sorted(str(s) for s in self._orphans),
                "bad_roots": bad_roots, "missing_roots": missing,
                "nested": nested, "uncovered": uncovered}

    def complete(self, expect_sids=None) -> bool:
        return self.completeness(expect_sids)["complete"]

    # -- export -------------------------------------------------------------

    def export_jsonl(self, path: str) -> int:
        """One JSON object per line: all spans then all annotations."""
        n = 0
        with open(path, "w") as f:
            for rec in self.spans + self.annotations:
                f.write(json.dumps(rec, default=str) + "\n")
                n += 1
        return n

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON (Perfetto / chrome://tracing).

        pid = replica, tid = request sid (hashed to an int when needed),
        ``ph: "X"`` complete events with microsecond timestamps rebased to
        the first event so coordinates stay small.
        """
        spans, anns = self.spans, self.annotations
        events: List[dict] = []
        t_base = min([s["t0"] for s in spans]
                     + [a["t"] for a in anns], default=0.0)
        pids = set()

        def tid_of(sid):
            if sid is None:
                return _TID_CTRL
            if isinstance(sid, int):
                return sid
            return hash(str(sid)) % 900_000

        for s in spans:
            pid = int(s["replica"])
            pids.add(pid)
            events.append({
                "name": s["name"], "cat": "request", "ph": "X",
                "ts": (s["t0"] - t_base) * 1e6,
                "dur": max((s["t1"] - s["t0"]) * 1e6, 0.0),
                "pid": pid, "tid": tid_of(s["sid"]),
                "args": {"sid": str(s["sid"]), **s["args"]},
            })
        for a in anns:
            pid = int(a["replica"])
            pids.add(pid)
            events.append({
                "name": a["name"], "cat": "annotation", "ph": "i", "s": "p",
                "ts": (a["t"] - t_base) * 1e6, "pid": pid,
                "tid": a["tid"] if a["tid"] is not None else tid_of(a["sid"]),
                "args": {k: str(v) for k, v in a["args"].items()},
            })
        for pid in sorted(pids):
            name = "router" if pid < 0 else f"replica{pid}"
            events.append({"name": "process_name", "ph": "M", "pid": pid,
                           "tid": 0, "args": {"name": name}})
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": _TID_SCHED, "args": {"name": "scheduler"}})
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": _TID_CTRL, "args": {"name": "control"}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> int:
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return len(trace["traceEvents"])


def export_events_jsonl(path: str, log, **extra) -> int:
    """Append an ``EventLog``'s retained events to ``path`` as JSONL.

    The shared exporter behind ``faults.flush_log`` and ``--spans-out``
    style dumps: every line is ``{**extra, **event}``. Returns the number
    of lines written. Does NOT clear the log (callers own that)."""
    events = log.as_list()
    if not events:
        return 0
    with open(path, "a") as f:
        for ev in events:
            f.write(json.dumps({**extra, **ev}, default=str) + "\n")
    return len(events)


# ---------------------------------------------------------------------------
# Layer 2: metrics registry + Prometheus exposition
# ---------------------------------------------------------------------------

# FROZEN schema: (name, kind, label names, help). Adding/renaming entries
# requires updating the frozen key-set test in tests/test_observe.py —
# exactly like the ServeStats v3 dict. kind: c=counter g=gauge h=histogram.
METRICS_SCHEMA: Tuple[Tuple[str, str, Tuple[str, ...], str], ...] = (
    ("repro_requests_submitted_total", "c", ("replica",),
     "Requests accepted into a scheduler queue"),
    ("repro_requests_finished_total", "c", ("replica",),
     "Requests fully decoded"),
    ("repro_decisions_total", "c", ("replica",),
     "Exit decisions taken (stage-1 steps)"),
    ("repro_exited_total", "c", ("replica",),
     "Decisions that exited early at stage 1"),
    ("repro_stage2_total", "c", ("replica",),
     "Decisions escalated to stage 2"),
    ("repro_stalls_total", "c", ("replica",),
     "Ring-full backpressure stalls"),
    ("repro_buckets_total", "c", ("replica",),
     "Stage-2 bucket dispatches"),
    ("repro_ring_bytes_moved_total", "c", ("replica",),
     "Bytes moved through the inter-stage ring"),
    ("repro_migrations_total", "c", ("replica",),
     "Completed live migrations"),
    ("repro_migration_rollbacks_total", "c", ("replica",),
     "Live migrations rolled back"),
    ("repro_realized_q", "g", ("replica",),
     "Realized hard fraction q (lifetime)"),
    ("repro_realized_q_ewma", "g", ("replica",),
     "Realized q, exponentially weighted"),
    ("repro_q_drift", "g", ("replica",),
     "realized_q_ewma - provisioned p"),
    ("repro_stage1_occupancy", "g", ("replica",),
     "Busy slot fraction of the stage-1 pool"),
    ("repro_stage2_occupancy", "g", ("replica",),
     "Parked-lane fraction of stage-2 capacity"),
    ("repro_mean_bucket_fill", "g", ("replica",),
     "Mean stage-2 bucket fill fraction"),
    ("repro_slots_busy", "g", ("replica",),
     "Busy decode slots"),
    ("repro_queue_depth", "g", ("replica",),
     "Requests waiting for admission"),
    ("repro_cache_pages_total", "g", ("replica",),
     "Allocatable KV pages in the paged pool"),
    ("repro_cache_pages_in_use", "g", ("replica",),
     "KV pages currently allocated"),
    ("repro_cache_pages_in_use_peak", "g", ("replica",),
     "High-water mark of allocated KV pages (page-pool watermark)"),
    ("repro_cache_hbm_bytes", "g", ("replica",),
     "Bytes resident in the stage-2 KV store"),
    ("repro_page_fragmentation", "g", ("replica",),
     "Allocated-but-unused tail fraction of in-use pages"),
    ("repro_events_dropped_total", "c", ("feed",),
     "EventLog events lost to the cap (FIFO overwrite)"),
    ("repro_routed_total", "c", ("policy",),
     "Router placements by policy"),
    ("repro_preemptions_total", "c", (),
     "Queued-request preemptions (requeue-never-drop)"),
    ("repro_fleet_pending", "g", (),
     "Router-level pending requests"),
    ("repro_backend_resolutions_total", "c", (),
     "kernel_backend() memo misses (fresh resolutions)"),
    ("repro_jit_cache_entries", "g", (),
     "Compiled-executable cache entries across serving jits (retrace "
     "counter)"),
    ("repro_scrapes_total", "c", (),
     "Metrics exposition renders (HTTP scrapes + dumps)"),
    ("repro_request_latency_seconds", "h", ("replica",),
     "Submit-to-finish latency (scheduler clock)"),
)

_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
            0.25, 0.5, 1.0, 2.5, 5.0, 10.0)
_KINDS = {"c": "counter", "g": "gauge", "h": "histogram"}


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _Metric:
    def __init__(self, name: str, kind: str, labels: Tuple[str, ...],
                 help_: str):
        self.name, self.kind, self.labels, self.help = name, kind, labels, help_
        self.series: Dict[Tuple[str, ...], object] = {}

    def _key(self, labelvals: Dict[str, str]) -> Tuple[str, ...]:
        if set(labelvals) != set(self.labels):
            raise ValueError(
                f"{self.name}: labels must be exactly {self.labels}, "
                f"got {tuple(sorted(labelvals))}")
        return tuple(str(labelvals[k]) for k in self.labels)

    def _labelstr(self, key: Tuple[str, ...]) -> str:
        if not self.labels:
            return ""
        inner = ",".join(f'{k}="{v}"' for k, v in zip(self.labels, key))
        return "{" + inner + "}"

    # counters -------------------------------------------------------------
    def inc(self, amount: float = 1.0, **labels) -> None:
        assert self.kind == "c", self.name
        k = self._key(labels)
        self.series[k] = self.series.get(k, 0.0) + amount

    def set_total(self, value: float, **labels) -> None:
        """Set a sampled monotone total (counters fed from lifetime
        sources like ``ServeStats`` rather than discrete increments)."""
        assert self.kind == "c", self.name
        k = self._key(labels)
        self.series[k] = max(float(value), float(self.series.get(k, 0.0)))

    # gauges ---------------------------------------------------------------
    def set(self, value: float, **labels) -> None:
        assert self.kind == "g", self.name
        self.series[self._key(labels)] = float(value)

    def set_max(self, value: float, **labels) -> None:
        """High-water gauge: keeps the max ever observed."""
        assert self.kind == "g", self.name
        k = self._key(labels)
        self.series[k] = max(float(value), float(self.series.get(k, value)))

    # histograms -----------------------------------------------------------
    def observe(self, value: float, **labels) -> None:
        assert self.kind == "h", self.name
        k = self._key(labels)
        st = self.series.get(k)
        if st is None:
            st = {"buckets": [0] * len(_BUCKETS), "sum": 0.0, "count": 0}
            self.series[k] = st
        v = float(value)
        for i, le in enumerate(_BUCKETS):
            if v <= le:
                st["buckets"][i] += 1
        st["sum"] += v
        st["count"] += 1

    def value(self, **labels) -> float:
        return self.series.get(self._key(labels), 0.0)

    # exposition -----------------------------------------------------------
    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {_KINDS[self.kind]}"]
        if self.kind == "h":
            for key in sorted(self.series):
                st = self.series[key]
                base = self._labelstr(key)

                def lab(le_s, _b=base):
                    return (_b[:-1] + f',le="{le_s}"}}') if _b \
                        else f'{{le="{le_s}"}}'
                # observe() increments every bucket with v <= le, so the
                # stored counts are already cumulative as Prometheus wants.
                for le, n in zip(_BUCKETS, st["buckets"]):
                    lines.append(f"{self.name}_bucket{lab(_fmt(le))} {n}")
                lines.append(
                    f"{self.name}_bucket{lab('+Inf')} {st['count']}")
                lines.append(f"{self.name}_sum{base} {_fmt(st['sum'])}")
                lines.append(f"{self.name}_count{base} {st['count']}")
        else:
            for key in sorted(self.series):
                lines.append(f"{self.name}{self._labelstr(key)} "
                             f"{_fmt(self.series[key])}")
        return lines


class MetricsRegistry:
    """Closed registry: every metric comes from :data:`METRICS_SCHEMA`.

    Unknown names raise — the exported surface is a frozen contract, like
    the ``ServeStats`` dict key set, so dashboards never silently break."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {
            name: _Metric(name, kind, labels, help_)
            for name, kind, labels, help_ in METRICS_SCHEMA}
        self._lock = threading.Lock()

    def get(self, name: str) -> _Metric:
        m = self._metrics.get(name)
        if m is None:
            raise KeyError(f"unknown metric {name!r}: the schema is frozen "
                           f"(see observe.METRICS_SCHEMA)")
        return m

    def __iter__(self):
        return iter(self._metrics.values())

    def exposition(self) -> str:
        with self._lock:
            self.get("repro_scrapes_total").inc()
            lines: List[str] = []
            for m in self._metrics.values():
                lines.extend(m.render())
        return "\n".join(lines) + "\n"


def parse_exposition(text: str) -> Dict[str, float]:
    """Parse Prometheus text exposition into ``{name{labels}: value}``.

    Strict enough for the CI smoke: raises ``ValueError`` on any
    non-comment line that is not ``name[{labels}] value``."""
    out: Dict[str, float] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            key, val = line.rsplit(None, 1)
        except ValueError:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        name = key.split("{", 1)[0]
        if not name.replace("_", "").replace(":", "").isalnum():
            raise ValueError(f"line {lineno}: bad metric name {name!r}")
        if "{" in key and not key.endswith("}"):
            raise ValueError(f"line {lineno}: unterminated labels {key!r}")
        out[key] = float(val)
    if not out:
        raise ValueError("no samples in exposition")
    return out


# ---------------------------------------------------------------------------
# Cadence sampler over ServeStats / FleetStats + jit counters
# ---------------------------------------------------------------------------

class StatsSampler:
    """Pull-model bridge from live serving objects into the registry.

    ``sample()`` reads every attached source once; ``maybe_sample()``
    honors ``cadence_s`` and is cheap enough to ride the scheduler's
    per-tick event feed (attachment does that automatically when the
    scheduler has an event log)."""

    def __init__(self, registry: MetricsRegistry, cadence_s: float = 0.25):
        self.registry = registry
        self.cadence_s = float(cadence_s)
        self._scheds: List[tuple] = []     # (sched, replica_label)
        self._routers: List[object] = []
        self._logs: List[tuple] = []       # (feed_label, EventLog)
        self._subs: List[tuple] = []       # (log, cb) for detach
        self._last = 0.0
        self.n_samples = 0

    # -- attachment ---------------------------------------------------------

    def attach_scheduler(self, sched, *, replica: int = 0):
        self._scheds.append((sched, str(replica)))
        ev = getattr(sched, "events", None)
        if ev is not None:
            self._logs.append((f"sched{replica}", ev))
            cb = lambda _ev: self.maybe_sample()  # noqa: E731
            ev.subscribe(cb)
            self._subs.append((ev, cb))
        return self

    def attach_router(self, router):
        self._routers.append(router)
        self._logs.append(("router", router.events))
        reg = self.registry
        routed = reg.get("repro_routed_total")
        preempt = reg.get("repro_preemptions_total")

        def cb(ev):
            tag = ev.get("event")
            if tag == "route":
                routed.inc(policy=ev.get("policy", "unknown"))
            elif tag == "preempt":
                preempt.inc()
        router.events.subscribe(cb)
        self._subs.append((router.events, cb))
        return self

    def attach_log(self, label: str, log):
        self._logs.append((label, log))
        return self

    def close(self) -> None:
        for log, cb in self._subs:
            try:
                log.unsubscribe(cb)
            except ValueError:
                pass
        self._subs = []

    # -- sampling -----------------------------------------------------------

    def maybe_sample(self) -> bool:
        now = time.monotonic()
        if now - self._last < self.cadence_s:
            return False
        self.sample()
        return True

    def sample(self) -> None:
        self._last = time.monotonic()
        self.n_samples += 1
        reg = self.registry
        for sched, rep in self._scheds:
            self._sample_sched(reg, sched, rep)
        for router in self._routers:
            reg.get("repro_fleet_pending").set(len(router._pending))
        for label, log in self._logs:
            reg.get("repro_events_dropped_total").set_total(
                log.n_dropped, feed=label)
        from repro.kernels import dispatch as _dispatch
        reg.get("repro_backend_resolutions_total").set_total(
            _dispatch.n_backend_resolutions())
        reg.get("repro_jit_cache_entries").set(jit_cache_entries())

    def _sample_sched(self, reg, sched, rep) -> None:
        st = getattr(sched, "stats", None)
        if st is None:
            return
        reg.get("repro_requests_submitted_total").set_total(
            st.n_finished + len(st.submit_times), replica=rep)
        reg.get("repro_requests_finished_total").set_total(
            st.n_finished, replica=rep)
        reg.get("repro_decisions_total").set_total(
            st.n_decisions, replica=rep)
        reg.get("repro_stage2_total").set_total(st.n_stage2, replica=rep)
        reg.get("repro_exited_total").set_total(st.n_exited, replica=rep)
        reg.get("repro_stalls_total").set_total(st.n_stalls, replica=rep)
        reg.get("repro_buckets_total").set_total(st.n_buckets, replica=rep)
        reg.get("repro_ring_bytes_moved_total").set_total(
            st.ring_bytes_moved, replica=rep)
        reg.get("repro_migrations_total").set_total(
            st.n_migrations, replica=rep)
        reg.get("repro_migration_rollbacks_total").set_total(
            st.n_migration_rollbacks, replica=rep)
        reg.get("repro_realized_q").set(st.realized_q, replica=rep)
        reg.get("repro_realized_q_ewma").set(st.realized_q_ewma, replica=rep)
        reg.get("repro_q_drift").set(st.q_drift, replica=rep)
        reg.get("repro_stage1_occupancy").set(
            st.stage1_occupancy, replica=rep)
        reg.get("repro_stage2_occupancy").set(
            st.stage2_occupancy, replica=rep)
        reg.get("repro_mean_bucket_fill").set(st.mean_bucket_fill,
                                              replica=rep)
        reg.get("repro_cache_pages_total").set(st.cache_pages_total,
                                               replica=rep)
        reg.get("repro_cache_pages_in_use").set(st.cache_pages_in_use,
                                                replica=rep)
        reg.get("repro_cache_pages_in_use_peak").set_max(
            st.cache_pages_in_use, replica=rep)
        reg.get("repro_cache_hbm_bytes").set(st.cache_hbm_bytes, replica=rep)
        reg.get("repro_page_fragmentation").set(st.page_fragmentation,
                                                replica=rep)
        qd = getattr(sched, "queue", None)
        if qd is not None:
            reg.get("repro_queue_depth").set(len(qd), replica=rep)
        busy = getattr(sched, "n_busy", None)
        if busy is not None:
            reg.get("repro_slots_busy").set(
                busy() if callable(busy) else busy, replica=rep)
        # Latency histogram: feed only the tail that arrived since the
        # previous sample (the deque is bounded; n_finished is lifetime).
        key = id(sched)
        seen = getattr(self, "_lat_seen", None)
        if seen is None:
            seen = self._lat_seen = {}
        prev = seen.get(key, 0)
        lat = st.latencies
        new = st.n_finished - prev
        if new > 0:
            hist = reg.get("repro_request_latency_seconds")
            for v in list(lat)[-min(new, len(lat)):]:
                hist.observe(v, replica=rep)
            seen[key] = st.n_finished


def jit_cache_entries() -> int:
    """Total compiled-executable cache entries across the serving jits —
    the retrace/recompile counter (same ``_cache_size`` the tier-1 tests
    assert single-launch ticks with). Best-effort: jits without the
    private API count as 0."""
    total = 0
    try:
        from repro.runtime import scheduler as _sched
        from repro.kernels import dispatch as _dispatch
        fns = [getattr(_sched, n, None) for n in
               ("_pool_tick", "_pool_tick_fused", "_admit_stage1",
                "_unpark_lanes", "_ring_enqueue_range", "ring_drain")]
        fns += [getattr(_dispatch, n, None) for n in
                ("_exit_decision", "_gather_compact",
                 "_fused_dispatch_donated", "_fused_dispatch_copy",
                 "_paged_gather_append_donated",
                 "_paged_gather_append_copy")]
        for fn in fns:
            size = getattr(fn, "_cache_size", None)
            if callable(size):
                try:
                    total += int(size())
                except Exception:
                    pass
    except Exception:
        return 0
    return total


# ---------------------------------------------------------------------------
# Zero-dependency HTTP exposition + one-shot dump
# ---------------------------------------------------------------------------

class MetricsServer:
    """``/metrics`` over stdlib ``http.server`` in a daemon thread.

    ``port=0`` binds an ephemeral port; read ``.port`` after ``start()``.
    Each scrape pulls a fresh ``sampler.sample()`` first (pull-model), so
    an idle scheduler still exposes its latest state."""

    def __init__(self, registry: MetricsRegistry,
                 sampler: Optional[StatsSampler] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self.registry, self.sampler = registry, sampler
        self._host, self._port_req = host, port
        self._httpd = None
        self._thread = None

    @property
    def port(self) -> int:
        if self._httpd is None:
            raise RuntimeError("server not started")
        return self._httpd.server_address[1]

    def start(self) -> "MetricsServer":
        import http.server

        registry, sampler = self.registry, self.sampler

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib API)
                if self.path.split("?")[0] not in ("/metrics", "/"):
                    self.send_error(404)
                    return
                try:
                    if sampler is not None:
                        sampler.sample()
                    body = registry.exposition().encode()
                except Exception as e:  # surface, never hang the scraper
                    self.send_error(500, str(e))
                    return
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # silence per-request stderr noise
                pass

        self._httpd = http.server.ThreadingHTTPServer(
            (self._host, self._port_req), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-metrics",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._thread.join(timeout=5)
            self._httpd = self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()


def dump_metrics(registry: MetricsRegistry, path: str,
                 sampler: Optional[StatsSampler] = None) -> str:
    """One-shot exposition to a file (the ``--metrics-dump`` mode)."""
    if sampler is not None:
        sampler.sample()
    text = registry.exposition()
    with open(path, "w") as f:
        f.write(text)
    return text


# ---------------------------------------------------------------------------
# Layer 3: profiler hooks
# ---------------------------------------------------------------------------

_PROFILING = False
_NULL_CTX = contextlib.nullcontext()


def profiling_active() -> bool:
    return _PROFILING


def annotate(name: str):
    """Host-side profiler annotation for a hot section.

    A shared nullcontext unless a :class:`ProfileWindow` is open, so the
    steady-state tick pays one global load + one compare. Inside a
    window it becomes ``jax.profiler.TraceAnnotation`` and the section
    shows up on the xprof host timeline."""
    if not _PROFILING:
        return _NULL_CTX
    import jax
    return jax.profiler.TraceAnnotation(name)


class ProfileWindow:
    """Opt-in ``jax.profiler`` capture window (``--profile-dir``).

    Starts a trace into ``logdir`` on ``__enter__``; stops after
    ``n_ticks`` scheduler ticks when given an event feed (counted on
    ``tick`` events), or at ``__exit__`` otherwise. While open,
    :func:`annotate` sections are live."""

    def __init__(self, logdir: str, n_ticks: Optional[int] = None,
                 events=None):
        self.logdir = logdir
        self.n_ticks = n_ticks
        self.events = events
        self._ticks = 0
        self._active = False
        self._cb = None

    def __enter__(self):
        global _PROFILING
        import jax
        jax.profiler.start_trace(self.logdir)
        self._active = True
        _PROFILING = True
        if self.events is not None and self.n_ticks is not None:
            def cb(ev):
                if ev.get("event") == "tick":
                    self._ticks += 1
                    if self._ticks >= self.n_ticks:
                        self._stop()
            self._cb = self.events.subscribe(cb)
        return self

    def _stop(self) -> None:
        global _PROFILING
        if not self._active:
            return
        self._active = False
        _PROFILING = False
        import jax
        jax.profiler.stop_trace()
        if self._cb is not None and self.events is not None:
            try:
                self.events.unsubscribe(self._cb)
            except ValueError:
                pass
            self._cb = None

    def __exit__(self, *exc):
        self._stop()
