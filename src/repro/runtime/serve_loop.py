"""Two-stage Early-Exit serving runtime (the paper's Fig. 3 pipeline).

Stage 1 (full batch) -> Exit Decision -> Conditional Buffer (compaction into
fixed-capacity hard-sample buckets) -> Stage 2 (buckets only) -> Exit Merge
by Sample ID. Between the stages sits a bounded hard-sample queue — the
conditional buffer's occupancy is the paper's Fig. 7 deadlock/sizing story
and yields the Fig. 4 q-vs-p robustness behaviour:

  q < p : stage 2 under-fed, bucket bubbles, stage 1 limits throughput;
  q > p : queue grows; when full, stage 1 stalls (backpressure) and
          throughput degrades by ~p/q — exactly the shaded band.

The runtime tracks realized q and reports occupancy/stall statistics so a
deployment can re-plan (``core.stage_mesh``) when drift is persistent.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import conditional as cond
from repro.core import early_exit as ee
from repro.core import exit_decision as ed
from repro.models.config import ArchConfig


@dataclass
class ServeConfig:
    capacity: int                   # stage-2 bucket size (ceil(p*B) rounded)
    queue_depth: int = 4            # buckets the buffer can hold
    c_thr: float = 0.9


@dataclass
class ServeStats:
    n_samples: int = 0
    n_exited: int = 0
    n_stage2: int = 0
    n_stalls: int = 0
    bucket_fill: List[float] = field(default_factory=list)

    @property
    def realized_q(self) -> float:
        return self.n_stage2 / max(self.n_samples, 1)

    def as_dict(self):
        return {"n_samples": self.n_samples, "n_exited": self.n_exited,
                "n_stage2": self.n_stage2, "n_stalls": self.n_stalls,
                "realized_q": self.realized_q,
                "mean_bucket_fill": float(np.mean(self.bucket_fill))
                if self.bucket_fill else 0.0}


class TwoStageServer:
    """Batch-level EE server over jitted stage callables.

    stage1_fn: tokens (B, S) -> (hidden, exit_logits)
    stage2_fn: hidden slab (C, S, d) -> final logits (C, V)
    In a stage-mesh deployment each callable is jitted onto its own submesh
    (launch/serve.py); here they may share one device.
    """

    def __init__(self, stage1_fn: Callable, stage2_fn: Callable,
                 sc: ServeConfig):
        self.stage1 = stage1_fn
        self.stage2 = stage2_fn
        self.sc = sc
        self.queue: deque = deque()          # (hidden_row, sample_id) pairs
        self.stats = ServeStats()

    def _drain_bucket(self, results: dict):
        """Pop up to ``capacity`` queued hard samples, run stage 2, merge."""
        take = min(len(self.queue), self.sc.capacity)
        if take == 0:
            return
        rows, ids = zip(*[self.queue.popleft() for _ in range(take)])
        slab = jnp.stack(list(rows))
        if take < self.sc.capacity:          # flush slots (paper §III-C.2)
            pad = jnp.broadcast_to(slab[:1],
                                   (self.sc.capacity - take,) + slab.shape[1:])
            slab = jnp.concatenate([slab, pad])
        logits = self.stage2(slab)
        for i, sid in enumerate(ids):
            results[sid] = np.asarray(logits[i])
        self.stats.n_stage2 += take
        self.stats.bucket_fill.append(take / self.sc.capacity)

    def submit(self, tokens: np.ndarray, sample_ids: np.ndarray,
               results: dict):
        """Serve one stage-1 batch; easy samples resolve immediately, hard
        ones enqueue. Buckets drain whenever a full bucket is available; if
        the queue would overflow, drain first (stage-1 backpressure stall)."""
        hidden, exit_logits = self.stage1(jnp.asarray(tokens))
        exit_mask, pred, conf = ed.decision_and_argmax(
            exit_logits, self.sc.c_thr)
        exit_mask = np.asarray(exit_mask)
        self.stats.n_samples += len(sample_ids)
        for i, sid in enumerate(sample_ids):
            if exit_mask[i]:
                results[sid] = np.asarray(exit_logits[i])
                self.stats.n_exited += 1
            else:
                if len(self.queue) >= self.sc.queue_depth * self.sc.capacity:
                    self.stats.n_stalls += 1
                    self._drain_bucket(results)
                self.queue.append((jnp.asarray(hidden[i]), int(sid)))
        while len(self.queue) >= self.sc.capacity:
            self._drain_bucket(results)

    def flush(self, results: dict):
        while self.queue:
            self._drain_bucket(results)


def build_server(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                 sc: ServeConfig) -> TwoStageServer:
    """Single-host server over the EE model (examples + tests)."""

    @jax.jit
    def s1(tokens):
        h, _, logits, _ = ee.stage1_prefill(params, cfg, spec, tokens)
        return h, logits

    @jax.jit
    def s2(slab):
        logits, _ = ee.stage2_prefill(params, cfg, spec, slab)
        return logits

    return TwoStageServer(s1, s2, sc)


def serve_dataset(server: TwoStageServer, tokens: np.ndarray,
                  batch: int) -> dict:
    """Run a whole token set through the server in stage-1 batches.
    Returns {sample_id: logits} plus the stats object."""
    n = tokens.shape[0]
    results: dict = {}
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        server.submit(tokens[lo:hi], np.arange(lo, hi), results)
    server.flush(results)
    return results
