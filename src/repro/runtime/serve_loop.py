"""Two-stage Early-Exit serving runtime (the paper's Fig. 3 pipeline),
device-resident.

Stage 1 (full batch) -> Exit Decision -> Conditional Buffer (compaction into
fixed-capacity hard-sample buckets) -> Stage 2 (buckets only) -> Exit Merge
by Sample ID. Between the stages sits a bounded hard-sample queue — the
conditional buffer's occupancy is the paper's Fig. 7 deadlock/sizing story
and yields the Fig. 4 q-vs-p robustness behaviour:

  q < p : stage 2 under-fed, bucket bubbles, stage 1 limits throughput;
  q > p : queue grows; when full, stage 1 stalls (backpressure) and
          throughput degrades by ~p/q — exactly the shaded band.

**Device residency.** ATHEENA's throughput comes from keeping the exit
machinery on-chip: the FPGA conditional buffer never round-trips a feature
map through host memory. ``TwoStageServer`` mirrors that:

  * the exit decision + compaction run as ONE jitted step per stage-1 batch
    through the kernel dispatch layer (``kernels.dispatch``): the fused
    ``exit_decision_op`` streams the (B, V) logits from HBM once — no
    materialized softmax — and ``gather_compact_op`` emits the hard-sample
    slab without leaving the device;
  * hard samples carry over between stage-1 batches in a preallocated
    **device-side ring buffer** — a ``(queue_depth * capacity, S, d)`` slab
    plus int32 head/count cursors — updated in place by jitted
    ``ring_enqueue`` / ``ring_drain`` steps with ``donate_argnums`` so no
    copy of the queue ever exists. The old implementation (kept below as
    ``HostLoopServer``, the benchmark baseline) instead synced each hidden
    row to host, held it in a Python ``deque`` and re-stacked it per bucket;
  * drains are asynchronous: stage 2 is dispatched on a bucket and only the
    (ids, logits) futures are retained; nothing calls
    ``block_until_ready``/``np.asarray`` until ``flush()``, so results leave
    the device in one per-bucket transfer and stage 2 overlaps with
    subsequent stage-1 batches. The single host sync per batch is the scalar
    ``n_hard`` needed for backpressure control flow.

**Ring sizing / deadlock avoidance (paper Fig. 7).** The ring holds
``queue_depth * capacity`` samples. A stage-1 batch whose hard count exceeds
the free space enqueues in chunks, stalling stage 1 between chunks while
*full* buckets drain — partial (flush-padded) buckets waste stage-2 capacity
and are used only when no full bucket exists. Any batch size is therefore
correct even against a tiny ring (no deadlock, no drop); an undersized ring
just stalls stage 1 harder — the paper's Fig. 7 minimum-depth sizing is a
throughput constraint, surfaced by ``ServeStats.n_stalls``, not a
correctness one.

The runtime tracks realized q and reports occupancy/stall statistics so a
deployment can re-plan (``core.stage_mesh``) when drift is persistent.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import early_exit as ee
from repro.core import exit_decision as ed
from repro.kernels import dispatch
from repro.models.config import ArchConfig


@dataclass
class ServeConfig:
    capacity: int                   # stage-2 bucket size (ceil(p*B) rounded)
    queue_depth: int = 4            # buckets the buffer can hold
    c_thr: float = 0.9
    max_pending: int = 16           # pending device result groups (stage-1
                                    # batches + stage-2 buckets) before the
                                    # oldest are harvested to host, bounding
                                    # device memory on long-running streams


@dataclass
class ServeStats:
    n_samples: int = 0
    n_exited: int = 0
    n_stage2: int = 0
    n_stalls: int = 0
    n_buckets: int = 0              # running aggregate, O(1) memory
    bucket_fill_sum: float = 0.0

    def record_bucket(self, fill: float) -> None:
        self.n_buckets += 1
        self.bucket_fill_sum += fill

    @property
    def mean_bucket_fill(self) -> float:
        return self.bucket_fill_sum / self.n_buckets if self.n_buckets else 0.0

    @property
    def realized_q(self) -> float:
        return self.n_stage2 / max(self.n_samples, 1)

    def as_dict(self):
        return {"n_samples": self.n_samples, "n_exited": self.n_exited,
                "n_stage2": self.n_stage2, "n_stalls": self.n_stalls,
                "realized_q": self.realized_q,
                "mean_bucket_fill": self.mean_bucket_fill}


# ---------------------------------------------------------------------------
# device-side ring buffer: preallocated slab + int32 cursors, updated in
# place (donated) by jitted steps
# ---------------------------------------------------------------------------

def ring_init(size: int, row_shape: Tuple[int, ...], dtype) -> dict:
    """Allocate the ring: {'hidden' (size, *row), 'ids' (size,), 'head' (),
    'count' ()} — ids slots are -1 (the paper's unused Sample ID)."""
    return {
        "hidden": jnp.zeros((size,) + tuple(row_shape), dtype),
        "ids": jnp.full((size,), -1, jnp.int32),
        "head": jnp.zeros((), jnp.int32),
        "count": jnp.zeros((), jnp.int32),
    }


@functools.partial(jax.jit, donate_argnums=(0,))
def _ring_enqueue_range(buf: dict, slab, slab_ids, lo, hi) -> dict:
    """Append slab rows [lo, min(hi, n_valid)) at the ring's tail, where
    n_valid is the compacted slab's valid prefix (ids >= 0). The donated
    buffer is updated in place; unselected rows scatter out of bounds and
    are dropped. The caller guarantees the selected range fits."""
    size = buf["ids"].shape[0]
    n = slab_ids.shape[0]
    n_valid = jnp.sum(slab_ids >= 0).astype(jnp.int32)
    upper = jnp.minimum(hi, n_valid)
    lanes = jnp.arange(n, dtype=jnp.int32)
    sel = (lanes >= lo) & (lanes < upper)
    idx = (buf["head"] + buf["count"] + lanes - lo) % size
    idx = jnp.where(sel, idx, size)                  # OOB -> dropped
    return {
        "hidden": buf["hidden"].at[idx].set(slab, mode="drop"),
        "ids": buf["ids"].at[idx].set(slab_ids, mode="drop"),
        "head": buf["head"],
        "count": buf["count"] + jnp.maximum(upper - lo, 0),
    }


def ring_enqueue(buf: dict, slab: jnp.ndarray, slab_ids: jnp.ndarray) -> dict:
    """Append the whole valid prefix of a compacted slab (ids >= 0) at the
    ring's tail; see ``_ring_enqueue_range``."""
    return _ring_enqueue_range(buf, slab, slab_ids, 0, slab_ids.shape[0])


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("capacity",))
def ring_drain(buf: dict, capacity: int
               ) -> Tuple[dict, jnp.ndarray, jnp.ndarray]:
    """Pop up to ``capacity`` samples from the ring's head into a stage-2
    bucket. Returns (buf, bucket (capacity, *row), bucket_ids (capacity,))
    — slots past the take carry id -1 (flush) and whatever stale rows the
    ring holds (stage 2 is row-independent, flush rows are discarded by the
    exit merge)."""
    size = buf["ids"].shape[0]
    take_n = jnp.minimum(buf["count"], capacity).astype(jnp.int32)
    lanes = jnp.arange(capacity, dtype=jnp.int32)
    idx = (buf["head"] + lanes) % size
    valid = lanes < take_n
    bucket = jnp.take(buf["hidden"], idx, axis=0)
    bucket_ids = jnp.where(valid, jnp.take(buf["ids"], idx), -1)
    new = {
        "hidden": buf["hidden"],
        "ids": buf["ids"].at[jnp.where(valid, idx, size)].set(
            -1, mode="drop"),
        "head": (buf["head"] + take_n) % size,
        "count": buf["count"] - take_n,
    }
    return new, bucket, bucket_ids


@functools.partial(jax.jit, static_argnames=("backend",))
def _decide_compact(hidden, exit_logits, sample_ids, c_thr, *, backend):
    """Fused exit decision + conditional-buffer compaction, one device
    program shared by every server instance (c_thr is traced, so a new
    threshold never recompiles; the resolved kernel backend is a static
    arg, so a dispatch override is honored rather than baked in at first
    trace). Compaction capacity = the stage-1 batch, so no hard sample is
    ever dropped here; the ring applies backpressure."""
    exit_mask, _, _ = dispatch.exit_decision_op(exit_logits, c_thr,
                                                backend=backend)
    b = hidden.shape[0]
    slab, pos, n_hard = dispatch.gather_compact_op(hidden, ~exit_mask, b,
                                                   backend=backend)
    slab_ids = jnp.where(pos >= 0,
                         jnp.take(sample_ids, jnp.maximum(pos, 0)), -1)
    return slab, slab_ids, n_hard, exit_mask


# ---------------------------------------------------------------------------
# device-resident two-stage server
# ---------------------------------------------------------------------------

class TwoStageServer:
    """Batch-level EE server over jitted stage callables, device-resident.

    stage1_fn: tokens (B, S) -> (hidden, exit_logits)
    stage2_fn: hidden slab (C, S, d) -> final logits (C, V)
    In a stage-mesh deployment each callable is jitted onto its own submesh
    (launch/serve.py); here they may share one device.

    ``submit`` keeps everything on device: one jitted step runs stage 1 +
    fused exit decision + compaction, the hard slab is enqueued into the
    device ring, and full buckets are dispatched to stage 2 asynchronously.
    Results (easy exit logits, per-bucket stage-2 logits) stay device-side
    as futures until ``flush`` collects them — one transfer per batch /
    bucket, ``block_until_ready`` only at flush.
    """

    def __init__(self, stage1_fn: Callable, stage2_fn: Callable,
                 sc: ServeConfig):
        self.stage1 = stage1_fn
        self.stage2 = stage2_fn
        self.sc = sc
        self.size = sc.queue_depth * sc.capacity
        self.stats = ServeStats()
        self._buf: Optional[dict] = None
        self._count = 0                       # host mirror of buf['count']
        # pending device futures, collected at flush()
        self._easy: List[Tuple[np.ndarray, jnp.ndarray, jnp.ndarray]] = []
        self._buckets: List[Tuple[jnp.ndarray, jnp.ndarray]] = []

    # -- internal ------------------------------------------------------------

    @staticmethod
    def _collect_easy(entry, results: dict) -> None:
        sids, exit_mask, exit_logits = entry
        mask = np.asarray(exit_mask)
        logits = np.asarray(exit_logits)
        for i in np.nonzero(mask)[0]:
            results[int(sids[i])] = logits[i]

    @staticmethod
    def _collect_bucket(entry, results: dict) -> None:
        bucket_ids, logits = entry
        ids = np.asarray(bucket_ids)
        logits = np.asarray(logits)
        for i in np.nonzero(ids >= 0)[0]:
            results[int(ids[i])] = logits[i]

    def _harvest_oldest(self, results: dict) -> None:
        """Collect the oldest pending result groups until the backlog fits
        ``max_pending``. The oldest futures were dispatched many batches
        ago, so this rarely blocks — it just keeps device-side result
        memory O(max_pending * B * V) instead of O(total requests)."""
        while len(self._easy) + len(self._buckets) > self.sc.max_pending:
            if self._easy:
                self._collect_easy(self._easy.pop(0), results)
            else:
                self._collect_bucket(self._buckets.pop(0), results)

    def _drain(self) -> None:
        """Pop one bucket from the ring and dispatch stage 2 (async)."""
        take = min(self._count, self.sc.capacity)
        if take == 0:
            return
        self._buf, bucket, bucket_ids = ring_drain(self._buf,
                                                   self.sc.capacity)
        logits = self.stage2(bucket)
        self._buckets.append((bucket_ids, logits))
        self._count -= take
        self.stats.n_stage2 += take
        self.stats.record_bucket(take / self.sc.capacity)

    # -- public --------------------------------------------------------------

    def submit(self, tokens: np.ndarray, sample_ids: np.ndarray,
               results: dict):
        """Serve one stage-1 batch. Easy samples' exit logits and hard
        samples' hidden rows never leave the device; full buckets drain
        asynchronously whenever available. If the ring cannot absorb the
        batch's hard samples, stage 1 stalls (backpressure) and full buckets
        drain first — partial buckets only when no full one exists.

        ``results`` is filled lazily: entries appear when pending futures
        are harvested (backlog > ``max_pending``) and at ``flush()`` —
        unlike HostLoopServer, a sample's logits are NOT guaranteed to be
        present right after the submit that resolved it."""
        tokens = jnp.asarray(tokens)
        ids_dev = jnp.asarray(np.asarray(sample_ids, np.int32))
        hidden, exit_logits = self.stage1(tokens)
        slab, slab_ids, n_hard_dev, exit_mask = _decide_compact(
            hidden, exit_logits, ids_dev, self.sc.c_thr,
            backend=dispatch.kernel_backend())
        n_hard = int(n_hard_dev)              # the one host sync per batch
        b = int(tokens.shape[0])
        self.stats.n_samples += b
        self.stats.n_exited += b - n_hard
        self._easy.append((np.asarray(sample_ids), exit_mask, exit_logits))
        if n_hard > 0:
            if self._buf is None:
                self._buf = ring_init(self.size, slab.shape[1:], slab.dtype)
            # enqueue in chunks, stalling (draining) whenever the ring is
            # out of space — so a batch hairier than the whole ring still
            # serves, it just backpressures stage 1 harder (Fig. 7 story)
            off = 0
            while off < n_hard:
                free = self.size - self._count
                if free == 0:
                    self.stats.n_stalls += 1
                    self._drain()             # full buckets first by
                    continue                  # construction (count==size)
                take = min(free, n_hard - off)
                self._buf = _ring_enqueue_range(self._buf, slab, slab_ids,
                                                off, off + take)
                self._count += take
                off += take
        while self._count >= self.sc.capacity:
            self._drain()
        self._harvest_oldest(results)

    def flush(self, results: dict):
        """Drain the ring (partial final bucket included) and collect every
        pending device future into ``results`` — the only point that
        deliberately blocks on the device."""
        while self._count > 0:
            self._drain()
        pending = ([x for t in self._easy for x in t[1:]]
                   + [x for t in self._buckets for x in t])
        if pending:
            jax.block_until_ready(pending)
        for entry in self._easy:
            self._collect_easy(entry, results)
        for entry in self._buckets:
            self._collect_bucket(entry, results)
        self._easy.clear()
        self._buckets.clear()


# ---------------------------------------------------------------------------
# the seed's host-loop server — kept verbatim as the benchmark baseline
# (benchmarks/serve_pipeline.py measures the device-resident speedup
# against it) and as the e2e parity oracle in tests
# ---------------------------------------------------------------------------

class HostLoopServer:
    """Per-sample host-loop EE server (pre-device-resident implementation):
    syncs each hard hidden row to host, queues it in a Python deque and
    re-stacks it per bucket. Same interface as TwoStageServer."""

    def __init__(self, stage1_fn: Callable, stage2_fn: Callable,
                 sc: ServeConfig):
        self.stage1 = stage1_fn
        self.stage2 = stage2_fn
        self.sc = sc
        self.queue: deque = deque()          # (hidden_row, sample_id) pairs
        self.stats = ServeStats()

    def _drain_bucket(self, results: dict):
        """Pop up to ``capacity`` queued hard samples, run stage 2, merge."""
        take = min(len(self.queue), self.sc.capacity)
        if take == 0:
            return
        rows, ids = zip(*[self.queue.popleft() for _ in range(take)])
        slab = jnp.stack(list(rows))
        if take < self.sc.capacity:          # flush slots (paper §III-C.2)
            pad = jnp.broadcast_to(slab[:1],
                                   (self.sc.capacity - take,) + slab.shape[1:])
            slab = jnp.concatenate([slab, pad])
        logits = self.stage2(slab)
        for i, sid in enumerate(ids):
            results[sid] = np.asarray(logits[i])
        self.stats.n_stage2 += take
        self.stats.record_bucket(take / self.sc.capacity)

    def submit(self, tokens: np.ndarray, sample_ids: np.ndarray,
               results: dict):
        """Serve one stage-1 batch; easy samples resolve immediately, hard
        ones enqueue. Buckets drain whenever a full bucket is available; if
        the queue would overflow, drain first (stage-1 backpressure stall)."""
        hidden, exit_logits = self.stage1(jnp.asarray(tokens))
        exit_mask, pred, conf = ed.decision_and_argmax(
            exit_logits, self.sc.c_thr)
        exit_mask = np.asarray(exit_mask)
        self.stats.n_samples += len(sample_ids)
        for i, sid in enumerate(sample_ids):
            if exit_mask[i]:
                results[sid] = np.asarray(exit_logits[i])
                self.stats.n_exited += 1
            else:
                if len(self.queue) >= self.sc.queue_depth * self.sc.capacity:
                    self.stats.n_stalls += 1
                    self._drain_bucket(results)
                self.queue.append((jnp.asarray(hidden[i]), int(sid)))
        while len(self.queue) >= self.sc.capacity:
            self._drain_bucket(results)

    def flush(self, results: dict):
        while self.queue:
            self._drain_bucket(results)


def _stage_fns(params, cfg: ArchConfig, spec: ee.EarlyExitSpec):
    @jax.jit
    def s1(tokens):
        h, _, logits, _ = ee.stage1_prefill(params, cfg, spec, tokens)
        return h, logits

    @jax.jit
    def s2(slab):
        logits, _ = ee.stage2_prefill(params, cfg, spec, slab)
        return logits

    return s1, s2


def build_server(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                 sc: ServeConfig) -> TwoStageServer:
    """Single-host device-resident server over the EE model."""
    s1, s2 = _stage_fns(params, cfg, spec)
    return TwoStageServer(s1, s2, sc)


def build_host_server(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                      sc: ServeConfig) -> HostLoopServer:
    """The legacy host-loop server (benchmark baseline / parity oracle)."""
    s1, s2 = _stage_fns(params, cfg, spec)
    return HostLoopServer(s1, s2, sc)


def serve_dataset(server, tokens: np.ndarray, batch: int) -> dict:
    """Run a whole token set through the server in stage-1 batches.
    Returns {sample_id: logits} plus the stats object."""
    n = tokens.shape[0]
    results: dict = {}
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        server.submit(tokens[lo:hi], np.arange(lo, hi), results)
    server.flush(results)
    return results
