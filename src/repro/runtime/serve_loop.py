"""Two-stage Early-Exit serving runtime (the paper's Fig. 3 pipeline),
device-resident, for both prefill and autoregressive decode.

Stage 1 (full batch) -> Exit Decision -> Conditional Buffer (compaction into
fixed-capacity hard-sample buckets) -> Stage 2 (buckets only) -> Exit Merge
by Sample ID. Between the stages sits a bounded hard-sample queue — the
conditional buffer's occupancy is the paper's Fig. 7 deadlock/sizing story
and yields the Fig. 4 q-vs-p robustness behaviour:

  q < p : stage 2 under-fed, bucket bubbles, stage 1 limits throughput;
  q > p : queue grows; when full, stage 1 stalls (backpressure) and
          throughput degrades by ~p/q — exactly the shaded band.

**Device residency.** ATHEENA's throughput comes from keeping the exit
machinery on-chip: the FPGA conditional buffer never round-trips a feature
map through host memory. The servers here mirror that:

  * the exit decision + compaction run as ONE jitted step per stage-1 batch
    through the kernel dispatch layer (``kernels.dispatch``): the fused
    ``exit_decision_op`` streams the (B, V) logits from HBM once — no
    materialized softmax — and ``gather_compact_op`` emits the hard-sample
    slab without leaving the device;
  * hard samples carry over between stage-1 batches in a preallocated
    **device-side ring buffer** over an arbitrary **pytree payload**: every
    leaf is a ``(size, *row)`` slab sharing one set of int32 head/count
    cursors and one Sample-ID lane, updated in place by jitted
    ``ring_enqueue`` / ``ring_drain`` steps with ``donate_argnums`` so no
    copy of the queue ever exists. Prefill rings carry the bare hidden slab;
    decode rings carry ``{hidden row, stage-2 KV-cache segment row}``. The
    pre-device-resident implementation (kept below as ``HostLoopServer``,
    the benchmark baseline) instead synced each hidden row to host, held it
    in a Python ``deque`` and re-stacked it per bucket;
  * drains are asynchronous: stage 2 is dispatched on a bucket and only the
    (ids, logits) futures are retained; nothing calls
    ``block_until_ready``/``np.asarray`` until ``flush()``, so results leave
    the device in one per-bucket transfer and stage 2 overlaps with
    subsequent stage-1 batches. The single host sync per batch is the scalar
    ``n_hard`` needed for backpressure control flow.

**Decode serving (``DecodeServer``).** Autoregressive decode makes the exit
decision *per token*: every decode step runs ``ee.stage1_decode`` on the
full token batch, and only the hard tokens' hidden rows — together with
those samples' stage-2 KV-cache segment rows (``ee.split_caches``) — travel
through the ring into bucketed ``ee.stage2_decode`` dispatches. Updated
bucket cache rows are scattered back into the sample-major stage-2 cache
store on device. Decode is step-synchronous (token t+1 of a sample needs
its token-t logits), so the ring drains fully at the end of each step; its
job is device-side bucketing + backpressure within the step. A token that
exits early skips stage 2 entirely, so its stage-2 cache keeps zeros at
that position — the *exit-gap* semantics shared bitwise with the host-loop
baseline (cf. the cache-handling challenges in Laskaridis et al. 2021).

**Ring sizing / deadlock avoidance (paper Fig. 7).** The ring holds
``queue_depth * capacity`` samples. A stage-1 batch whose hard count exceeds
the free space enqueues in chunks, stalling stage 1 between chunks while
*full* buckets drain — partial (flush-padded) buckets waste stage-2 capacity
and are used only when no full bucket exists. Any batch size is therefore
correct even against a tiny ring (no deadlock, no drop); an undersized ring
just stalls stage 1 harder — the paper's Fig. 7 minimum-depth sizing is a
throughput constraint, surfaced by ``ServeStats.n_stalls``, not a
correctness one. For decode rings each row additionally carries the sample's
stage-2 cache segment, so ring bytes scale with ``max_len`` — size
``queue_depth`` down accordingly.

**Stage disaggregation.** Every server runs over a ``StagePlacement``
(runtime/stage_executor.py): stage 1 + the exit-decision kernels on one
``StageExecutor``, the pytree ring + stage 2 on the other. With submeshes
carved from a ``StageMeshPlan`` (chips apportioned to each stage in
proportion to p — the paper's §IV spatial resource split), params are
resident per stage (``ee.split_params``) and the hard-sample slab / bucket
results hop between submeshes as ``jax.device_put`` transfers. The default
placement is degenerate (no mesh, placement = identity), so single-device
serving is the same hot loop, bit for bit — parity the disaggregation tests
enforce under ``--xla_force_host_platform_device_count``.

The runtime tracks realized q *per decision* (= per sample for prefill, per
token for decode) and reports per-stage occupancy/stall statistics plus
per-request latency so a deployment can re-plan (``core.stage_mesh``) when
drift is persistent.

**Continuous batching.** The step-synchronous servers here are the ``sync``
scheduling policy. ``runtime/scheduler.py`` owns the slot-based
``ContinuousScheduler`` (per-slot step counters, admission queue, HAPI-style
staged dispatch) that trades this file's bitwise batch parity for
utilization; the ring primitives, ``RingQueue`` backpressure plumbing,
``ServeConfig`` and ``ServeStats`` live there and are re-exported here so
the two policies share one implementation.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import Callable, List, NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import early_exit as ee
from repro.core import exit_decision as ed
from repro.kernels import dispatch
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.runtime.stage_executor import StagePlacement
from repro.runtime import faults, serve_api
# the scheduler module owns the shared serving substrate; re-exported names
# keep this module the one import site for serving callers and tests
from repro.runtime.scheduler import (  # noqa: F401  (re-exports)
    ContinuousScheduler, HarvestTimeout, Request, RingQueue, ServeConfig,
    ServeStats, SyncScheduler, _gather_rows, _ring_enqueue_range,
    _scatter_rows, bounded_wait, ring_drain, ring_enqueue, ring_init)
from repro.runtime.serve_api import (  # noqa: F401  (re-exports)
    ReplicaHandle, RequestQueue, build, validate_request)


@functools.partial(jax.jit, static_argnames=("backend",))
def _decide_compact(hidden, exit_logits, sample_ids, c_thr, *, backend):
    """Fused exit decision + conditional-buffer compaction, one device
    program shared by every server instance (c_thr is traced, so a new
    threshold never recompiles; the resolved kernel backend is a static
    arg, so a dispatch override is honored rather than baked in at first
    trace). Compaction capacity = the stage-1 batch, so no hard sample is
    ever dropped here; the ring applies backpressure. The per-row
    confidences the fused kernel already computes ride along for the
    drift-telemetry reservoir (free on device; only fetched when a
    controller is listening), as do the greedy preds — the decode merge
    path emits them instead of re-running argmax over the logits."""
    exit_mask, pred, conf = dispatch.exit_decision_op(exit_logits, c_thr,
                                                      backend=backend)
    b = hidden.shape[0]
    slab, pos, n_hard = dispatch.gather_compact_op(hidden, ~exit_mask, b,
                                                   backend=backend)
    slab_ids = jnp.where(pos >= 0,
                         jnp.take(sample_ids, jnp.maximum(pos, 0)), -1)
    return slab, slab_ids, n_hard, exit_mask, pred, conf


# ---------------------------------------------------------------------------
# shared ring plumbing: the step-synchronous servers sit on the scheduler's
# RingQueue (chunked enqueue under backpressure + bucket pops) — one ring
# implementation for prefill, sync decode and continuous decode
# ---------------------------------------------------------------------------

class _RingedServer:
    def __init__(self, sc: ServeConfig,
                 placement: Optional[StagePlacement] = None):
        self.sc = sc
        self.placement = placement or StagePlacement.single_device()
        self.ex1 = self.placement.ex1
        self.ex2 = self.placement.ex2    # the ring + stage 2 live here
        self.stats = ServeStats()
        self.stats.record_placement(self.placement)
        self.ring = RingQueue(sc, self.ex2, self.stats)
        # control surface: the live threshold (traced — re-aiming it never
        # recompiles) and an optional telemetry sink for the per-decision
        # confidences (None = no extra host fetch on the hot path)
        self.c_thr = float(sc.c_thr)
        self.conf_sink = None

    def set_c_thr(self, c_thr: float) -> None:
        self.c_thr = float(c_thr)

    @property
    def _count(self) -> int:             # host mirror of the ring count
        return self.ring.count

    def _drain(self) -> None:             # pop one bucket + dispatch stage 2
        raise NotImplementedError

    def _enqueue_backpressured(self, slab_tree, slab_ids, n_hard: int) -> None:
        """Enqueue ``n_hard`` valid rows of a compacted slab pytree in
        chunks, stalling (draining) whenever the ring is out of space — see
        ``scheduler.RingQueue.enqueue`` (the Fig. 7 backpressure story)."""
        self.ring.enqueue(slab_tree, slab_ids, n_hard, self._drain)

    def _use_fused(self) -> bool:
        """The fused dispatch op (decision + compaction + in-ring enqueue,
        one program) applies when stage 1 and the ring share a submesh; a
        disaggregated placement keeps the composed chain, whose enqueue IS
        the cross-submesh hop."""
        return not self.placement.disaggregated

    def _fused_dispatch_enqueue(self, exit_logits, sample_ids, payload,
                                row_spec):
        """One fused op replaces exit_decision -> gather_compact -> per-leaf
        ring scatter: compacted hard rows land directly in the ring slabs
        at (head+count) offsets, with the ring buffer donated through the
        op. Syncs the scalar n_hard (+ confidences when a sink listens —
        the same single host sync as the composed path), advances the
        ring's host count mirror, and pushes any overflow past the ring's
        free space through the composed backpressure chain (identical
        stall/drain ordering). Returns (exit_mask, pred, conf, n_hard)."""
        ring_buf = self.ring.ensure(row_spec)
        (ring_buf, exit_mask, pred, conf, src,
         n_hard_dev) = dispatch.fused_dispatch_op(
            exit_logits, None, sample_ids, payload, ring_buf, self.c_thr)
        self.ring.put_buf(ring_buf)
        if self.conf_sink is not None:        # rides the n_hard sync
            n_hard_dev, conf_np = jax.device_get((n_hard_dev, conf))
            self.conf_sink.extend(conf_np)
        n_hard = int(n_hard_dev)              # the one host sync
        if n_hard > 0:
            # the enqueue already happened in-op; its fault boundary keeps
            # the composed visit cadence (once per hard batch)
            faults.fault_point("enqueue")
            n_enq = min(n_hard, self.ring.size - self.ring.count)
            self.ring.note_enqueued(n_enq)
            if n_enq < n_hard:                # ring filled mid-batch: spill
                slab = _gather_rows(payload, src)
                ids = jnp.where(src >= 0,
                                jnp.take(sample_ids, jnp.maximum(src, 0)),
                                -1)
                self.ring.enqueue(slab, ids, n_hard, self._drain,
                                  off=n_enq, fire_fault=False)
        return exit_mask, pred, conf, n_hard

    def _pop_bucket(self):
        """Pop up to ``capacity`` rows; returns (bucket pytree, ids) or
        None when the ring is empty. Updates occupancy stats."""
        popped = self.ring.pop()
        if popped is None:
            return None
        bucket, bucket_ids, _ = popped
        return bucket, bucket_ids


# ---------------------------------------------------------------------------
# device-resident two-stage prefill server
# ---------------------------------------------------------------------------

class TwoStageServer(_RingedServer):
    """Batch-level EE server over jitted stage callables, device-resident.

    stage1_fn: tokens (B, S) -> (hidden, exit_logits)
    stage2_fn: hidden slab (C, S, d) -> final logits (C, V)

    ``placement`` decides WHERE: stage 1 (and the exit-decision kernels) on
    ``placement.ex1``, the ring and stage 2 on ``placement.ex2``. With a
    disaggregated placement (StagePlacement.from_plan over disjoint
    submeshes) the callables must close over params placed on their own
    executor (``_stage_fns`` does this), and the hard-slab enqueue becomes
    a device-to-device transfer across the submesh boundary. The default
    placement is the degenerate single-device one — the hot path is then
    identical to a placement-unaware server, bit for bit.

    ``submit`` keeps everything on device: one jitted step runs stage 1 +
    fused exit decision + compaction, the hard slab is enqueued into the
    device ring, and full buckets are dispatched to stage 2 asynchronously.
    Results (easy exit logits, per-bucket stage-2 logits) stay device-side
    as futures until ``flush`` collects them — one transfer per batch /
    bucket, ``block_until_ready`` only at flush.
    """

    def __init__(self, stage1_fn: Callable, stage2_fn: Callable,
                 sc: ServeConfig,
                 placement: Optional[StagePlacement] = None):
        super().__init__(sc, placement)
        self.stage1 = stage1_fn
        self.stage2 = stage2_fn
        # pending device futures, collected at flush()
        self._easy: List[Tuple[np.ndarray, jnp.ndarray, jnp.ndarray]] = []
        self._buckets: List[Tuple[jnp.ndarray, jnp.ndarray]] = []

    # -- internal ------------------------------------------------------------

    @staticmethod
    def _collect_easy(entry, results: dict) -> None:
        sids, exit_mask, exit_logits = entry
        mask = np.asarray(exit_mask)
        logits = np.asarray(exit_logits)
        for i in np.nonzero(mask)[0]:
            results[int(sids[i])] = logits[i]

    @staticmethod
    def _collect_bucket(entry, results: dict) -> None:
        bucket_ids, logits = entry
        ids = np.asarray(bucket_ids)
        logits = np.asarray(logits)
        for i in np.nonzero(ids >= 0)[0]:
            results[int(ids[i])] = logits[i]

    def _harvest_oldest(self, results: dict) -> None:
        """Collect the oldest pending result groups until the backlog fits
        ``max_pending``. The oldest futures were dispatched many batches
        ago, so this rarely blocks — it just keeps device-side result
        memory O(max_pending * B * V) instead of O(total requests)."""
        while len(self._easy) + len(self._buckets) > self.sc.max_pending:
            if self._easy:
                self._collect_easy(self._easy.pop(0), results)
            else:
                self._collect_bucket(self._buckets.pop(0), results)

    def _drain(self) -> None:
        """Pop one bucket from the ring and dispatch stage 2 (async)."""
        popped = self._pop_bucket()
        if popped is None:
            return
        bucket, bucket_ids = popped
        logits = self.stage2(bucket)
        self._buckets.append((bucket_ids, logits))

    # -- public --------------------------------------------------------------

    def submit(self, tokens: np.ndarray, sample_ids: np.ndarray,
               results: dict):
        """Serve one stage-1 batch. Easy samples' exit logits and hard
        samples' hidden rows never leave the device; full buckets drain
        asynchronously whenever available. If the ring cannot absorb the
        batch's hard samples, stage 1 stalls (backpressure) and full buckets
        drain first — partial buckets only when no full one exists.

        ``results`` is filled lazily: entries appear when pending futures
        are harvested (backlog > ``max_pending``) and at ``flush()`` —
        unlike HostLoopServer, a sample's logits are NOT guaranteed to be
        present right after the submit that resolved it."""
        tokens = self.ex1.place_io(jnp.asarray(tokens))
        ids_dev = self.ex1.place_io(jnp.asarray(np.asarray(sample_ids,
                                                           np.int32)))
        hidden, exit_logits = self.stage1(tokens)
        if self._use_fused():
            exit_mask, _, conf, n_hard = self._fused_dispatch_enqueue(
                exit_logits, ids_dev, hidden,
                jax.ShapeDtypeStruct(hidden.shape[1:], hidden.dtype))
        else:
            slab, slab_ids, n_hard_dev, exit_mask, _, conf = _decide_compact(
                hidden, exit_logits, ids_dev, self.c_thr,
                backend=dispatch.kernel_backend())
            if self.conf_sink is not None:    # rides the n_hard sync
                n_hard_dev, conf_np = jax.device_get((n_hard_dev, conf))
                self.conf_sink.extend(conf_np)
            n_hard = int(n_hard_dev)          # the one host sync per batch
            if n_hard > 0:
                self._enqueue_backpressured(slab, slab_ids, n_hard)
        b = int(tokens.shape[0])
        self.stats.n_samples += b
        self.stats.record_decisions(b, n_hard)
        self._easy.append((np.asarray(sample_ids), exit_mask, exit_logits))
        while self._count >= self.sc.capacity:
            self._drain()
        self._harvest_oldest(results)

    def flush(self, results: dict):
        """Drain the ring (partial final bucket included) and collect every
        pending device future into ``results`` — the only point that
        deliberately blocks on the device."""
        while self._count > 0:
            self._drain()
        pending = ([x for t in self._easy for x in t[1:]]
                   + [x for t in self._buckets for x in t])
        if pending:
            jax.block_until_ready(pending)
        for entry in self._easy:
            self._collect_easy(entry, results)
        for entry in self._buckets:
            self._collect_bucket(entry, results)
        self._easy.clear()
        self._buckets.clear()


# ---------------------------------------------------------------------------
# the seed's host-loop server — kept verbatim as the benchmark baseline
# (benchmarks/serve_pipeline.py measures the device-resident speedup
# against it) and as the e2e parity oracle in tests
# ---------------------------------------------------------------------------

class HostLoopServer:
    """Per-sample host-loop EE server (pre-device-resident implementation):
    syncs each hard hidden row to host, queues it in a Python deque and
    re-stacks it per bucket. Same interface as TwoStageServer."""

    def __init__(self, stage1_fn: Callable, stage2_fn: Callable,
                 sc: ServeConfig):
        self.stage1 = stage1_fn
        self.stage2 = stage2_fn
        self.sc = sc
        self.queue: deque = deque()          # (hidden_row, sample_id) pairs
        self.stats = ServeStats()

    def _drain_bucket(self, results: dict):
        """Pop up to ``capacity`` queued hard samples, run stage 2, merge."""
        take = min(len(self.queue), self.sc.capacity)
        if take == 0:
            return
        rows, ids = zip(*[self.queue.popleft() for _ in range(take)])
        slab = jnp.stack(list(rows))
        if take < self.sc.capacity:          # flush slots (paper §III-C.2)
            pad = jnp.broadcast_to(slab[:1],
                                   (self.sc.capacity - take,) + slab.shape[1:])
            slab = jnp.concatenate([slab, pad])
        logits = self.stage2(slab)
        for i, sid in enumerate(ids):
            results[sid] = np.asarray(logits[i])
        self.stats.n_stage2 += take
        self.stats.record_bucket(take / self.sc.capacity)

    def submit(self, tokens: np.ndarray, sample_ids: np.ndarray,
               results: dict):
        """Serve one stage-1 batch; easy samples resolve immediately, hard
        ones enqueue. Buckets drain whenever a full bucket is available; if
        the queue would overflow, drain first (stage-1 backpressure stall)."""
        hidden, exit_logits = self.stage1(jnp.asarray(tokens))
        exit_mask, pred, conf = ed.decision_and_argmax(
            exit_logits, self.sc.c_thr)
        exit_mask = np.asarray(exit_mask)
        self.stats.n_samples += len(sample_ids)
        self.stats.record_decisions(len(sample_ids),
                                    int((~exit_mask).sum()))
        for i, sid in enumerate(sample_ids):
            if exit_mask[i]:
                results[sid] = np.asarray(exit_logits[i])
            else:
                if len(self.queue) >= self.sc.queue_depth * self.sc.capacity:
                    self.stats.n_stalls += 1
                    self._drain_bucket(results)
                self.queue.append((jnp.asarray(hidden[i]), int(sid)))
        while len(self.queue) >= self.sc.capacity:
            self._drain_bucket(results)

    def flush(self, results: dict):
        while self.queue:
            self._drain_bucket(results)


# ---------------------------------------------------------------------------
# decode-time serving: per-token exit decisions with stage-2 KV-cache
# segments carried through the pytree ring
# ---------------------------------------------------------------------------

def cache_rows_of(seg: dict) -> dict:
    """Re-layout a segment cache pytree (run_layers layout) so every leaf is
    sample-major (batch axis 0): 'blocks' leaves carry a leading superblock
    axis (n_sb, B, ...) -> (B, n_sb, ...); 'first'/'rem' leaves are already
    batch-leading. The result is a valid pytree-ring payload (rows = axis
    0 of every leaf)."""
    return {"first": seg["first"],
            "blocks": jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0),
                                   seg["blocks"]),
            "rem": seg["rem"]}


def cache_of_rows(rows: dict) -> dict:
    """Inverse of ``cache_rows_of``: back to the run_layers layout."""
    return {"first": rows["first"],
            "blocks": jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1),
                                   rows["blocks"]),
            "rem": rows["rem"]}


# ---------------------------------------------------------------------------
# paged stage-2 cache: page pools + block tables instead of dense rows
# ---------------------------------------------------------------------------

def _is_layer_cache(node) -> bool:
    """A per-layer decode cache dict: attention {k, v} or MLA
    {latent, k_rope}. The only cache shapes the paged store accepts."""
    return isinstance(node, dict) and (
        ("k" in node and "v" in node) or "latent" in node)


def _map_layer_caches(node, fn):
    """Apply ``fn`` to every per-layer cache dict in a segment tree,
    preserving the surrounding structure."""
    if _is_layer_cache(node):
        return fn(node)
    if isinstance(node, dict):
        return {k: _map_layer_caches(v, fn) for k, v in node.items()}
    if isinstance(node, (list, tuple)):
        return type(node)(_map_layer_caches(v, fn) for v in node)
    return node


def paged_seg_pool(rows: dict, page_size: int, n_pages: int) -> dict:
    """Zero page-pool tree (run_layers layout) templated on a sample-major
    stage-2 rows tree. 'blocks' leaves (B, n_sb, max_len, *F) become
    (n_sb, n_pages, page, *F) pools; 'rem' layer leaves (B, max_len, *F)
    become (n_pages, page, *F). Every leaf's position axis must be the SAME
    max_len, a multiple of ``page_size`` — windowed ring caches, recurrent
    state and cross-attention memory are not pageable and raise."""
    if rows["first"]:
        raise ValueError("stage-2 rows carry no 'first' caches; got a "
                         "non-empty first segment — not pageable")
    lens = set()

    def _pool_leaf(x, lead):
        L = x.shape[1 + lead]
        if L % page_size != 0:
            raise ValueError(f"cache position axis {L} is not a multiple of "
                             f"page_size={page_size} — not pageable")
        lens.add(L)
        head = (x.shape[1],) if lead else ()
        return jnp.zeros(head + (n_pages, page_size) + x.shape[2 + lead:],
                         x.dtype)

    def _check(node, lead):
        if not _is_layer_cache(node):
            raise ValueError(f"non-attention cache {jax.tree.structure(node)}"
                             " — not pageable (windowed/recurrent/cross "
                             "layers keep dense rows)")
        if "bt" in node:
            raise ValueError("rows template is already paged")
        return {k: _pool_leaf(v, lead) for k, v in node.items()}

    pool = {"first": [],
            "blocks": _map_layer_caches(rows["blocks"],
                                        lambda d: _check(d, 1)),
            "rem": _map_layer_caches(rows["rem"], lambda d: _check(d, 0))}
    if len(lens) > 1:
        raise ValueError(f"inconsistent cache position axes {sorted(lens)} "
                         "— not pageable")
    return pool


def _inject_bt(pool: dict, bt: jnp.ndarray) -> dict:
    """Add the block table to every layer-cache dict of a pool tree:
    'rem' layers get ``bt`` (B, M) directly; 'blocks' layers get it
    broadcast over their leading superblock axis (scanned per layer)."""
    def blocks_fn(d):
        n_sb = next(iter(d.values())).shape[0]
        return dict(d, bt=jnp.broadcast_to(bt[None], (n_sb,) + bt.shape))

    return {"first": pool["first"],
            "blocks": _map_layer_caches(pool["blocks"], blocks_fn),
            "rem": _map_layer_caches(pool["rem"],
                                     lambda d: dict(d, bt=bt))}


def _strip_bt(seg: dict) -> dict:
    """Inverse of ``_inject_bt``: drop the block-table leaves so the pool
    tree keeps one structure (the table lane is scheduler state)."""
    return _map_layer_caches(
        seg, lambda d: {k: v for k, v in d.items() if k != "bt"})


@functools.partial(jax.jit, static_argnames=("sentinel",))
def _sanitize_paged_bucket(bt_rows, ids, step, sentinel: int):
    """Flush / stale ring rows (ids < 0) must not touch the shared pool:
    their block tables collapse to the null page and their write position
    to the out-of-range sentinel, so the paged append drops and the gather
    reads zeros. Live rows pass through untouched."""
    bad = ids < 0
    step = jnp.broadcast_to(jnp.asarray(step, jnp.int32), ids.shape)
    return (jnp.where(bad[:, None], 0, bt_rows),
            jnp.where(bad, sentinel, step))


@functools.partial(jax.jit, donate_argnums=(0,))
def _merge_bucket_logits(merged, ids, logits):
    """Exit Merge, one bucket at a time: overwrite hard samples' rows of
    the per-step logits with their stage-2 results (flush ids dropped)."""
    safe = jnp.where(ids >= 0, ids, merged.shape[0])
    return merged.at[safe].set(logits, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _merge_bucket_tokens(tok_vec, ids, logits):
    """Exit Merge for the greedy token lane: easy rows keep the decision
    kernel's pred (already argmax of the exit logits — no second logits
    pass), hard rows take their bucket's argmax (flush ids dropped)."""
    safe = jnp.where(ids >= 0, ids, tok_vec.shape[0])
    s2_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return tok_vec.at[safe].set(s2_tok, mode="drop")


@jax.jit
def _greedy_tokens(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]


class DecodeFns(NamedTuple):
    """Jitted decode-stage callables shared by ``DecodeServer``, the
    host-loop baseline AND the continuous scheduler, so benchmark deltas are
    purely the exit/scheduling machinery and parity is bitwise (sync) /
    per-sample token-equivalent (continuous). ``step`` may be the scalar
    batch position (sync) or a per-row (B,) vector (continuous pool).
    ``s1_raw`` is the un-jitted stage-1 body: the continuous pool tick
    inlines it inside its own jitted step (masked cache select around it),
    which a donating jit wrapper would get in the way of."""
    prefill: Callable   # (tokens (B,S), max_len static) -> (logits, caches)
    split: Callable     # caches -> (stage1_caches, stage2_cache_rows)
    s1: Callable        # (tok (B,1), c1, step) -> (h (B,d), c1', exit_logits)
    s2: Callable        # (h (C,d), cache_rows, step) -> (logits, new_rows)
    s1_raw: Callable    # s1's body, un-jitted (continuous pool tick)
    # paged stage-2 cache (None = dense). When set, the scheduler/server
    # store the stage-2 cache as page pools + per-slot block tables; the
    # ring's cache payload is the (max_pages,) i32 table row, not dense
    # cache rows.
    page_size: Optional[int] = None
    s2_paged: Optional[Callable] = None   # (h, bt, step, pool) -> (logits, pool')
    pool_init: Optional[Callable] = None  # (rows template, n_pages) -> pool
    admit_pages: Optional[Callable] = None  # (pool, rows, bt_rows) -> pool'


def decode_stage_fns(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                     placement: Optional[StagePlacement] = None,
                     page_size: Optional[int] = None) -> DecodeFns:
    """Jitted decode callables with per-stage residency: the one-shot
    full-depth prefill (and its cache split) runs on ex1 with the full
    param tree, per-step stage 1 closes over the stage-1 slice on ex1, and
    the bucketed stage-2 decode closes over the stage-2 slice on ex2.
    Degenerate placement = everything on the default device, the same
    programs as before."""
    pl = placement or StagePlacement.single_device()
    # ex1 holds the FULL tree (the one-shot prefill needs every layer);
    # per-step stage 1 closes over the same placed tree rather than a
    # second stage-1 slice, so stage-1 params are resident once, not twice.
    # The stage-2 slice is only cut (a copy of its superblock leaves) when
    # there is a second submesh to put it on.
    presliced = pl.disaggregated
    p_full = pl.ex1.place(params)
    if presliced:
        _, p2 = ee.split_params(cfg, spec, params)
        p2 = pl.ex2.place(p2)
    else:
        p2 = params

    @functools.partial(jax.jit, static_argnames=("max_len",))
    def pf(tokens, max_len: int):
        logits, caches, _ = T.prefill(p_full["backbone"], cfg, tokens,
                                      max_len=max_len)
        return logits, caches

    @jax.jit
    def split(caches):
        c1, c2 = ee.split_caches(cfg, spec, caches)
        return c1, cache_rows_of(c2)

    def s1_raw(tok, c1, step):
        h, nc1, exit_logits = ee.stage1_decode(p_full, cfg, spec, tok, c1,
                                               step)
        return h[:, 0], nc1, exit_logits

    s1 = functools.partial(jax.jit, donate_argnums=(1,))(s1_raw)

    @functools.partial(jax.jit, donate_argnums=(1,))
    def s2(h_rows, cache_rows, step):
        logits, nc = ee.stage2_decode(p2, cfg, spec, h_rows[:, None],
                                      cache_of_rows(cache_rows), step,
                                      presliced_params=presliced)
        return logits, cache_rows_of(nc)

    if page_size is None:
        return DecodeFns(pf, split, s1, s2, s1_raw)
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")

    @functools.partial(jax.jit, donate_argnums=(3,))
    def s2_paged(h_rows, bt, step, pool):
        logits, nc = ee.stage2_decode(p2, cfg, spec, h_rows[:, None],
                                      _inject_bt(pool, bt), step,
                                      presliced_params=presliced)
        return logits, _strip_bt(nc)

    def pool_init(rows, n_pages: int):
        return paged_seg_pool(rows, page_size, n_pages)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def admit_pages(pool, rows, bt_rows):
        """Scatter k admitted rows' DENSE stage-2 caches into their pages.
        rows: sample-major tree, leaves (k, [n_sb,] L, *F); bt_rows:
        (k, M) i32, null (0) tail entries land in the null page — every
        such write carries the dense tail's zeros, so page 0 stays zero."""
        k, M = bt_rows.shape

        def rem_fn(d, r):
            # pool (P, page, *F) <- rows (k, L, *F) paginated to (k*M, ...)
            return jax.tree.map(
                lambda p, x: p.at[bt_rows.reshape(-1)].set(
                    x.reshape((k * M, page_size) + x.shape[2:]),
                    mode="drop"),
                d, r)

        def blocks_fn(d, r):
            # pool (n_sb, P, page, *F) <- rows (k, n_sb, L, *F)
            def leaf(p, x):
                n_sb = x.shape[1]
                x = jnp.moveaxis(x, 0, 1).reshape(
                    (n_sb, k * M, page_size) + x.shape[3:])
                return p.at[:, bt_rows.reshape(-1)].set(x, mode="drop")
            return jax.tree.map(leaf, d, r)

        return {"first": [],
                "blocks": jax.tree.map(blocks_fn, pool["blocks"],
                                       rows["blocks"],
                                       is_leaf=_is_layer_cache),
                "rem": jax.tree.map(rem_fn, pool["rem"], rows["rem"],
                                    is_leaf=_is_layer_cache)}

    return DecodeFns(pf, split, s1, s2, s1_raw, page_size=page_size,
                     s2_paged=s2_paged, pool_init=pool_init,
                     admit_pages=admit_pages)


def decode_step0_confidences(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                             prompt, max_len: int) -> jnp.ndarray:
    """Exit-head max-softmax confidences of the FIRST decode step (greedy
    token from the prefill logits): the calibration set for per-token
    thresholds, whose statistics drift from prefill's per-sample
    confidences. prompt: (B, S) int32; max_len sizes the cache pads."""
    prompt = jnp.asarray(prompt)
    S = prompt.shape[1]
    logits, caches, _ = T.prefill(params["backbone"], cfg, prompt,
                                  max_len=max_len)
    c1, _ = ee.split_caches(cfg, spec, caches)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    _, _, exit_logits = ee.stage1_decode(params, cfg, spec, tok, c1,
                                         jnp.int32(S))
    return ed.softmax_confidence(exit_logits)


class DecodeServer(_RingedServer):
    """Device-resident decode-time two-stage EE server.

    ``generate`` prefills the full-depth model (populating both cache
    segments for the prompt), then decodes greedily with a per-token exit
    decision: each step runs stage 1 on the whole batch, the fused
    decision/compaction kernels emit the hard-token slab, and the hard
    tokens' hidden rows + their stage-2 KV-cache segment rows ride the
    pytree ring into bucketed stage-2 dispatches. Updated cache rows
    scatter back on device; easy tokens never touch stage 2 (their stage-2
    cache keeps zeros at that position — exit-gap semantics, identical in
    the host baseline). The only per-step host sync is the scalar
    ``n_hard``; merged per-step logits are harvested lazily under
    ``max_pending``.

    Under a disaggregated ``placement`` the stage-2 cache store, the ring
    and the bucketed ``stage2_decode`` dispatches live on ``ex2``'s submesh
    while stage 1, the exit kernels and the merged logits stay on ``ex1``:
    each step's hard slab hops ex1 -> ex2 (enqueue) and each bucket's
    logits hop ex2 -> ex1 (exit merge) as ``jax.device_put`` transfers —
    never through the host.
    """

    def __init__(self, fns: DecodeFns, sc: ServeConfig,
                 placement: Optional[StagePlacement] = None):
        super().__init__(sc, placement)
        self.fns = fns
        self._c1 = None          # stage-1 segment caches (run_layers layout)
        self._rows = None        # stage-2 segment cache, sample-major rows
                                 # (paged mode: the (B, M) block-table lane)
        self._pool = None        # paged mode: the stage-2 page pools
        self._max_len = 0        # paged mode: the append sentinel
        self._ids = None         # arange(B) device constant
        self._pos = None         # current absolute position (drains need it)
        self._step_buckets: List[Tuple[jnp.ndarray, jnp.ndarray]] = []

    # -- internal ------------------------------------------------------------

    def _drain(self) -> None:
        popped = self._pop_bucket()
        if popped is None:
            return
        bucket, bucket_ids = popped
        if self.fns.page_size is not None:
            # shared pool: flush rows must not append (a flush slot clones
            # batch row 0's payload — possibly an EASY row, whose stage-2
            # pages must keep zeros at this step: exit-gap semantics)
            bt_safe, step_safe = _sanitize_paged_bucket(
                bucket["cache"], bucket_ids, self._pos,
                sentinel=self._max_len)
            logits, self._pool = self.fns.s2_paged(bucket["h"], bt_safe,
                                                   step_safe, self._pool)
        else:
            logits, new_rows = self.fns.s2(bucket["h"], bucket["cache"],
                                           self._pos)
            self._rows = _scatter_rows(self._rows, new_rows, bucket_ids)
        self._step_buckets.append((bucket_ids, logits))

    def _step(self, tok, pos: int):
        """One decode step for the whole batch; returns (merged (B, V)
        logits, next greedy tokens (B, 1)), both device-side on ex1. The
        token lane starts as the decision kernel's pred (easy rows' argmax
        comes free with the exit decision) and hard rows are overwritten
        per bucket. Ring drains fully — decode is step-synchronous."""
        h_rows, self._c1, exit_logits = self.fns.s1(tok, self._c1, pos)
        self._pos = pos
        self._step_buckets = []
        if self._use_fused():
            # fused: hard rows' hidden AND stage-2 cache rows land in the
            # ring in the same pass (self._ids is arange(B), so the op's
            # gather-by-src is exactly the composed gather-by-ids)
            row_spec = {
                "h": jax.ShapeDtypeStruct(h_rows.shape[1:], h_rows.dtype),
                "cache": jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                    self._rows)}
            _, pred, conf, n_hard = self._fused_dispatch_enqueue(
                exit_logits, self._ids, {"h": h_rows, "cache": self._rows},
                row_spec)
            b = h_rows.shape[0]
            self.stats.record_decisions(b, n_hard)
        else:
            slab, slab_ids, n_hard_dev, _, pred, conf = _decide_compact(
                h_rows, exit_logits, self._ids, self.c_thr,
                backend=dispatch.kernel_backend())
            if self.conf_sink is not None:   # rides the n_hard sync
                n_hard_dev, conf_np = jax.device_get((n_hard_dev, conf))
                self.conf_sink.extend(conf_np)
            n_hard = int(n_hard_dev)         # the one host sync per step
            b = h_rows.shape[0]
            self.stats.record_decisions(b, n_hard)
            if n_hard > 0:
                # ex1 -> ex2 hop: the id lane crosses first (the cache
                # gather runs ON ex2 — the store never leaves stage 2's
                # submesh); the hidden slab crosses inside the enqueue's
                # place_io
                slab_ids = self.ex2.place_io(slab_ids)
                cache_slab = _gather_rows(self._rows, slab_ids)
                self._enqueue_backpressured({"h": slab, "cache": cache_slab},
                                            slab_ids, n_hard)
        while self._count > 0:               # full buckets, then the partial
            self._drain()
        merged = exit_logits
        tok_vec = pred
        for bucket_ids, logits in self._step_buckets:
            # ex2 -> ex1 hop: bucket results come home for the exit merge
            ids1 = self.ex1.place_io(bucket_ids)
            logits1 = self.ex1.place_io(logits)
            merged = _merge_bucket_logits(merged, ids1, logits1)
            tok_vec = _merge_bucket_tokens(tok_vec, ids1, logits1)
        return merged, tok_vec[:, None]

    # -- public --------------------------------------------------------------

    def generate(self, prompt: np.ndarray, n_tokens: int) -> dict:
        """Greedy EE generation: prefill the (B, S) prompt, then emit
        ``n_tokens`` tokens (the first from the prefill logits, the rest
        from per-token two-stage decode). Returns {'tokens' (B, n_tokens),
        'logits' (B, n_tokens, V)} as host arrays."""
        if n_tokens < 1:
            raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
        prompt = self.ex1.place_io(jnp.asarray(np.asarray(prompt, np.int32)))
        B, S = prompt.shape
        self.stats.n_samples += B
        self.ring.reset()                    # fresh ring per stream shape
        self._ids = self.ex1.place_io(jnp.arange(B, dtype=jnp.int32))
        logits0, caches = self.fns.prefill(prompt, S + n_tokens)
        self._c1, rows = self.fns.split(caches)
        if self.fns.page_size is not None:
            # paged parity mode: an identity block table (row b owns pages
            # [1 + b*M, 1 + (b+1)*M)) over a pool exactly sized for the
            # batch — the dense oracle with the paged data path
            page = self.fns.page_size
            if (S + n_tokens) % page != 0:
                raise ValueError(
                    f"paged decode needs S + n_tokens divisible by "
                    f"page_size={page}, got {S} + {n_tokens}")
            self._max_len = S + n_tokens
            M = self._max_len // page
            bt = 1 + jnp.arange(B * M, dtype=jnp.int32).reshape(B, M)
            rows = self.ex2.place_io(rows)
            pool = self.ex2.place_io(self.fns.pool_init(rows, B * M + 1))
            bt = self.ex2.place_io(bt)
            self._pool = self.fns.admit_pages(pool, rows, bt)
            self._rows = bt              # the ring's cache payload lane
            self.stats.cache_pages_total = B * M
            self.stats.cache_pages_in_use = B * M
            self.stats.cache_page_size = page
            # end-of-stream occupancy: every row fills its span
            self.stats.live_tokens = B * (S + n_tokens - 1)
            self.stats.cache_hbm_bytes = sum(
                leaf.nbytes for leaf in jax.tree.leaves(self._pool))
        else:
            # the stage-2 cache store migrates to its home submesh once, at
            # stream start (prefill runs on ex1, which holds full params)
            self._rows = self.ex2.place_io(rows)
            self.stats.cache_hbm_bytes = sum(
                leaf.nbytes for leaf in jax.tree.leaves(self._rows))
        merged = logits0
        tok = _greedy_tokens(merged)         # t=0: from the prefill logits
        logits_out: List = [None] * n_tokens
        toks_out: List = []
        pending: List[Tuple[int, jnp.ndarray]] = []
        for t in range(n_tokens):
            toks_out.append(tok)
            pending.append((t, merged))
            while len(pending) > self.sc.max_pending:
                slot, arr = pending.pop(0)
                logits_out[slot] = np.asarray(arr)
            if t == n_tokens - 1:
                break
            merged, tok = self._step(tok, S + t)
        for slot, arr in pending:            # flush
            logits_out[slot] = np.asarray(arr)
        tokens = np.concatenate([np.asarray(x) for x in toks_out], axis=1)
        return {"tokens": tokens, "logits": np.stack(logits_out, axis=1)}


class HostLoopDecoder:
    """Per-token host-loop decode baseline (HostLoopServer-style): syncs the
    exit mask each step, walks the hard tokens in Python, re-stacks each
    bucket's hidden rows AND cache rows sample by sample, and scatters
    updated cache rows back one sample at a time. Shares the jitted stage
    callables with ``DecodeServer``, so merged logits are bitwise identical
    — the delta is purely the exit machinery."""

    def __init__(self, fns: DecodeFns, sc: ServeConfig):
        self.fns = fns
        self.sc = sc
        self.stats = ServeStats()

    def generate(self, prompt: np.ndarray, n_tokens: int) -> dict:
        if n_tokens < 1:
            raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
        prompt = jnp.asarray(np.asarray(prompt, np.int32))
        B, S = prompt.shape
        self.stats.n_samples += B
        logits0, caches = self.fns.prefill(prompt, S + n_tokens)
        c1, rows = self.fns.split(caches)
        merged = np.asarray(logits0)
        logits_out, toks_out = [], []
        C = self.sc.capacity
        for t in range(n_tokens):
            tok = np.argmax(merged, axis=-1).astype(np.int32)[:, None]
            toks_out.append(tok)
            logits_out.append(merged)
            if t == n_tokens - 1:
                break
            pos = S + t
            h_rows, c1, exit_logits = self.fns.s1(jnp.asarray(tok), c1, pos)
            exit_mask, _, _ = ed.decision_and_argmax(exit_logits,
                                                     self.sc.c_thr)
            exit_mask = np.asarray(exit_mask)        # per-step host sync
            merged = np.array(np.asarray(exit_logits))
            hard = [i for i in range(B) if not exit_mask[i]]
            self.stats.record_decisions(B, len(hard))
            for lo in range(0, len(hard), C):
                chunk = hard[lo:lo + C]
                pad = C - len(chunk)
                take = chunk + [chunk[0]] * pad      # flush-padded bucket
                bucket_h = jnp.stack([h_rows[i] for i in take])
                bucket_cache = jax.tree.map(
                    lambda m: jnp.stack([m[i] for i in take]), rows)
                logits, new_rows = self.fns.s2(bucket_h, bucket_cache, pos)
                lnp = np.asarray(logits)
                for j, sid in enumerate(chunk):
                    merged[sid] = lnp[j]
                    rows = jax.tree.map(
                        lambda m, r, j=j, sid=sid: m.at[sid].set(r[j]),
                        rows, new_rows)
                self.stats.n_stage2 += len(chunk)
                self.stats.record_bucket(len(chunk) / C)
        tokens = np.concatenate(toks_out, axis=1)
        return {"tokens": tokens, "logits": np.stack(logits_out, axis=1)}


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def _stage_fns(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
               placement: Optional[StagePlacement] = None):
    """Per-stage jitted prefill callables. Disaggregated: each closes over
    ITS stage's param slice placed on its executor (ee.split_params) —
    stage-1 layers + exit head resident on ex1, stage-2 layers + final
    head on ex2. Degenerate: both close over the caller's full tree
    (slicing would COPY the superblock leaves for no placement benefit);
    the sliced and full-tree programs are bitwise-identical, which the
    disaggregation tests enforce."""
    pl = placement or StagePlacement.single_device()
    presliced = pl.disaggregated
    if presliced:
        p1, p2 = ee.split_params(cfg, spec, params)
        p1 = pl.ex1.place(p1)
        p2 = pl.ex2.place(p2)
    else:
        p1 = p2 = params

    @jax.jit
    def s1(tokens):
        h, _, logits, _ = ee.stage1_prefill(p1, cfg, spec, tokens)
        return h, logits

    @jax.jit
    def s2(slab):
        logits, _ = ee.stage2_prefill(p2, cfg, spec, slab,
                                      presliced_params=presliced)
        return logits

    return s1, s2


# ---------------------------------------------------------------------------
# DEPRECATED construction factories: keyword-compatible shims over
# serve_api.build — the one entry point every serving mode shares. Each
# shim warns once per process (DeprecationWarning) and forwards.
# ---------------------------------------------------------------------------

def build_server(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                 sc: ServeConfig,
                 placement: Optional[StagePlacement] = None
                 ) -> TwoStageServer:
    """DEPRECATED — use ``serve_api.build(mode="prefill")``."""
    serve_api._deprecated_factory("build_server")
    return serve_api.build(params, cfg, spec, sc, mode="prefill",
                           scheduler=None, placement=placement)


def build_host_server(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                      sc: ServeConfig) -> HostLoopServer:
    """DEPRECATED — use ``serve_api.build(mode="prefill", host=True)``."""
    serve_api._deprecated_factory("build_host_server")
    return serve_api.build(params, cfg, spec, sc, mode="prefill",
                           scheduler=None, host=True)


def build_decode_server(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                        sc: ServeConfig,
                        placement: Optional[StagePlacement] = None
                        ) -> DecodeServer:
    """DEPRECATED — use ``serve_api.build(mode="decode",
    scheduler=None)``."""
    serve_api._deprecated_factory("build_decode_server")
    return serve_api.build(params, cfg, spec, sc, mode="decode",
                           scheduler=None, placement=placement)


def build_host_decoder(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                       sc: ServeConfig) -> HostLoopDecoder:
    """DEPRECATED — use ``serve_api.build(mode="decode", scheduler=None,
    host=True)``."""
    serve_api._deprecated_factory("build_host_decoder")
    return serve_api.build(params, cfg, spec, sc, mode="decode",
                           scheduler=None, host=True)


def build_continuous_scheduler(params, cfg: ArchConfig,
                               spec: ee.EarlyExitSpec, sc: ServeConfig, *,
                               n_slots: int, max_len: int,
                               placement: Optional[StagePlacement] = None,
                               clock=None) -> ContinuousScheduler:
    """DEPRECATED — use ``serve_api.build(mode="decode",
    scheduler="continuous")`` (same keywords; carries the ``fns_factory``
    live migration rebuilds stage callables with)."""
    serve_api._deprecated_factory("build_continuous_scheduler")
    return serve_api.build(params, cfg, spec, sc, mode="decode",
                           scheduler="continuous", placement=placement,
                           n_slots=n_slots, max_len=max_len, clock=clock)


def build_sync_scheduler(params, cfg: ArchConfig, spec: ee.EarlyExitSpec,
                         sc: ServeConfig, *, n_slots: int,
                         placement: Optional[StagePlacement] = None,
                         clock=None) -> SyncScheduler:
    """DEPRECATED — use ``serve_api.build(mode="decode",
    scheduler="sync")``."""
    serve_api._deprecated_factory("build_sync_scheduler")
    return serve_api.build(params, cfg, spec, sc, mode="decode",
                           scheduler="sync", placement=placement,
                           n_slots=n_slots, clock=clock)


def serve_dataset(server, tokens: np.ndarray, batch: int) -> dict:
    """Run a whole token set through the server in stage-1 batches.
    Returns {sample_id: logits} plus the stats object."""
    n = tokens.shape[0]
    results: dict = {}
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        server.submit(tokens[lo:hi], np.arange(lo, hi), results)
    server.flush(results)
    return results
