"""Transport-agnostic serving API: the one admission surface every server
construction path and every serving front end programs against.

The scheduler's admission interface grew organically across PRs 4-6
(``submit``/``run``/``results`` plus the drift controller's actuator
setters), which was fine while there was exactly one scheduler on one
(sub)mesh. A fleet router (``runtime/router.py``) that owns admission
across N replicas needs that surface cut as an explicit contract, not an
implementation detail, so replicas can be in-process schedulers today and
multi-process / multi-host proxies tomorrow without touching the router.
This module owns that contract:

  * ``validate_request`` — THE submit-side validation (n_tokens >= 1,
    prompt + n_tokens within the pool's max_len, duplicate sample ids).
    One definition, byte-identical error messages, shared by the
    continuous scheduler, the sync scheduler and the fleet router — a
    malformed request is rejected at whichever surface sees it first,
    with the same error either way.
  * ``RequestQueue`` — the admission queue abstraction: FIFO arrival
    order, validated pushes, sid bookkeeping, revocation (the router's
    preemption primitive — only *unadmitted* requests can be revoked, so
    re-queueing never perturbs an in-flight token stream), and a copy
    protocol so live migration can snapshot/restore it like any other
    host container.
  * ``ReplicaHandle`` — the protocol a routable replica implements:
    ``submit``/``step``/``drain``/``results``/``stats`` plus the control
    actuators (``set_c_thr``/``request_capacity``/``request_migration``)
    and the load/feed introspection the router's policies consume
    (``n_slots``/``n_busy``/``queue_len``/``next_arrival``/
    ``revoke_queued``/``drain_finished``). ``ContinuousScheduler`` and
    ``SyncScheduler`` both implement it; the router depends ONLY on it.
  * ``build`` — the one construction entry point for every serving mode
    (prefill/decode x sync/continuous x device/host-loop), replacing the
    four ``build_*`` factories that ``runtime/serve_loop.py`` now
    re-exports as keyword-compatible deprecation shims.

``step()`` returns one of three strings — the replica state machine the
router (and ``drain``) drives:

  * ``"busy"``    — the replica made progress (ticked, drained, admitted);
  * ``"waiting"`` — queued work exists but nothing is admissible yet (the
    caller owns the clock and should advance it toward
    ``next_arrival()``);
  * ``"idle"``    — queue and pool are fully drained.

This module deliberately imports nothing from the runtime at module scope
(``build`` resolves its factories lazily), so the scheduler can depend on
``RequestQueue`` without an import cycle.
"""
from __future__ import annotations

import warnings
from collections import deque
from typing import (Callable, Deque, Dict, Iterator, List, Optional,
                    Protocol, Sequence, runtime_checkable)

__all__ = ["ReplicaHandle", "RequestQueue", "build", "validate_request"]


def validate_request(req, *, max_len: Optional[int] = None,
                     is_dup: Optional[Callable[[int], bool]] = None) -> None:
    """Submit-side request validation — the single shared definition (and
    the single set of error messages) for every admission surface.

    ``max_len`` bounds ``len(prompt) + n_tokens`` (None = unbounded, the
    sync policy's static-batch regime); ``is_dup(sid)`` reports whether
    the surface has already seen the sample id (queued, admitted or
    finished)."""
    if req.n_tokens < 1:
        raise ValueError(f"n_tokens must be >= 1, got {req.n_tokens}")
    if max_len is not None and len(req.prompt) + req.n_tokens > max_len:
        raise ValueError(
            f"request {req.sample_id}: S + n_tokens = "
            f"{len(req.prompt) + req.n_tokens} exceeds pool max_len "
            f"{max_len}")
    if is_dup is not None and is_dup(req.sample_id):
        raise ValueError(f"duplicate sample id {req.sample_id}")


class RequestQueue:
    """Validated FIFO admission queue over ``Request`` objects.

    Owns the sid membership set that the duplicate check reads, so "is
    this sample id already queued?" has exactly one source of truth. The
    deque interface (``append``/``popleft``/``__getitem__``/iteration)
    matches what the schedulers' admission loops already used, so the
    queue drops in where a bare ``deque`` lived.

    ``revoke`` is the router's preemption primitive: remove specific
    *unadmitted* requests (admitted ones are no longer here — a pop is
    the admission boundary) and hand them back, preserving arrival order
    among the survivors. ``__copy__`` gives live migration the same
    shallow-snapshot semantics the bare containers had.
    """

    def __init__(self, max_len: Optional[int] = None,
                 is_dup: Optional[Callable[[int], bool]] = None):
        self.max_len = max_len
        self._is_dup = is_dup
        self._q: Deque = deque()
        self._queued: set = set()

    # -- validated push ------------------------------------------------------

    def append(self, req) -> None:
        """Validate and enqueue (arrival order = queue order). Rejects a
        malformed request before it can damage in-flight state."""
        validate_request(
            req, max_len=self.max_len,
            is_dup=lambda sid: sid in self._queued
            or (self._is_dup is not None and self._is_dup(sid)))
        self._queued.add(req.sample_id)
        self._q.append(req)

    # -- admission pops / inspection -----------------------------------------

    def popleft(self):
        req = self._q.popleft()
        self._queued.discard(req.sample_id)
        return req

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __getitem__(self, i):
        return self._q[i]

    def __iter__(self) -> Iterator:
        return iter(self._q)

    def __contains__(self, sample_id: int) -> bool:
        return sample_id in self._queued

    def next_arrival(self) -> Optional[float]:
        """The HEAD request's arrival_time (None when empty) — admission
        is FIFO, so this is the time that unblocks the next admission;
        a clock-owning caller advances toward it when ``step`` reports
        ``"waiting"``."""
        if not self._q:
            return None
        return self._q[0].arrival_time

    # -- revocation (preemption / degrade redistribution) --------------------

    def revoke(self, sample_ids: Optional[Sequence[int]] = None) -> List:
        """Remove and return queued (UNADMITTED) requests by sample id —
        ``None`` revokes everything. Requests already popped for admission
        are untouched (and absent from the return), which is what makes
        fleet-level preemption stream-preserving: a revoked request has
        never emitted a token."""
        want = None if sample_ids is None else set(sample_ids)
        taken, kept = [], deque()
        for r in self._q:
            if want is None or r.sample_id in want:
                taken.append(r)
                self._queued.discard(r.sample_id)
            else:
                kept.append(r)
        self._q = kept
        return taken

    # -- snapshot protocol (live migration) ----------------------------------

    def __copy__(self) -> "RequestQueue":
        new = RequestQueue(self.max_len, self._is_dup)
        new._q = deque(self._q)
        new._queued = set(self._queued)
        return new


@runtime_checkable
class ReplicaHandle(Protocol):
    """What a routable serving replica looks like. ``ContinuousScheduler``
    and ``SyncScheduler`` implement it in-process; the fleet router
    depends only on this surface, so a transport proxy (multi-process,
    multi-host) that speaks it routes identically.

    Beyond the admission core (``submit``/``step``/``drain``/``results``/
    ``stats``) and the control actuators, the protocol carries the load
    and event-feed introspection the routing policies need: pool
    geometry, live occupancy, queue depth, revocation, and the
    per-request finish feed (sid + realized per-request hardness — the
    tenant-difficulty signal ``drift_aware`` routing learns from)."""

    clock: object
    results: Dict[int, List[int]]

    # -- admission core ------------------------------------------------------

    def submit(self, req) -> None: ...

    def step(self) -> str: ...

    def drain(self) -> Dict[int, List[int]]: ...

    @property
    def stats(self): ...

    # -- control actuators ---------------------------------------------------

    def set_c_thr(self, c_thr: float) -> None: ...

    def request_capacity(self, capacity: int) -> None: ...

    def request_migration(self, plan) -> None: ...

    # -- load / feed introspection (routing policies) ------------------------

    @property
    def n_slots(self) -> int: ...

    @property
    def n_busy(self) -> int: ...

    @property
    def queue_len(self) -> int: ...

    def next_arrival(self) -> Optional[float]: ...

    def revoke_queued(self,
                      sample_ids: Optional[Sequence[int]] = None) -> List: ...

    def drain_finished(self) -> List: ...


# ---------------------------------------------------------------------------
# unified construction: one entry point for every serving mode
# ---------------------------------------------------------------------------

_MODES = ("prefill", "decode")
_SCHEDULERS = (None, "sync", "continuous")


def build(params, cfg, spec, sc, *, mode: str = "decode",
          scheduler: Optional[str] = "continuous", placement=None,
          n_slots: Optional[int] = None, max_len: Optional[int] = None,
          clock=None, host: bool = False, page_size: Optional[int] = None,
          n_pages: Optional[int] = None, events=None):
    """Build a serving object for any (mode, scheduler) point — the single
    construction path ``launch/serve.py``, the benchmarks and the examples
    share (the old ``build_*`` factories in ``runtime/serve_loop.py`` are
    deprecation shims over this).

    ==========  ============  =============================================
    mode        scheduler     returns
    ==========  ============  =============================================
    "prefill"   None          ``TwoStageServer`` (``HostLoopServer`` when
                              ``host=True``) — batch-level EE serving
    "decode"    None          ``DecodeServer`` (``HostLoopDecoder`` when
                              ``host=True``) — step-synchronous generate()
    "decode"    "sync"        ``SyncScheduler`` over a ``DecodeServer``
                              (needs ``n_slots``)
    "decode"    "continuous"  ``ContinuousScheduler`` (needs ``n_slots``
                              and ``max_len``; carries the ``fns_factory``
                              live migration rebuilds stage callables
                              with)
    ==========  ============  =============================================

    ``placement`` disaggregates the two stages onto disjoint submeshes for
    any device-resident variant; ``clock`` (sync/continuous only) shares a
    time base across replicas — REQUIRED when the result joins a
    ``FleetRouter`` fleet.

    ``page_size`` switches the stage-2 KV store to the PAGED pool (decode
    modes only): the stage fns gain the block-table decode surface, the
    step-synchronous ``DecodeServer`` pages its generate-time cache, and
    the continuous scheduler allocates pages on admit / frees on finish
    over ``n_pages`` allocatable pages (default: dense-equivalent
    capacity, ``n_slots * max_len / page_size`` — pass less to serve more
    slots than the dense store could hold at the same HBM budget)."""
    # ``events`` (scheduler modes only) wires a telemetry.EventLog request-
    # lifecycle feed into the scheduler — the observability plane
    # (runtime/observe.Tracer / StatsSampler) subscribes to it.
    from repro.runtime import serve_loop as SL

    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    if scheduler not in _SCHEDULERS:
        raise ValueError(
            f"scheduler must be one of {_SCHEDULERS}, got {scheduler!r}")
    if page_size is not None and mode != "decode":
        raise ValueError("page_size is a decode-mode knob (the paged pool "
                         "is the stage-2 decode cache)")
    if n_pages is not None and page_size is None:
        raise ValueError("n_pages needs page_size")
    if n_pages is not None and scheduler != "continuous":
        raise ValueError("n_pages sizes the continuous scheduler's page "
                         "pool; the sync/bare paged servers are "
                         "batch-sized")
    if mode == "prefill":
        if scheduler is not None:
            raise ValueError(
                "prefill serving has no scheduling policy: pass "
                "scheduler=None (decode owns sync/continuous)")
        if host:
            return SL.HostLoopServer(*SL._stage_fns(params, cfg, spec), sc)
        s1, s2 = SL._stage_fns(params, cfg, spec, placement)
        return SL.TwoStageServer(s1, s2, sc, placement)
    if events is not None and scheduler is None:
        raise ValueError("events= is a scheduler-mode feed (the bare "
                         "servers have no request lifecycle to emit)")
    # decode
    if scheduler is None:
        fns = SL.decode_stage_fns(params, cfg, spec,
                                  None if host else placement,
                                  page_size=page_size)
        if host:
            if page_size is not None:
                raise ValueError("the host-loop oracle has no paged cache "
                                 "(it IS the dense reference)")
            return SL.HostLoopDecoder(fns, sc)
        return SL.DecodeServer(fns, sc, placement)
    if host:
        raise ValueError("host=True is a baseline-oracle knob for the bare "
                         "servers; schedulers wrap the device-resident one")
    if n_slots is None:
        raise ValueError(f"scheduler={scheduler!r} needs n_slots")
    if scheduler == "sync":
        server = SL.DecodeServer(
            SL.decode_stage_fns(params, cfg, spec, placement,
                                page_size=page_size), sc, placement)
        return SL.SyncScheduler(server, n_slots, clock=clock,
                                max_len=max_len, events=events)
    if max_len is None:
        raise ValueError("scheduler='continuous' needs max_len (the pool's "
                         "shared cache width)")
    return SL.ContinuousScheduler(
        SL.decode_stage_fns(params, cfg, spec, placement,
                            page_size=page_size), sc,
        n_slots=n_slots, max_len=max_len, placement=placement, clock=clock,
        n_pages=n_pages, events=events,
        fns_factory=lambda pl: SL.decode_stage_fns(params, cfg, spec, pl,
                                                   page_size=page_size))


_WARNED: set = set()


def _deprecated_factory(name: str) -> None:
    """One DeprecationWarning per shim name per process — the old
    ``serve_loop.build_*`` factories forward here."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"runtime.serve_loop.{name} is deprecated; construct servers via "
        f"runtime.serve_api.build(mode=..., scheduler=..., placement=...)",
        DeprecationWarning, stacklevel=3)
