"""Fleet-scale replica router: admission across N serving replicas with
difficulty-aware routing, per-tenant SLO classes, and a live ops surface.

ATHEENA provisions a network section's hardware to the exit probability
``p`` of the traffic it actually sees; at fleet scale the same principle
says *routing* should shape per-replica traffic so each replica's
provisioning stays matched to its realized hard rate ``q`` — steer easy
traffic to exit-heavy (small-stage-2) replicas and hard traffic to fat
ones, the progressive-inference scheduling framing of HAPI. The
``FleetRouter`` owns admission across a fleet of replicas and depends ONLY
on the transport-agnostic ``ReplicaHandle`` surface (``serve_api.py``), so
the replicas can be in-process ``ContinuousScheduler``/``SyncScheduler``
objects today and multi-process / multi-host proxies tomorrow.

Routing policies (``policy=``):

  * ``round_robin``   — cycle over eligible replicas (the baseline the
                        fleet benchmark gates against);
  * ``least_loaded``  — min live occupancy + queue depth;
  * ``drift_aware``   — match the submitting tenant's rolling difficulty
                        estimate (EWMA of realized per-request hard rate,
                        learned from each replica's finish feed) to each
                        replica's provisioned ``p``, penalized by the
                        replica's current drift (``realized_q_ewma`` above
                        its ``p`` means its stage-2 is already saturating)
                        and a load tiebreak.

SLO classes and preemption: every ``Request`` carries ``tenant`` /
``slo_class``; classes order admission by priority, optionally cap a
tenant's in-flight requests, and let a blocked higher-priority request
preempt a lower-priority (or over-budget same-priority) tenant's QUEUED
request off a replica. Preemption uses ``revoke_queued`` — only unadmitted
requests move, so a preempted request has never emitted a token and goes
back into the router's pending set (re-queued, NEVER dropped: the
no-drop/no-dup contract extends fleet-wide, and per-sample token streams
stay equal to a single-scheduler oracle because per-row compute is batch-
and replica-composition-independent).

Ops surface: ``FleetStats.as_dict`` aggregates per-replica ``ServeStats``
(each itself a versioned schema) plus per-tenant difficulty/usage and the
router's own counters; a streaming per-request event feed
(submit/route/preempt/finish/degrade) rides the PR-6 ``EventLog`` —
``router.events.subscribe(fn)`` sees every event as it is emitted.
``degrade_replica`` wires replica health to ``migrate_on_device_loss``:
the degraded replica's queued requests are revoked and redistributed, its
in-flight work drains normally, and (when device loss is the cause) the
survivor chips are re-split via a live migration.

Clock discipline: all replicas MUST share one clock object (the router's),
so "the fleet at time t" is one coherent statement — ``FleetRouter``
asserts this at construction.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.runtime.scheduler import Request
from repro.runtime.serve_api import validate_request
from repro.runtime.telemetry import EventLog

__all__ = ["DEFAULT_SLO_CLASSES", "FleetRouter", "FleetStats", "SLOClass",
           "TenantState"]

HEALTHY = "healthy"
DEGRADED = "degraded"

ROUTING_POLICIES = ("round_robin", "least_loaded", "drift_aware")

# difficulty-estimate smoothing: per-request hard rates are noisy (a
# 16-token request has 15 Bernoulli decisions), so the tenant estimate
# folds them at alpha=0.3 — converged within ~10 finishes of a regime
# change, stable against any one request
_DIFFICULTY_ALPHA = 0.3


@dataclass(frozen=True)
class SLOClass:
    """One service class: ``priority`` orders admission (lower = more
    urgent; preemption only ever flows down the priority order), and
    ``max_inflight`` optionally caps a tenant's concurrently-routed
    requests (the budget whose violation makes a tenant preemptible by
    its own priority peers)."""
    name: str
    priority: int
    max_inflight: Optional[int] = None


DEFAULT_SLO_CLASSES: Dict[str, SLOClass] = {
    "gold": SLOClass("gold", 0),
    "standard": SLOClass("standard", 1),
    "batch": SLOClass("batch", 2),
}


@dataclass
class TenantState:
    """Rolling per-tenant view: the difficulty estimate ``drift_aware``
    routes by (EWMA of realized per-request hard rate, None until the
    tenant's first finish), plus usage counters."""
    difficulty_ewma: Optional[float] = None
    inflight: int = 0
    n_submitted: int = 0
    n_finished: int = 0
    n_preempted: int = 0

    def observe_finish(self, n_hard: float, n_dec: float) -> None:
        if n_dec <= 0:
            return
        q = float(n_hard) / float(n_dec)
        self.difficulty_ewma = (
            q if self.difficulty_ewma is None
            else _DIFFICULTY_ALPHA * q
            + (1.0 - _DIFFICULTY_ALPHA) * self.difficulty_ewma)

    def as_dict(self) -> dict:
        return {"difficulty_ewma": self.difficulty_ewma,
                "inflight": self.inflight,
                "n_submitted": self.n_submitted,
                "n_finished": self.n_finished,
                "n_preempted": self.n_preempted}


class FleetStats:
    """The fleet ops aggregate: router counters + per-tenant state +
    per-replica ``ServeStats`` (each replica dict is itself the versioned
    ``ServeStats`` schema). ``as_dict`` is versioned like the per-replica
    schema: adding/removing/renaming a top-level key bumps
    ``SCHEMA_VERSION``.

    v2 adds the fleet-wide paged-cache aggregates (sums of the per-replica
    v3 gauges): ``fleet_cache_pages_total`` / ``fleet_cache_pages_in_use``
    / ``fleet_cache_hbm_bytes`` / ``fleet_ring_bytes_moved``."""
    SCHEMA_VERSION = 2

    def __init__(self, router: "FleetRouter"):
        self._router = router
        self.n_submitted = 0
        self.n_routed = 0
        self.n_preemptions = 0
        self.n_requeued = 0
        self.n_degraded = 0

    @property
    def n_finished(self) -> int:
        return sum(t.n_finished for t in self._router.tenants.values())

    @property
    def fleet_realized_q(self) -> float:
        """Decision-weighted realized hard rate across the fleet."""
        dec = sum(r.stats.n_decisions for r in self._router.replicas)
        hard = sum(r.stats.n_stage2 for r in self._router.replicas)
        return hard / dec if dec else 0.0

    def as_dict(self) -> dict:
        rt = self._router
        return {
            "schema_version": self.SCHEMA_VERSION,
            "policy": rt.policy,
            "n_replicas": len(rt.replicas),
            "n_pending": len(rt._pending),
            "n_submitted": self.n_submitted,
            "n_routed": self.n_routed,
            "n_finished": self.n_finished,
            "n_preemptions": self.n_preemptions,
            "n_requeued": self.n_requeued,
            "n_degraded": self.n_degraded,
            # the fleet-wide contract: requests are re-queued, never
            # dropped — anything submitted is pending, in flight, or done
            "n_dropped": (self.n_submitted - self.n_finished
                          - len(rt._pending) - sum(
                              t.inflight for t in rt.tenants.values())),
            "fleet_realized_q": self.fleet_realized_q,
            "fleet_cache_pages_total": sum(
                r.stats.cache_pages_total for r in rt.replicas),
            "fleet_cache_pages_in_use": sum(
                r.stats.cache_pages_in_use for r in rt.replicas),
            "fleet_cache_hbm_bytes": sum(
                r.stats.cache_hbm_bytes for r in rt.replicas),
            "fleet_ring_bytes_moved": sum(
                r.stats.ring_bytes_moved for r in rt.replicas),
            "health": list(rt.health),
            "tenants": {name: t.as_dict()
                        for name, t in sorted(rt.tenants.items())},
            "replicas": [r.stats.as_dict() for r in rt.replicas],
        }


@dataclass(order=True)
class _Pending:
    """Router-queue entry, ordered by (priority, arrival, submit seq) —
    the admission order a route pass walks."""
    priority: int
    arrival_time: float
    seq: int
    req: Request = field(compare=False)


class FleetRouter:
    """Admission owner across N ``ReplicaHandle`` replicas.

    ``max_queue_per_replica`` bounds each replica's unadmitted queue (the
    backpressure that makes load-aware policies meaningful; default: the
    replica's own ``n_slots``). ``provisioned_p`` optionally declares each
    replica's design-time hard rate (written to its ``stats`` so drift is
    measurable); ``drift_aware`` falls back to 0.5 for undeclared
    replicas."""

    def __init__(self, replicas: Sequence, *, policy: str = "drift_aware",
                 slo_classes: Optional[Dict[str, SLOClass]] = None,
                 max_queue_per_replica: Optional[int] = None,
                 provisioned_p: Optional[Sequence[float]] = None,
                 events: Optional[EventLog] = None):
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"policy must be one of {ROUTING_POLICIES}, "
                             f"got {policy!r}")
        self.replicas = list(replicas)
        clock = self.replicas[0].clock
        if any(r.clock is not clock for r in self.replicas):
            raise ValueError("all replicas must share ONE clock object — "
                             "pass the same clock= to every build()")
        self.clock = clock
        self.policy = policy
        self.slo_classes = dict(slo_classes if slo_classes is not None
                                else DEFAULT_SLO_CLASSES)
        self.max_queue = max_queue_per_replica
        if provisioned_p is not None:
            if len(provisioned_p) != len(self.replicas):
                raise ValueError(
                    f"provisioned_p has {len(provisioned_p)} entries for "
                    f"{len(self.replicas)} replicas")
            for r, p in zip(self.replicas, provisioned_p):
                r.stats.provisioned_p = float(p)
        self.health: List[str] = [HEALTHY] * len(self.replicas)
        self.tenants: Dict[str, TenantState] = {}
        self.events = events if events is not None else EventLog(cap=4096)
        self.stats = FleetStats(self)
        self._pending: List[_Pending] = []
        self._seen: set = set()          # every sid ever submitted (no-dup)
        self._routed_to: Dict[int, int] = {}   # sid -> replica idx (queued
        self._tenant_of: Dict[int, str] = {}   # or in flight)
        self._seq = itertools.count()
        self._rr = 0                           # round_robin cursor

    # -- admission -----------------------------------------------------------

    def _slo(self, req: Request) -> SLOClass:
        try:
            return self.slo_classes[req.slo_class]
        except KeyError:
            raise ValueError(
                f"request {req.sample_id}: unknown slo_class "
                f"{req.slo_class!r} (have {sorted(self.slo_classes)})"
            ) from None

    def submit(self, req: Request) -> None:
        """Fleet-wide validated admission: same errors as a single
        replica's ``submit`` (shared ``serve_api.validate_request``), with
        the duplicate check over everything the FLEET has ever seen."""
        validate_request(req, max_len=None,
                         is_dup=lambda sid: sid in self._seen)
        slo = self._slo(req)                 # reject unknown class early
        self._seen.add(req.sample_id)
        tenant = self.tenants.setdefault(req.tenant, TenantState())
        tenant.n_submitted += 1
        self.stats.n_submitted += 1
        self._pending.append(_Pending(slo.priority, req.arrival_time,
                                      next(self._seq), req))
        self.events.emit("submit", sid=req.sample_id, tenant=req.tenant,
                         slo=req.slo_class)

    # -- placement -----------------------------------------------------------

    def _room(self, i: int) -> bool:
        r = self.replicas[i]
        cap = self.max_queue if self.max_queue is not None else r.n_slots
        return r.queue_len < cap

    def _eligible(self, req: Request) -> List[int]:
        return [i for i in range(len(self.replicas))
                if self.health[i] == HEALTHY and self._room(i)]

    def _score_drift_aware(self, i: int, d_hat: float) -> float:
        """Lower is better: provisioning mismatch + saturation penalty +
        load tiebreak. A replica whose realized q already runs above its
        provisioned p has a saturating stage-2 bucket — routing more hard
        traffic there buys latency, not throughput."""
        r = self.replicas[i]
        p = r.stats.provisioned_p
        p = 0.5 if p is None else float(p)
        q = r.stats.realized_q_ewma
        load = (r.n_busy + r.queue_len) / max(r.n_slots, 1)
        return abs(d_hat - p) + max(0.0, q - p) + 0.25 * load

    def _tenant_difficulty(self, tenant: str) -> float:
        t = self.tenants.get(tenant)
        if t is not None and t.difficulty_ewma is not None:
            return t.difficulty_ewma
        # prior before the tenant's first finish: the fleet's mean
        # provisioned p (an uninformed request is best matched to an
        # average replica), else 0.5
        ps = [r.stats.provisioned_p for r in self.replicas
              if r.stats.provisioned_p is not None]
        return float(sum(ps) / len(ps)) if ps else 0.5

    def _place(self, req: Request, candidates: List[int]) -> int:
        if self.policy == "round_robin":
            for k in range(len(self.replicas)):
                i = (self._rr + k) % len(self.replicas)
                if i in candidates:
                    self._rr = (i + 1) % len(self.replicas)
                    return i
            raise AssertionError("no candidate")   # callers pass non-empty
        if self.policy == "least_loaded":
            return min(candidates, key=lambda i: (
                self.replicas[i].n_busy + self.replicas[i].queue_len, i))
        d_hat = self._tenant_difficulty(req.tenant)
        return min(candidates,
                   key=lambda i: (self._score_drift_aware(i, d_hat), i))

    # -- preemption ----------------------------------------------------------

    def _preemptible(self, prio: int) -> List:
        """(victim_priority, -arrival, replica_idx, req) for every QUEUED
        request a priority-``prio`` arrival may displace: strictly lower
        priority classes, or same-priority tenants over their in-flight
        budget. Sorted worst-victim-first."""
        victims = []
        for i, r in enumerate(self.replicas):
            if self.health[i] != HEALTHY:
                continue                     # degrade already revoked these
            for q in r.queue:
                v_slo = self._slo(q)
                over = (v_slo.max_inflight is not None
                        and self.tenants[q.tenant].inflight
                        > v_slo.max_inflight)
                if v_slo.priority > prio or (v_slo.priority == prio
                                             and over):
                    victims.append((v_slo.priority, q.arrival_time, i, q))
        # displace the lowest class first; within a class, the latest
        # arrival (it has waited least)
        victims.sort(key=lambda v: (-v[0], -v[1]))
        return victims

    def _try_preempt(self, req: Request, slo: SLOClass) -> Optional[int]:
        """Blocked-by-room path: displace one queued lower-priority (or
        over-budget) request back into the router's pending set, freeing
        its replica slot for ``req``. Returns the freed replica index, or
        None when nothing is preemptible."""
        for _prio, _at, i, victim in self._preemptible(slo.priority):
            taken = self.replicas[i].revoke_queued([victim.sample_id])
            if not taken:                    # admitted since the scan —
                continue                     # no longer preemptible
            v = taken[0]
            t = self.tenants[v.tenant]
            t.inflight -= 1
            t.n_preempted += 1
            del self._routed_to[v.sample_id]
            self.stats.n_preemptions += 1
            self.stats.n_requeued += 1
            v_slo = self._slo(v)
            self._pending.append(_Pending(v_slo.priority, v.arrival_time,
                                          next(self._seq), v))
            self.events.emit("preempt", sid=v.sample_id, tenant=v.tenant,
                             slo=v.slo_class, replica=i,
                             by_sid=req.sample_id, by_slo=slo.name)
            return i
        return None

    # -- the routing pass ----------------------------------------------------

    def _route_one(self, req: Request, slo: SLOClass) -> bool:
        tenant = self.tenants[req.tenant]
        if (slo.max_inflight is not None
                and tenant.inflight >= slo.max_inflight):
            return False                     # budget-blocked: preemption
        candidates = self._eligible(req)     # cannot help, wait for
        if not candidates:                   # finishes
            freed = self._try_preempt(req, slo)
            if freed is None:
                return False
            candidates = [freed]
        i = self._place(req, candidates)
        self.replicas[i].submit(req)
        self._routed_to[req.sample_id] = i
        self._tenant_of[req.sample_id] = req.tenant
        tenant.inflight += 1
        self.stats.n_routed += 1
        self.events.emit("route", sid=req.sample_id, tenant=req.tenant,
                         slo=req.slo_class, replica=i, policy=self.policy,
                         queue_len=self.replicas[i].queue_len,
                         n_busy=self.replicas[i].n_busy)
        return True

    def _route(self) -> int:
        """One admission pass: walk arrived pending requests in (priority,
        arrival, seq) order, placing what fits. Blocked requests stay
        pending — nothing is ever dropped."""
        now = self.clock.now()
        arrived = sorted(p for p in self._pending
                         if p.arrival_time <= now)
        if arrived and not any(h == HEALTHY for h in self.health):
            raise RuntimeError(
                "no healthy replica left to route pending requests")
        n = 0
        for p in arrived:
            if self._route_one(p.req, self._slo(p.req)):
                self._pending.remove(p)
                n += 1
        return n

    # -- finish feed ---------------------------------------------------------

    def _harvest(self, i: int) -> None:
        for sid, n_hard, n_dec in self.replicas[i].drain_finished():
            ridx = self._routed_to.pop(sid, None)
            # the replica feed carries only sids; the tenant comes from the
            # routing record stamped in _route_one
            tenant_name = self._tenant_of.pop(sid, "default")
            t = self.tenants.setdefault(tenant_name, TenantState())
            t.inflight = max(0, t.inflight - 1)
            t.n_finished += 1
            t.observe_finish(n_hard, n_dec)
            self.events.emit("finish", sid=sid, tenant=tenant_name,
                             replica=i if ridx is None else ridx,
                             n_decisions=n_dec, n_hard=n_hard)

    # -- the fleet loop ------------------------------------------------------

    def step(self) -> str:
        """One fleet iteration: route what is admissible, step every
        replica with live work, fold finish feeds. Same state machine as a
        single replica: ``"busy"`` (progress), ``"waiting"`` (future
        arrivals own the clock — call ``advance_clock``), ``"idle"``."""
        routed = self._route()
        busy = routed > 0
        waiting = False
        for i, r in enumerate(self.replicas):
            if r.n_busy == 0 and r.queue_len == 0:
                continue
            st = r.step()
            self._harvest(i)
            if st == "busy":
                busy = True
            elif st == "waiting":
                waiting = True
        if busy:
            return "busy"
        if waiting or self._pending:
            return "waiting"
        return "idle"

    def advance_clock(self) -> bool:
        """Jump the shared clock to the next fleet event (earliest pending
        arrival or replica-queued arrival in the future). Returns False
        when there is nothing to advance to."""
        now = self.clock.now()
        times = [p.arrival_time for p in self._pending
                 if p.arrival_time > now]
        for r in self.replicas:
            t = r.next_arrival()
            if t is not None and t > now:
                times.append(t)
        if not times:
            return False
        self.clock.advance_to(min(times))
        return True

    def run(self) -> Dict[int, List[int]]:
        """Drive the fleet until every request finishes; returns the
        merged per-sample results (exactly the streams a single-scheduler
        oracle run of the same requests produces)."""
        while True:
            st = self.step()
            if st == "idle":
                break
            if st == "waiting" and not self.advance_clock():
                # arrived-but-blocked work with no future event means every
                # replica must drain something first; step again (replicas
                # with in-flight work report busy, so this cannot spin)
                if not any(r.n_busy > 0 for r in self.replicas):
                    raise RuntimeError(
                        "fleet wedged: pending requests, no healthy "
                        "capacity, nothing in flight")
        for i, r in enumerate(self.replicas):
            r.drain()                        # final deferred-token harvest
            self._harvest(i)
        return self.results

    @property
    def results(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for r in self.replicas:
            out.update(r.results)
        return out

    # -- health / degrade ----------------------------------------------------

    def degrade_replica(self, i: int, failed_devices=None,
                        q: Optional[float] = None,
                        pause_budget_ms: float = float("inf")) -> int:
        """Mark replica ``i`` DEGRADED: it gets no new traffic, its queued
        (unadmitted) requests are revoked and redistributed to the rest of
        the fleet, and its in-flight work drains normally (streams
        unperturbed). With ``failed_devices``, the replica is additionally
        re-planned onto its survivor chips via ``migrate_on_device_loss``
        (live migration at its next discrete re-plan point). Returns the
        number of redistributed requests."""
        if self.health[i] == DEGRADED:
            return 0
        self.health[i] = DEGRADED
        self.stats.n_degraded += 1
        revoked = self.replicas[i].revoke_queued(None)
        for req in revoked:
            t = self.tenants[req.tenant]
            t.inflight -= 1
            del self._routed_to[req.sample_id]
            self._tenant_of.pop(req.sample_id, None)
            slo = self._slo(req)
            self._pending.append(_Pending(slo.priority, req.arrival_time,
                                          next(self._seq), req))
            self.stats.n_requeued += 1
        if failed_devices is not None:
            from repro.runtime.migration import migrate_on_device_loss
            migrate_on_device_loss(self.replicas[i], failed_devices, q=q,
                                   pause_budget_ms=pause_budget_ms)
        self.events.emit("degrade", replica=i,
                         redistributed=len(revoked),
                         device_loss=failed_devices is not None)
        return len(revoked)

    def restore_replica(self, i: int) -> None:
        """Return a drained DEGRADED replica to the routable set."""
        self.health[i] = HEALTHY
        self.events.emit("restore", replica=i)
