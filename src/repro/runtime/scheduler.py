"""Slot-based decode scheduling: continuous batching for two-stage
early-exit serving.

ATHEENA provisions stage 2 for the *fraction* of hard samples (paper §IV),
but a step-synchronous decode server realizes that only within a step: every
easy token waits for the ring to drain before the batch may advance, so
stage 1 idles exactly when early exits should be paying off. This module
makes per-sample progression asynchronous (HAPI-style staged progressive
inference; cf. the Laskaridis et al. early-exit survey):

  * ``ContinuousScheduler`` owns a fixed pool of decode **slots**. Each slot
    holds one in-flight request with its own step counter (absolute cache
    position), so one pooled stage-1 dispatch advances samples sitting at
    *different* depths — the per-row ``step`` vector path in
    ``models.attention``/``models.mla``. Slots whose token exits early keep
    decoding through stage 1 on the next tick; slots whose token is hard are
    **parked** and their hidden row + stage-2 cache rows + position ride the
    pytree ring (payload lanes ``{"h", "cache", "step"}``) until a bucket
    fills, the bucketed stage-2 dispatch scatters results back at each row's
    own cache offset, and the slots resume. Completed slots are immediately
    backfilled from an **admission queue** of open-loop (Poisson) arrivals.

  * ``SyncScheduler`` is the degenerate policy: static batch formation over
    a step-synchronous server's ``generate`` (``DecodeServer`` — which stays
    bitwise-parity-checked against ``HostLoopDecoder``). It exists so both
    policies share one request/latency bookkeeping and can be compared under
    identical open-loop traffic (``benchmarks/serve_continuous.py``).

**Correctness contract.** Continuous mode deliberately trades batch-level
bitwise identity for utilization: merged logits are never materialized per
step across the batch, and samples interleave arbitrarily. What is preserved
— and enforced by ``tests/test_scheduler.py`` — is *per-sample token-stream
equivalence*: every sample id's greedy token stream is identical to the one
``HostLoopDecoder`` produces, in order, with no token dropped or duplicated.
Per-row computations (RMSNorm, attention over the row's own cache span,
row-wise matmuls) are batch-composition-independent, which is what makes the
streams match even though the batches they were computed in never do.

**Masked pooled stage 1.** The pool tick runs stage 1 on the full slot
batch with a per-slot ``active`` mask: free/parked rows compute garbage that
is discarded, and their caches are re-selected to the pre-tick state
(``_seg_select``) so recurrent state (mamba2/rglru) advances exactly once
per *consumed* token and attention rows re-write their slot when they
resume. This keeps every tick a fixed-shape jitted program — no recompiles
as slots churn.

The device-side pytree ring (``ring_init``/``ring_enqueue``/``ring_drain``)
and the chunked-enqueue/backpressure plumbing (``RingQueue``) live here and
are shared with the step-synchronous servers in ``runtime/serve_loop.py``
(which re-exports them; the paper's Fig. 7 sizing/deadlock story is
unchanged). ``ServeStats`` also lives here: it now records per-request
submit→finish latency (``latency_p50/p90/p99``) and a per-dispatch
``realized_q`` series — the drift signal threshold re-planning consumes.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.runtime import faults, observe, telemetry
from repro.runtime.serve_api import RequestQueue
from repro.runtime.stage_executor import StagePlacement


@dataclass
class ServeConfig:
    capacity: int                   # stage-2 bucket size (ceil(p*B) rounded)
    queue_depth: int = 4            # buckets the buffer can hold
    c_thr: float = 0.9
    max_pending: int = 16           # pending device result groups (stage-1
                                    # batches + stage-2 buckets) before the
                                    # oldest are harvested to host, bounding
                                    # device memory on long-running streams
    harvest_timeout_s: Optional[float] = 60.0   # bound on any single wait
                                    # for a pending device result; a bucket
                                    # that never resolves raises
                                    # HarvestTimeout instead of wedging the
                                    # hot loop (None = wait forever)


class HarvestTimeout(TimeoutError):
    """A pending device result failed to become ready within the harvest
    timeout — surfaces a wedged transfer/dispatch as an error instead of an
    unbounded hot-loop hang."""


def bounded_wait(tree, timeout_s: Optional[float], what: str = "result"):
    """Wait for every jax.Array leaf of ``tree`` to be ready, raising
    ``HarvestTimeout`` past ``timeout_s`` (None = block natively). Polls
    ``is_ready()`` with a growing sleep so the fast path (already-ready
    results, the overwhelmingly common case) costs one no-op pass."""
    if timeout_s is None:
        return tree
    deadline = time.perf_counter() + timeout_s
    pause = 1e-4
    for leaf in jax.tree.leaves(tree):
        is_ready = getattr(leaf, "is_ready", None)
        if is_ready is None:                 # numpy/python leaf: ready
            continue
        while not is_ready():
            if time.perf_counter() >= deadline:
                raise HarvestTimeout(
                    f"{what} not ready after {timeout_s:.1f}s — a device "
                    f"dispatch or cross-stage transfer is stuck")
            time.sleep(pause)
            pause = min(pause * 2.0, 0.05)
    return tree


# bounded history so long-running streams keep O(1)-ish stats memory: the
# latency reservoir covers the percentile window, the q series the recent
# drift window (the re-planning signal cares about *persistent* drift)
_SERIES_CAP = 65536


@dataclass
class ServeStats:
    """Serving counters. ``n_samples`` counts distinct samples admitted;
    ``n_decisions`` counts exit decisions — equal for prefill (one decision
    per sample), ``n_samples * generated_tokens`` for decode. ``realized_q``
    is therefore per-decision, which is the quantity the stage-2 bucket is
    provisioned against in both regimes.

    Per-stage occupancy (the TAP apportionment feedback signal): a stage-1
    "cycle" is either a real dispatch (one batch/step/tick) or a forced-drain
    stall — a cycle spent waiting on stage 2 because the ring was full
    (every server counts ``n_stalls`` per forced drain, so one batch under
    heavy backpressure can stall several times). ``stage1_occupancy`` is
    the fraction of cycles doing stage-1 work; q > p pushes it below 1,
    the paper's Fig. 4 lower band. Stage 2's slots are its bucket lanes —
    ``stage2_occupancy`` is the fraction carrying real hard samples
    rather than flush padding (q < p pushes it below 1: bucket bubbles).
    ``stage1_chips``/``stage2_chips`` record the submesh sizes the serving
    placement apportioned (1/1 for single-device).

    Open-loop request tracking: ``record_submit``/``record_finish`` stamp
    per-request wall time; ``latency_p50/p90/p99`` summarize the (bounded)
    reservoir. ``realized_q_series`` keeps the per-dispatch hard fraction —
    the drift signal online threshold re-planning consumes (a persistent
    q > p trend means C_thr or the stage mesh needs re-planning).

    Windowed drift view: ``realized_q_ewma`` is the EWMA of the recent q
    series (``telemetry.ewma`` — the ONE definition the controller and the
    drift benchmarks share) and ``q_drift`` its excursion from the
    provisioned p (0.0 until a controller / caller sets
    ``provisioned_p``). Both ride in ``as_dict``.

    ``as_dict`` is a VERSIONED schema (``SCHEMA_VERSION``, emitted as the
    ``schema_version`` key): the dict is consumed outside this process —
    the serve CLI's JSON output, the benchmark payloads
    ``benchmarks/compare.py`` gates against ``baseline_cpu.json``, and the
    fleet ops surface (``FleetStats.as_dict`` embeds one per replica).
    Fields accreted ad hoc across PRs 2-6; from v2 on, adding/removing/
    renaming a key REQUIRES a version bump (and
    ``tests/test_serve_api.py`` freezes the key set). The schema is
    documented in README's "Serving stats schema" section.

    v3 adds the paged-KV-cache memory economics: ``cache_pages_total`` /
    ``cache_pages_in_use`` / ``cache_pages_free`` (the page allocator's
    free-list view; all 0 for dense pools), ``cache_hbm_bytes`` (bytes the
    KV store actually holds resident — the page pools when paged, the dense
    slot store otherwise), ``page_fragmentation`` (1 − live_tokens /
    (pages_in_use × page_size): the fraction of allocated page capacity not
    holding a live token — tail-page waste), and ``ring_bytes_moved``
    (cumulative bytes enqueued onto the stage-boundary ring; the hop-size
    gauge the paged page-index payload is meant to shrink)."""
    SCHEMA_VERSION = 3
    n_samples: int = 0
    n_decisions: int = 0
    n_exited: int = 0
    n_stage2: int = 0
    n_stalls: int = 0
    n_stage1_batches: int = 0       # stage-1 dispatches (batches / ticks)
    n_buckets: int = 0              # running aggregate, O(1) memory
    provisioned_p: Optional[float] = None   # the rate the mesh was sized for
    bucket_fill_sum: float = 0.0
    stage1_chips: int = 1
    stage2_chips: int = 1
    # per-request latency + per-dispatch q (bounded deques, not lists: the
    # bucket-fill aggregate stays O(1); these keep a capped history window)
    submit_times: Dict[int, float] = field(default_factory=dict, repr=False)
    latencies: Deque[float] = field(
        default_factory=lambda: deque(maxlen=_SERIES_CAP), repr=False)
    realized_q_series: Deque[float] = field(
        default_factory=lambda: deque(maxlen=_SERIES_CAP), repr=False)
    # the drift filter's window, kept as its own bounded deque so the
    # per-tick EWMA folds O(window) recent entries instead of copying the
    # full (up to _SERIES_CAP) series on every controller visit
    _q_window: Deque[float] = field(
        default_factory=lambda: deque(maxlen=telemetry.DRIFT_WINDOW),
        repr=False)
    # live-migration accounting: completed migrations, rolled-back attempts,
    # and the measured serving pause (admission-closed to admission-reopened)
    # per completed migration — the zero-downtime budget the migration bench
    # gates on
    n_migrations: int = 0
    n_migration_rollbacks: int = 0
    migration_pauses_ms: Deque[float] = field(
        default_factory=lambda: deque(maxlen=1024), repr=False)
    # paged-cache memory economics (v3): the owning scheduler/server keeps
    # these current; dense pools leave the page counters at 0
    cache_pages_total: int = 0
    cache_pages_in_use: int = 0
    cache_hbm_bytes: int = 0
    cache_page_size: int = 0        # not emitted; fragmentation denominator
    live_tokens: int = 0            # not emitted; fragmentation numerator
    ring_bytes_moved: int = 0

    def record_decisions(self, n: int, n_hard: int) -> None:
        self.n_stage1_batches += 1
        self.n_decisions += n
        self.n_exited += n - n_hard
        q = n_hard / n if n else 0.0
        self.realized_q_series.append(q)
        self._q_window.append(q)

    def record_bucket(self, fill: float) -> None:
        self.n_buckets += 1
        self.bucket_fill_sum += fill

    def record_placement(self, placement) -> None:
        self.stage1_chips = placement.ex1.n_devices
        self.stage2_chips = placement.ex2.n_devices

    def record_submit(self, sample_id: int, t: float) -> None:
        self.submit_times[sample_id] = t

    def record_migration(self, pause_ms: float) -> None:
        self.n_migrations += 1
        self.migration_pauses_ms.append(float(pause_ms))

    def record_migration_rollback(self) -> None:
        self.n_migration_rollbacks += 1

    def _pause_pct(self, pct: float) -> float:
        if not self.migration_pauses_ms:
            return 0.0
        return float(np.percentile(np.asarray(self.migration_pauses_ms), pct))

    @property
    def migration_pause_p50_ms(self) -> float:
        return self._pause_pct(50.0)

    @property
    def migration_pause_p99_ms(self) -> float:
        return self._pause_pct(99.0)

    def record_finish(self, sample_id: int, t: float) -> None:
        """Submit→finish wall latency; unmatched finishes are ignored so
        servers that never recorded submits (closed-loop tests) stay
        latency-free rather than wrong."""
        t0 = self.submit_times.pop(sample_id, None)
        if t0 is not None:
            self.latencies.append(t - t0)

    def _latency_pct(self, pct: float) -> float:
        if not self.latencies:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies), pct))

    @property
    def latency_p50(self) -> float:
        return self._latency_pct(50.0)

    @property
    def latency_p90(self) -> float:
        return self._latency_pct(90.0)

    @property
    def latency_p99(self) -> float:
        return self._latency_pct(99.0)

    @property
    def n_finished(self) -> int:
        return len(self.latencies)

    @property
    def mean_bucket_fill(self) -> float:
        return self.bucket_fill_sum / self.n_buckets if self.n_buckets else 0.0

    @property
    def stage1_occupancy(self) -> float:
        total = self.n_stage1_batches + self.n_stalls
        return self.n_stage1_batches / total if total else 0.0

    @property
    def stage2_occupancy(self) -> float:
        # buckets share one capacity, so the mean fill IS the slot occupancy
        return self.mean_bucket_fill

    @property
    def realized_q(self) -> float:
        return self.n_stage2 / max(self.n_decisions, 1)

    @property
    def realized_q_ewma(self) -> float:
        """EWMA of the recent per-dispatch q (telemetry.ewma's window/alpha
        — the shared drift-filter definition; folded over the bounded
        window deque, so a hot-loop read costs O(window) not O(series))."""
        return telemetry.ewma(self._q_window)

    @property
    def q_drift(self) -> float:
        """Windowed drift of realized q from the provisioned p (0.0 when no
        p was declared — an unprovisioned server has nothing to drift
        from)."""
        if self.provisioned_p is None:
            return 0.0
        return self.realized_q_ewma - self.provisioned_p

    @property
    def decisions_per_sample(self) -> float:
        return self.n_decisions / max(self.n_samples, 1)

    @property
    def cache_pages_free(self) -> int:
        return max(self.cache_pages_total - self.cache_pages_in_use, 0)

    @property
    def page_fragmentation(self) -> float:
        """Fraction of allocated page capacity not holding a live token
        (tail-page internal fragmentation). 0.0 for dense pools / empty
        allocators."""
        cap = self.cache_pages_in_use * self.cache_page_size
        if cap <= 0:
            return 0.0
        return float(min(max(1.0 - self.live_tokens / cap, 0.0), 1.0))

    def as_dict(self):
        return {"schema_version": self.SCHEMA_VERSION,
                "n_samples": self.n_samples, "n_decisions": self.n_decisions,
                "n_exited": self.n_exited, "n_stage2": self.n_stage2,
                "n_stalls": self.n_stalls, "realized_q": self.realized_q,
                "decisions_per_sample": self.decisions_per_sample,
                "mean_bucket_fill": self.mean_bucket_fill,
                "stage1_chips": self.stage1_chips,
                "stage2_chips": self.stage2_chips,
                "stage1_occupancy": self.stage1_occupancy,
                "stage2_occupancy": self.stage2_occupancy,
                "n_finished": self.n_finished,
                "latency_p50": self.latency_p50,
                "latency_p90": self.latency_p90,
                "latency_p99": self.latency_p99,
                "provisioned_p": self.provisioned_p,
                "realized_q_ewma": self.realized_q_ewma,
                "q_drift": self.q_drift,
                "n_migrations": self.n_migrations,
                "n_migration_rollbacks": self.n_migration_rollbacks,
                "migration_pause_p50_ms": self.migration_pause_p50_ms,
                "migration_pause_p99_ms": self.migration_pause_p99_ms,
                "cache_pages_total": self.cache_pages_total,
                "cache_pages_in_use": self.cache_pages_in_use,
                "cache_pages_free": self.cache_pages_free,
                "cache_hbm_bytes": self.cache_hbm_bytes,
                "page_fragmentation": self.page_fragmentation,
                "ring_bytes_moved": self.ring_bytes_moved,
                "realized_q_series": list(self.realized_q_series)}


# ---------------------------------------------------------------------------
# device-side ring buffer over a pytree payload: per-leaf (size, *row) slabs
# sharing one id lane + int32 cursors, updated in place (donated) by jitted
# steps. Decode payloads add per-row "step" lanes (the row's absolute cache
# position) so stage-2 results scatter back at the right offsets.
# ---------------------------------------------------------------------------

def ring_init(size: int, row, dtype=None) -> dict:
    """Allocate the ring. ``row`` is either a bare shape tuple with ``dtype``
    (single-slab convenience, payload = one array) or a pytree whose leaves
    carry ``.shape``/``.dtype`` per-row (arrays or ShapeDtypeStructs).
    Returns {'data' pytree of (size, *row_leaf), 'ids' (size,), 'head' (),
    'count' ()} — ids slots are -1 (the paper's unused Sample ID)."""
    if dtype is not None:
        row = jax.ShapeDtypeStruct(tuple(row), dtype)
    data = jax.tree.map(
        lambda r: jnp.zeros((size,) + tuple(r.shape), r.dtype), row)
    return {
        "data": data,
        "ids": jnp.full((size,), -1, jnp.int32),
        "head": jnp.zeros((), jnp.int32),
        "count": jnp.zeros((), jnp.int32),
    }


@functools.partial(jax.jit, donate_argnums=(0,))
def _ring_enqueue_range(buf: dict, slab, slab_ids, lo, hi) -> dict:
    """Append slab rows [lo, min(hi, n_valid)) at the ring's tail, where
    n_valid is the compacted slab's valid prefix (ids >= 0). ``slab`` is a
    pytree matching buf['data'] rows (every leaf (n, *row_leaf)). The donated
    buffer is updated in place; unselected rows scatter out of bounds and
    are dropped. The caller guarantees the selected range fits."""
    with jax.named_scope("ring_enqueue"):
        size = buf["ids"].shape[0]
        n = slab_ids.shape[0]
        n_valid = jnp.sum(slab_ids >= 0).astype(jnp.int32)
        upper = jnp.minimum(hi, n_valid)
        lanes = jnp.arange(n, dtype=jnp.int32)
        sel = (lanes >= lo) & (lanes < upper)
        idx = (buf["head"] + buf["count"] + lanes - lo) % size
        idx = jnp.where(sel, idx, size)              # OOB -> dropped
        return {
            "data": jax.tree.map(lambda d, s: d.at[idx].set(s, mode="drop"),
                                 buf["data"], slab),
            "ids": buf["ids"].at[idx].set(slab_ids, mode="drop"),
            "head": buf["head"],
            "count": buf["count"] + jnp.maximum(upper - lo, 0),
        }


def ring_enqueue(buf: dict, slab, slab_ids: jnp.ndarray) -> dict:
    """Append the whole valid prefix of a compacted slab pytree (ids >= 0)
    at the ring's tail; see ``_ring_enqueue_range``."""
    return _ring_enqueue_range(buf, slab, slab_ids, 0, slab_ids.shape[0])


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("capacity",))
def ring_drain(buf: dict, capacity: int):
    """Pop up to ``capacity`` samples from the ring's head into a stage-2
    bucket. Returns (buf, bucket pytree of (capacity, *row_leaf),
    bucket_ids (capacity,)) — slots past the take carry id -1 (flush) and
    whatever stale rows the ring holds (stage 2 is row-independent, flush
    rows are discarded by the exit merge)."""
    with jax.named_scope("ring_drain"):
        size = buf["ids"].shape[0]
        take_n = jnp.minimum(buf["count"], capacity).astype(jnp.int32)
        lanes = jnp.arange(capacity, dtype=jnp.int32)
        idx = (buf["head"] + lanes) % size
        valid = lanes < take_n
        bucket = jax.tree.map(lambda d: jnp.take(d, idx, axis=0),
                              buf["data"])
        bucket_ids = jnp.where(valid, jnp.take(buf["ids"], idx), -1)
        new = {
            "data": buf["data"],
            "ids": buf["ids"].at[jnp.where(valid, idx, size)].set(
                -1, mode="drop"),
            "head": (buf["head"] + take_n) % size,
            "count": buf["count"] - take_n,
        }
        return new, bucket, bucket_ids


class RingQueue:
    """Chunked-enqueue/bucket-pop plumbing over the device ring: the one
    hard-token queue implementation the step-synchronous servers
    (``runtime/serve_loop.py``) and the continuous scheduler share.

    ``enqueue`` appends ``n_hard`` valid rows of a compacted slab pytree in
    chunks, calling ``drain_one`` (pop a bucket + dispatch stage 2) whenever
    the ring is out of space — so a batch hairier than the whole ring still
    serves, it just backpressures stage 1 harder (paper Fig. 7). Full
    buckets drain first by construction (count == size when stalled).

    The slab arrives from stage 1; placing it onto ``ex`` IS the stage
    boundary hop — under a disaggregated placement that is a device-to-
    device ``jax.device_put`` across submesh shardings, and the ring itself
    is resident on stage 2's submesh."""

    def __init__(self, sc: ServeConfig, ex, stats: ServeStats):
        self.sc = sc
        self.ex = ex                      # the ring + stage 2 live here
        self.stats = stats
        self.size = sc.queue_depth * sc.capacity
        self._buf: Optional[dict] = None
        self.count = 0                    # host mirror of buf['count']
        self._row_nbytes = 0              # per-row payload bytes (all leaves)

    def reset(self) -> None:
        self._buf, self.count, self._row_nbytes = None, 0, 0

    def _note_row_bytes(self) -> None:
        """Cache the per-row payload size of the live buffer — the unit of
        ``stats.ring_bytes_moved`` (a paged payload ships page *indices*
        instead of cache rows, which is exactly what this gauge shows)."""
        self._row_nbytes = sum(
            int(np.prod(leaf.shape[1:])) * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(self._buf["data"]))

    def ensure(self, row_spec) -> dict:
        """Allocate (or return) the device buffer for payload rows shaped
        ``row_spec`` — the fused pool tick needs the buffer BEFORE launch
        (it is donated through the tick), whereas ``enqueue`` can defer
        allocation to the first slab it sees."""
        if self._buf is None:
            self._buf = self.ex.place_io(ring_init(self.size, row_spec))
            self._note_row_bytes()
        return self._buf

    def put_buf(self, buf: dict) -> None:
        """Swap in the buffer a donated tick returned (the old one's
        storage was consumed by the donation)."""
        self._buf = buf

    def note_enqueued(self, k: int) -> None:
        """Advance the host count mirror for ``k`` rows a fused tick
        already wrote device-side."""
        self.count += k
        self.stats.ring_bytes_moved += k * self._row_nbytes

    def enqueue(self, slab_tree, slab_ids, n_hard: int,
                drain_one: Callable[[], None], off: int = 0,
                fire_fault: bool = True) -> None:
        """Append rows [off, n_hard) of the compacted slab. ``off > 0`` is
        the fused tick's overflow spill: the first ``off`` rows already
        sit in the ring (written in-kernel), and the tick fired the
        'enqueue' fault point itself, so the spill skips it
        (``fire_fault=False`` — one visit per logical enqueue either
        way)."""
        if fire_fault:
            faults.fault_point("enqueue")
        slab_tree = self.ex.place_io(slab_tree)
        slab_ids = self.ex.place_io(slab_ids)
        if self._buf is None:
            spec = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                slab_tree)
            self._buf = self.ex.place_io(ring_init(self.size, spec))
            self._note_row_bytes()
        while off < n_hard:
            free = self.size - self.count
            if free == 0:
                self.stats.n_stalls += 1
                before = self.count
                # a transiently-failed drain retries with backoff; a drain
                # that "succeeds" without freeing ring space would spin this
                # stall loop forever, so no-progress is an error, not a hang
                faults.retry(drain_one, what="backpressure-drain")
                if self.count >= before:
                    raise RuntimeError(
                        "ring backpressure drain made no progress "
                        f"(count {before} -> {self.count}) — stage-2 "
                        "dispatch is stuck")
                continue
            take = min(free, n_hard - off)
            self._buf = _ring_enqueue_range(self._buf, slab_tree, slab_ids,
                                            off, off + take)
            self.count += take
            self.stats.ring_bytes_moved += take * self._row_nbytes
            off += take

    def pop(self):
        """Pop up to ``capacity`` rows; returns (bucket pytree, ids,
        n_taken) or None when the ring is empty — n_taken is authoritative
        for callers mirroring the FIFO host-side. Updates occupancy
        stats."""
        take = min(self.count, self.sc.capacity)
        if take == 0:
            return None
        self._buf, bucket, bucket_ids = ring_drain(self._buf,
                                                   self.sc.capacity)
        self.count -= take
        self.stats.n_stage2 += take
        self.stats.record_bucket(take / self.sc.capacity)
        return bucket, bucket_ids, take


# ---------------------------------------------------------------------------
# sample-major row helpers (shared with serve_loop): gather rows into a
# compacted slab / scatter updated bucket rows back into the store
# ---------------------------------------------------------------------------

@jax.jit
def _gather_rows(rows, ids):
    """Gather sample-major rows by compacted slab ids (-1 flush slots read
    row 0; their content is never used — flush ids drop on enqueue)."""
    take = jnp.maximum(ids, 0)
    return jax.tree.map(lambda m: jnp.take(m, take, axis=0), rows)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(rows, bucket_rows, ids):
    """Scatter updated bucket cache rows back into the sample-major store;
    flush ids (-1) scatter out of bounds and are dropped. Donated: the
    store is updated in place."""
    b = jax.tree.leaves(rows)[0].shape[0]
    safe = jnp.where(ids >= 0, ids, b)
    return jax.tree.map(lambda m, r: m.at[safe].set(r, mode="drop"),
                        rows, bucket_rows)


# ---------------------------------------------------------------------------
# open-loop request plumbing: arrivals, clocks
# ---------------------------------------------------------------------------

@dataclass
class Request:
    """One decode request in the admission queue. ``arrival_time`` is in the
    scheduler clock's time base (seconds); a request is admissible once the
    clock passes it — submit everything up front to replay a trace.

    ``tenant``/``slo_class`` identify who submitted and under which service
    class — the fleet router (``runtime/router.py``) keys priority
    admission, per-tenant budgets and difficulty estimates on them; a bare
    scheduler ignores both (single-tenant serving is the degenerate
    fleet)."""
    sample_id: int
    prompt: np.ndarray          # (S,) int32
    n_tokens: int               # total tokens to emit (incl. prefill token)
    arrival_time: float = 0.0
    tenant: str = "default"
    slo_class: str = "standard"


class Clock:
    """Wall clock with fast-forward: ``now`` is seconds since construction
    plus all skipped idle time, so an idle server jumps to the next arrival
    instead of sleeping, while *service* time stays real wall time. Both
    policies measure latency in this one time base."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._skip = 0.0

    def now(self) -> float:
        return time.perf_counter() - self._t0 + self._skip

    def advance_to(self, t: float) -> None:
        gap = t - self.now()
        if gap > 0:
            self._skip += gap


class LogicalClock:
    """Deterministic clock for property tests: only ``advance_to`` moves it."""

    def __init__(self, t: float = 0.0):
        self._t = t

    def now(self) -> float:
        return self._t

    def advance_to(self, t: float) -> None:
        self._t = max(self._t, t)


# ---------------------------------------------------------------------------
# pooled segment-cache helpers. A segment cache (ee.split_caches output /
# run_layers layout) is {'first': [per-layer pytrees, batch axis 0],
# 'blocks': leaves with batch axis 1 (leading superblock axis), 'rem':
# [batch axis 0]}. The slot pool holds one such tree of width n_slots and
# admits/ticks rows in place.
# ---------------------------------------------------------------------------

def _seg_map2(f_ax0, f_ax1, a, b):
    return {"first": jax.tree.map(f_ax0, a["first"], b["first"]),
            "blocks": jax.tree.map(f_ax1, a["blocks"], b["blocks"]),
            "rem": jax.tree.map(f_ax0, a["rem"], b["rem"])}


def seg_pool_like(seg, n_slots: int):
    """A zeroed slot-pool segment cache shaped like ``seg`` (batch 1) but
    ``n_slots`` wide."""
    def ax0(x):
        return jnp.zeros((n_slots,) + x.shape[1:], x.dtype)

    def ax1(x):
        return jnp.zeros(x.shape[:1] + (n_slots,) + x.shape[2:], x.dtype)

    return {"first": jax.tree.map(ax0, seg["first"]),
            "blocks": jax.tree.map(ax1, seg["blocks"]),
            "rem": jax.tree.map(ax0, seg["rem"])}


def _seg_select(active, new, old):
    """Per-slot cache select: keep ``new`` where the slot was active this
    tick, ``old`` otherwise — parked/free rows' garbage compute is discarded
    and recurrent state advances exactly once per consumed token."""
    def sel(axis):
        def f(n, o):
            shape = [1] * n.ndim
            shape[axis] = n.shape[axis]
            return jnp.where(active.reshape(shape), n, o)
        return f

    return _seg_map2(sel(0), sel(1), new, old)


# ---------------------------------------------------------------------------
# jitted pool-tick / lane-update steps (module level: the jit cache is keyed
# on the stage callables, so fresh scheduler instances over the same
# DecodeFns reuse compiled programs)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(1,),
                   static_argnames=("s1", "backend"))
def _pool_tick(tok, c1, pos, active, start, budget, c_thr, *, s1, backend):
    """One continuous tick over the whole slot pool: masked stage 1 at
    per-slot positions, fused exit decision + compaction, easy-token
    advance. The active mask is device-resident — easy rows stay active
    until their token budget is spent (``pos - start + 1`` counts emitted
    tokens), hard rows deactivate (parked) — so a tick needs no host
    uploads at all. Returns everything the host needs to park/emit/enqueue:
    (new_c1, hard slab, slab slot ids, slab steps, n_hard, easy mask,
    hard mask, emitted tokens, new tok lane, new pos lane, new active,
    per-slot exit confidences — the controller's reservoir feed, already
    computed by the fused decision kernel so exposing it is free)."""
    with jax.named_scope("pool_tick"):
        h, nc1, exit_logits = s1(tok, c1, pos)
        nc1 = _seg_select(active, nc1, c1)
        # the decision kernel's pred IS the greedy token — one logits pass
        # serves both the exit decision and the emitted token
        exit_mask, pred, conf = dispatch.exit_decision_op(exit_logits, c_thr,
                                                          backend=backend)
        easy = active & exit_mask
        hard = active & ~exit_mask
        n = tok.shape[0]
        slab, src, n_hard = dispatch.gather_compact_op(h, hard, n,
                                                       backend=backend)
        slab_slots = src                      # slot index IS the ring id
        slab_steps = jnp.where(src >= 0, jnp.take(pos, jnp.maximum(src, 0)),
                               0)
        new_tok = jnp.where(easy[:, None], pred[:, None], tok)
        new_pos = pos + easy.astype(jnp.int32)
        new_active = easy & (new_pos - start + 1 < budget)
        return (nc1, slab, slab_slots, slab_steps, n_hard, easy, hard, pred,
                new_tok, new_pos, new_active, conf)


@functools.partial(jax.jit, donate_argnums=(1, 6),
                   static_argnames=("s1", "backend"))
def _pool_tick_fused(tok, c1, pos, active, start, budget, ring, rows, c_thr,
                     *, s1, backend):
    """The persistent-tick variant: ONE compiled program per steady-state
    decode step. Stage 1, the cache select, the exit decision, compaction
    AND the ring enqueue all trace into this jit — the ring buffer is
    donated through the tick, the fused dispatch kernel writes compacted
    hard rows (hidden + stage-2 cache rows gathered from the sample-major
    store + step lanes) straight into the slabs at (head+count) offsets,
    and the kernel's pred doubles as the emitted token. ``rows`` (the
    stage-2 store) is read, never donated. Rows past the ring's free space
    are not written; the host spills them through the composed
    backpressure chain using the returned ``src``/``h``.

    Only valid on a non-disaggregated placement (one submesh cannot span
    two)."""
    with jax.named_scope("pool_tick_fused"):
        h, nc1, exit_logits = s1(tok, c1, pos)
        nc1 = _seg_select(active, nc1, c1)
        n = tok.shape[0]
        lanes = jnp.arange(n, dtype=jnp.int32)  # slot index IS the ring id
        payload = {"h": h, "cache": rows, "step": pos}
        ring, exit_mask, pred, conf, src, n_hard = dispatch.fused_dispatch(
            exit_logits, active, lanes, payload, ring, c_thr, backend=backend)
        easy = active & exit_mask
        hard = active & ~exit_mask
        new_tok = jnp.where(easy[:, None], pred[:, None], tok)
        new_pos = pos + easy.astype(jnp.int32)
        new_active = easy & (new_pos - start + 1 < budget)
        return (nc1, ring, h, src, n_hard, easy, hard, pred, new_tok,
                new_pos, new_active, conf)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5))
def _admit_stage1(c1_pool, tok, pos, active, start, budget, logits0, c1_rows,
                  slots, position, budgets):
    """One-dispatch stage-1 side of a chunked admission: greedy first tokens
    from the chunk's prefill logits (k, V), the chunk's stage-1 cache rows
    into their slots' pool rows, and the slots' lanes (next token, position,
    per-request token budget; active iff the budget leaves decode tokens).
    ``slots`` is the (k,) slot-index vector; ``position`` the shared prompt
    length. Donated pools; returns the first tokens (k,) on device (one
    host sync per chunk, not per request)."""
    tok0 = jnp.argmax(logits0, axis=-1).astype(jnp.int32)      # (k,)

    def ax0(p, s):
        return p.at[slots].set(s.astype(p.dtype))

    def ax1(p, s):
        return p.at[:, slots].set(s.astype(p.dtype))

    return (_seg_map2(ax0, ax1, c1_pool, c1_rows),
            tok.at[slots, 0].set(tok0), pos.at[slots].set(position),
            active.at[slots].set(budgets > 1),
            start.at[slots].set(position), budget.at[slots].set(budgets),
            tok0)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _unpark_lanes(tok, pos, active, start, budget, ids, s2_tok):
    """Apply a stage-2 bucket to the lanes: each valid id's next token is
    the bucket's greedy token, its position advances past the consumed one,
    and it re-activates unless its token budget is now spent (flush ids -1
    drop)."""
    n = tok.shape[0]
    safe = jnp.where(ids >= 0, ids, n)
    tok = tok.at[safe].set(s2_tok[:, None], mode="drop")
    pos = pos.at[safe].add(1, mode="drop")
    live = jnp.take(pos, safe, mode="clip") - jnp.take(start, safe,
                                                       mode="clip") + 1 \
        < jnp.take(budget, safe, mode="clip")
    active = active.at[safe].set(live, mode="drop")
    return tok, pos, active


@jax.jit
def _greedy_row(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# device-resident page allocator: one int32 free-list lane whose prefix
# [0, n_free) holds the free page ids (page 0 is the NULL page and is never
# allocated). alloc slices the tail of the free prefix into a null-padded
# block-table row WITHOUT touching the lane (the host n_free cursor is the
# only mutation, so a failed admission needs no device rollback); free
# compacts a row's live pages back onto the prefix end. Both are O(row)
# jitted programs — no host loop over pages.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_pages",))
def _alloc_row(lane, n_free, count, *, max_pages: int):
    """Pop ``count`` pages off the free prefix's tail (lane[n_free-count :
    n_free]) into a (max_pages,) block-table row, null-padded past
    ``count``. Pure: the lane is read, never written."""
    j = jnp.arange(max_pages, dtype=jnp.int32)
    idx = jnp.clip(n_free - count + j, 0, lane.shape[0] - 1)
    return jnp.where(j < count, jnp.take(lane, idx), 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_pages",))
def _alloc_rows(lane, n_free, counts, *, max_pages: int):
    """Batched ``_alloc_row``: pop ``counts[i]`` pages per row off the free
    prefix's tail, LIFO in row order — row i reads the same lane slice the
    i-th sequential ``_alloc_row`` call would, so one dispatch admits a
    whole chunk. Pure like ``_alloc_row``."""
    starts = n_free - jnp.cumsum(counts)
    j = jnp.arange(max_pages, dtype=jnp.int32)[None, :]
    idx = jnp.clip(starts[:, None] + j, 0, lane.shape[0] - 1)
    return jnp.where(j < counts[:, None], jnp.take(lane, idx),
                     0).astype(jnp.int32)


@functools.partial(jax.jit, donate_argnums=(0,))
def _free_row(lane, n_free, bt_row):
    """Return a block-table row's live pages (entries > 0) to the free
    prefix: cumsum-compacted onto positions [n_free, n_free+count); null
    entries scatter out of bounds and drop. Donated — the lane is updated
    in place."""
    valid = bt_row > 0
    pos = jnp.cumsum(valid.astype(jnp.int32)) - 1
    dst = jnp.where(valid, n_free + pos, lane.shape[0])
    return lane.at[dst].set(bt_row, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0, 2))
def _free_slot_row(lane, n_free, rows, slot):
    """Free-on-finish as ONE program: read slot ``slot``'s block-table row
    out of the (n_slots, max_pages) lane, compact its live pages onto the
    free prefix, and zero the row. Fusing the gather + free + clear keeps
    the per-finish cost at a single jitted dispatch (three eager ops here
    dominated the paged tick in profiles)."""
    bt_row = rows[slot]
    valid = bt_row > 0
    pos = jnp.cumsum(valid.astype(jnp.int32)) - 1
    dst = jnp.where(valid, n_free + pos, lane.shape[0])
    return (lane.at[dst].set(bt_row, mode="drop"),
            rows.at[slot].set(0))


class PageAllocator:
    """Free-list allocator over a shared KV page pool. ``n_pages`` counts
    ALLOCATABLE pages — ids 1..n_pages; the pool arrays hold one extra page
    at index 0, the all-zeros NULL page every padded block-table entry
    points at. The free set lives on device (the lane) with a host-side
    ``n_free`` cursor; allocation order is LIFO, which keeps recently-freed
    (cache-warm) pages hot.

    ``snapshot``/``restore`` give live migration an exact state capture:
    the snapshot DEFENSIVELY COPIES the lane (``_free_row`` donates it, so
    an aliased snapshot would be invalidated by the next free), and restore
    copies again so one snapshot survives multiple rollbacks."""

    def __init__(self, n_pages: int, page_size: int, ex=None):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        lane = jnp.arange(1, n_pages + 1, dtype=jnp.int32)
        self._lane = ex.place_io(lane) if ex is not None else lane
        self.n_free = n_pages

    @staticmethod
    def pages_for(span: int, page_size: int) -> int:
        """Pages needed to hold a ``span``-token cache row (>= 1: even an
        empty row owns its tail page)."""
        return max(1, -(-int(span) // int(page_size)))

    @property
    def n_in_use(self) -> int:
        return self.n_pages - self.n_free

    def alloc(self, count: int, *, max_pages: int) -> jnp.ndarray:
        """Allocate ``count`` pages as a null-padded (max_pages,) block-
        table row. The caller checks ``n_free`` first — admission
        backpressure is a policy decision, not an exception path."""
        if count > max_pages:
            raise ValueError(f"request needs {count} pages but a block "
                             f"table row holds {max_pages}")
        if count > self.n_free:
            raise RuntimeError(f"page pool exhausted: need {count}, "
                               f"free {self.n_free}/{self.n_pages}")
        row = _alloc_row(self._lane, self.n_free, count,
                         max_pages=max_pages)
        self.n_free -= count
        return row

    def alloc_many(self, counts: List[int], *, max_pages: int):
        """Allocate a chunk of block-table rows in ONE dispatch; row i gets
        ``counts[i]`` pages, identical page ids to ``counts[i]`` sequential
        ``alloc`` calls. Returns a (k, max_pages) i32 array."""
        if any(c > max_pages for c in counts):
            raise ValueError(f"request needs {max(counts)} pages but a "
                             f"block table row holds {max_pages}")
        total = sum(counts)
        if total > self.n_free:
            raise RuntimeError(f"page pool exhausted: need {total}, "
                               f"free {self.n_free}/{self.n_pages}")
        rows = _alloc_rows(self._lane, self.n_free,
                           jnp.asarray(counts, jnp.int32),
                           max_pages=max_pages)
        self.n_free -= total
        return rows

    def free(self, bt_row, count: int) -> None:
        """Return a block-table row's ``count`` live pages to the free
        list."""
        self._lane = _free_row(self._lane, self.n_free, bt_row)
        self.n_free += count

    def free_slot(self, rows, slot: int, count: int):
        """Free slot ``slot``'s pages straight out of the (n_slots, M)
        block-table lane and zero its row, one fused dispatch; returns the
        updated lane (``rows`` is donated)."""
        self._lane, rows = _free_slot_row(self._lane, self.n_free, rows,
                                          slot)
        self.n_free += count
        return rows

    def snapshot(self):
        return jnp.array(self._lane, copy=True), self.n_free

    def restore(self, snap) -> None:
        lane, n_free = snap
        self._lane = jnp.array(lane, copy=True)
        self.n_free = int(n_free)

    def relayout(self, place_fn) -> None:
        """Re-place the lane onto a new submesh (live migration's device
        re-split)."""
        self._lane = place_fn(self._lane)


# ---------------------------------------------------------------------------
# the continuous slot scheduler
# ---------------------------------------------------------------------------

_FREE, _ACTIVE, _PARKED = 0, 1, 2


class ContinuousScheduler:
    """Continuous-batching two-stage EE decode over a fixed slot pool.

    ``fns`` is a ``runtime.serve_loop.DecodeFns`` (duck-typed: anything with
    ``prefill``/``split``/``s1_raw``/``s2`` works — property tests drive the
    policy with toy stage callables). All admitted requests must satisfy
    ``len(prompt) + n_tokens <= max_len`` (the pool's shared cache width).

    Under a disaggregated ``placement`` the slot lanes, pooled stage-1 cache
    and the pool tick live on ``ex1``; the stage-2 row store, the ring and
    the bucketed vector-step ``stage2_decode`` dispatches on ``ex2``. The
    hard slab + step lane hop ex1 -> ex2 at enqueue and each bucket's greedy
    tokens hop ex2 -> ex1 at unpark — ``jax.device_put`` transfers, never
    the host.

    ``results`` maps sample id -> list of emitted greedy tokens (stream
    order). Latency is recorded per request in ``stats``.

    **Control surface** (the drift controller's actuators —
    ``runtime/controller.py``): ``set_c_thr`` re-aims the exit threshold
    (traced arg, never recompiles), ``set_eager_drain_below`` adapts the
    partial-bucket drain policy, ``set_active_cap`` bounds live slot
    occupancy (admission-side, so shrink happens by attrition — no slot is
    ever preempted), and ``request_capacity`` schedules a bucket re-size
    that applies only at a DISCRETE re-plan point (empty ring), the one
    actuation allowed to recompile. With no controller attached every
    control field keeps its constructor value and the hot loop is
    byte-for-byte the uncontrolled one.
    """

    def __init__(self, fns, sc: ServeConfig, *, n_slots: int, max_len: int,
                 placement: Optional[StagePlacement] = None, clock=None,
                 eager_drain_below: Optional[int] = None,
                 fns_factory: Optional[Callable] = None,
                 n_pages: Optional[int] = None, events=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.fns = fns
        # optional request-lifecycle feed (telemetry.EventLog): when set,
        # the scheduler emits submit/admit/park/bucket/finish/tick events
        # the observability plane (runtime/observe.Tracer) assembles into
        # per-request span trees. None (the default) costs the hot path
        # one attribute check per emission site.
        self.events = events
        # fns_factory(placement) -> DecodeFns rebuilds the stage callables
        # against a NEW placement (re-slicing params per ee.split_params
        # onto its submeshes) — the hook live migration needs to perform a
        # full chip re-split rather than only a capacity change
        self.fns_factory = fns_factory
        self.sc = sc
        self.n_slots = n_slots
        self.max_len = max_len
        self.c_thr = float(sc.c_thr)
        self.controller = None               # attached via controller.attach
        self.active_cap = n_slots            # live-slot occupancy cap
        self._pending_capacity: Optional[int] = None
        self._pending_migration = None       # armed via request_migration
        self._admission_open = True          # closed during QUIESCE
        # starvation-aware dispatch: a pool tick costs the same whether 2 or
        # n_slots rows are active, so once the ACTIVE count dips below this
        # threshold a partial bucket is worth its flush padding — stage-2
        # bubbles are cheaper than stage-1 ticks over a starved pool. The
        # default (bucket capacity) keeps at least a bucket's worth of slots
        # decoding; 0 recovers pure full-bucket dispatch (maximum fill,
        # maximum parking latency).
        self.eager_drain_below = (sc.capacity if eager_drain_below is None
                                  else eager_drain_below)
        self.placement = placement or StagePlacement.single_device()
        self.ex1, self.ex2 = self.placement.ex1, self.placement.ex2
        self.clock = clock or Clock()
        self.stats = ServeStats()
        self.stats.record_placement(self.placement)
        self.ring = RingQueue(sc, self.ex2, self.stats)
        # paged KV-cache mode: on iff the stage fns carry the paged decode
        # surface (page_size + s2_paged + pool_init + admit_pages —
        # serve_loop.decode_stage_fns(page_size=...)). The stage-2 row
        # store becomes a shared PAGE POOL + a per-slot block-table lane
        # (self._rows, reused verbatim as the ring payload's "cache" lane:
        # a hop ships page INDICES, never cache rows), and capacity is
        # measured in pages — ``n_pages`` allocatable pages (default: full
        # dense equivalence, n_slots * max_len/page_size).
        self.page_size = getattr(fns, "page_size", None)
        self._paged = (self.page_size is not None
                       and getattr(fns, "s2_paged", None) is not None)
        self._pool = None                    # the paged stage-2 page pool
        self._alloc: Optional[PageAllocator] = None
        if self._paged:
            if max_len % self.page_size != 0:
                raise ValueError(
                    f"max_len={max_len} must be a multiple of page_size="
                    f"{self.page_size} (paged/dense bitwise parity needs "
                    f"the gathered span == max_len)")
            self.max_pages = max_len // self.page_size
            self.n_pages = (int(n_pages) if n_pages is not None
                            else n_slots * self.max_pages)
            self._alloc = PageAllocator(self.n_pages, self.page_size,
                                        ex=self.ex2)
            self.stats.cache_pages_total = self.n_pages
            self.stats.cache_page_size = self.page_size
        elif n_pages is not None:
            raise ValueError("n_pages given but fns carry no paged decode "
                             "surface (decode_stage_fns(page_size=...))")
        # the transport-agnostic admission queue (runtime/serve_api.py):
        # owns FIFO order, the queued-sid set, submit-side validation and
        # the revocation primitive fleet preemption uses
        self.queue: RequestQueue = RequestQueue(
            max_len=max_len, is_dup=lambda sid: sid in self.results)
        self.results: Dict[int, List[int]] = {}
        # host-side slot metadata
        self._sid = [-1] * n_slots
        self._emitted = [0] * n_slots
        self._budget = [0] * n_slots
        self._state = [_FREE] * n_slots
        # paged bookkeeping: pages owned / prompt length per slot (live
        # cache tokens = prompt + emitted - 1 — the fragmentation gauge)
        self._slot_pages = [0] * n_slots
        self._slot_len = [0] * n_slots
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self.peak_busy = 0
        # per-slot hardness tally (hard decisions / decisions of the
        # resident request) and the per-request finish feed: (sid, n_hard,
        # n_decisions) tuples appended at finish — the router's tenant-
        # difficulty signal. Bounded like every other stats series; a
        # standalone scheduler that never drains it just keeps the recent
        # window.
        self._slot_hard = [0] * n_slots
        self._slot_dec = [0] * n_slots
        self._finished: Deque = deque(maxlen=_SERIES_CAP)
        # parked slots in ring order (the compaction is contractually
        # stable, so ascending slot order per tick IS enqueue order) — lets
        # bucket results be harvested lazily: state transitions happen at
        # dispatch, token values land under a bounded pending window
        self._parked_fifo: Deque[int] = deque()
        self._pending: Deque = deque()
        # fused-tick ring row spec, derived abstractly at pool build; None
        # means the stage fns resisted eval_shape and ticks stay composed
        self._ring_row_spec = None
        # device-side pool state (lazy: shapes come from the first
        # admission); lanes: next token, position, active/start/budget
        self._c1 = None
        self._rows = None
        self._tok = None
        self._pos = None
        self._active_lane = None
        self._start_lane = None
        self._budget_lane = None

    # -- control surface (drift-controller actuators) ------------------------

    def set_c_thr(self, c_thr: float) -> None:
        """Re-aim the exit threshold from the next tick on. ``c_thr`` is a
        traced argument of the pool tick, so this never recompiles."""
        self.c_thr = float(c_thr)

    def set_eager_drain_below(self, k: int) -> None:
        """Adapt the starvation-aware partial-drain policy: dispatch a
        partial bucket once the live count dips below ``k`` (0 = pure
        full-bucket dispatch)."""
        self.eager_drain_below = max(0, int(k))

    def set_active_cap(self, cap: int) -> None:
        """Bound live slot occupancy. Admission-side: a shrink takes effect
        by attrition (busy slots finish and are not backfilled), never by
        preempting an in-flight request. Clamped to [1, n_slots] so the
        pool always makes progress."""
        self.active_cap = max(1, min(int(cap), self.n_slots))

    def request_capacity(self, capacity: int) -> None:
        """Schedule a stage-2 bucket-capacity re-size (the re-plan
        actuator's apply path). Deferred to the next DISCRETE re-plan
        point — an empty ring — where no in-flight row's home can change
        shape under it; the resized ``ring_drain`` is the one steady-state
        recompile the controller is allowed to cause."""
        self._pending_capacity = max(1, min(int(capacity), self.n_slots))

    def _maybe_apply_capacity(self) -> None:
        if self._pending_capacity is None or self.ring.count > 0:
            return
        cap, self._pending_capacity = self._pending_capacity, None
        if cap == self.sc.capacity:
            return
        # fresh config + ring at the new capacity (the caller's ServeConfig
        # is never mutated); the buffer re-allocates lazily on next enqueue
        self.sc = ServeConfig(capacity=cap, queue_depth=self.sc.queue_depth,
                              c_thr=self.sc.c_thr,
                              max_pending=self.sc.max_pending,
                              harvest_timeout_s=self.sc.harvest_timeout_s)
        self.ring = RingQueue(self.sc, self.ex2, self.stats)

    def request_migration(self, plan) -> None:
        """Arm a live migration (a ``runtime.migration.MigrationPlan``).
        Like ``request_capacity`` it defers to a discrete point — the top
        of the next loop iteration — where the migrator quiesces, snapshots,
        re-places and resumes the pool; on failure it rolls back and
        serving continues on the old placement. Arming again before the
        previous plan ran replaces it (last writer wins)."""
        self._pending_migration = plan

    def _maybe_migrate(self) -> None:
        if self._pending_migration is None:
            return
        plan, self._pending_migration = self._pending_migration, None
        # lazy import: migration.py drives this scheduler (not vice versa)
        from repro.runtime.migration import LiveMigrator, MigrationError
        try:
            LiveMigrator(self, plan).run()
        except MigrationError:
            # the migrator already rolled back to the pre-migration
            # placement and re-opened admission; serving continues there.
            # The attempt is visible in stats.n_migration_rollbacks and the
            # fault log — nothing to re-raise: a failed RE-PLAN must not
            # kill a healthy server.
            pass

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue one request (arrival order = queue order; arrival_time
        gates admissibility against the scheduler clock). Validation —
        the shared ``serve_api.validate_request`` surface — happens at
        the queue's push, so a malformed request is rejected before it
        can damage in-flight state mid-admission."""
        self.queue.append(req)
        if self.events is not None:
            self.events.emit("submit", sid=req.sample_id,
                             arrival=req.arrival_time,
                             n_tokens=req.n_tokens)

    def _ensure_pool(self, c1_row, rows_row) -> None:
        if self._c1 is not None:
            return
        self._c1 = seg_pool_like(c1_row, self.n_slots)
        if self._paged:
            # the slot-major store is the BLOCK-TABLE lane (zero rows =
            # all-null tables); the actual cache bytes live in one shared
            # page pool (+1 page: the NULL page at index 0)
            self._rows = self.ex2.place_io(
                jnp.zeros((self.n_slots, self.max_pages), jnp.int32))
            self._pool = self.ex2.place_io(
                self.fns.pool_init(rows_row, self.n_pages + 1))
            self.stats.cache_hbm_bytes = sum(
                leaf.nbytes for leaf in jax.tree.leaves(self._pool))
        else:
            self._rows = self.ex2.place_io(
                jax.tree.map(lambda x: jnp.zeros(
                    (self.n_slots,) + x.shape[1:], x.dtype), rows_row))
            self.stats.cache_hbm_bytes = sum(
                leaf.nbytes for leaf in jax.tree.leaves(self._rows))
        self._tok = self.ex1.place_io(jnp.zeros((self.n_slots, 1), jnp.int32))
        self._pos = self.ex1.place_io(jnp.zeros((self.n_slots,), jnp.int32))
        self._active_lane = self.ex1.place_io(jnp.zeros((self.n_slots,),
                                                        bool))
        self._start_lane = self.ex1.place_io(jnp.zeros((self.n_slots,),
                                                       jnp.int32))
        self._budget_lane = self.ex1.place_io(jnp.zeros((self.n_slots,),
                                                        jnp.int32))
        # derive the fused tick's ring row spec without executing stage 1:
        # the ring must exist BEFORE the first fused launch (it is donated
        # through the tick), and its 'h' leaf shape is stage 1's output.
        # Duck-typed fns that resist abstract evaluation simply keep the
        # composed three-program tick.
        try:
            h_av, _, _ = jax.eval_shape(self.fns.s1_raw, self._tok,
                                        self._c1, self._pos)
            self._ring_row_spec = {
                "h": jax.ShapeDtypeStruct(h_av.shape[1:], h_av.dtype),
                "cache": jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                    self._rows),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
        except Exception:
            self._ring_row_spec = None

    def _admit_chunk(self, reqs: List[Request]) -> None:
        """Admit a chunk of requests sharing one prompt length with ONE
        batched prefill + one fused pool write — per-request admission cost
        is the classic continuous-batching tax, and chunking it is what
        keeps backfill from serializing the pipeline."""
        prompts = np.stack([np.asarray(r.prompt, np.int32) for r in reqs])
        S = prompts.shape[1]
        for r in reqs:
            self.stats.n_samples += 1
            self.stats.record_submit(r.sample_id, r.arrival_time)
        logits0, caches = self.fns.prefill(
            self.ex1.place_io(jnp.asarray(prompts)), self.max_len)
        c1_rows, rows_rows = self.fns.split(caches)
        self._ensure_pool(c1_rows, rows_rows)
        slots = [self._free.pop() for _ in reqs]
        slots_dev = jnp.asarray(slots, jnp.int32)
        budgets = jnp.asarray([r.n_tokens for r in reqs], jnp.int32)
        (self._c1, self._tok, self._pos, self._active_lane,
         self._start_lane, self._budget_lane, tok0) = _admit_stage1(
            self._c1, self._tok, self._pos, self._active_lane,
            self._start_lane, self._budget_lane, logits0, c1_rows,
            self.ex1.place_io(slots_dev), S, self.ex1.place_io(budgets))
        if self._paged:
            # alloc-on-admit: one block-table row per request (the page-
            # budget admission check in _try_admit guarantees the free
            # list covers the chunk), then ONE fused pool scatter moves
            # the chunk's prefill cache rows into their pages
            needs = []
            for r, slot in zip(reqs, slots):
                need = PageAllocator.pages_for(S + r.n_tokens - 1,
                                               self.page_size)
                needs.append(need)
                self._slot_pages[slot] = need
                self._slot_len[slot] = S
            bt_rows = self.ex2.place_io(
                self._alloc.alloc_many(needs, max_pages=self.max_pages))
            self._pool = self.fns.admit_pages(
                self._pool, self.ex2.place_io(rows_rows), bt_rows)
            self._rows = _scatter_rows(self._rows, bt_rows,
                                       self.ex2.place_io(slots_dev))
        else:
            self._rows = _scatter_rows(self._rows,
                                       self.ex2.place_io(rows_rows),
                                       self.ex2.place_io(slots_dev))
        tok0_np = np.asarray(tok0)           # one admission sync per chunk
        for j, (r, slot) in enumerate(zip(reqs, slots)):
            self.results[r.sample_id] = [int(tok0_np[j])]
            self._sid[slot] = r.sample_id
            self._emitted[slot] = 1
            self._budget[slot] = r.n_tokens
            self._state[slot] = _ACTIVE
            self._slot_hard[slot] = 0
            self._slot_dec[slot] = 0
            if self.events is not None:
                self.events.emit("admit", sid=r.sample_id, slot=slot,
                                 prompt_len=S)
            if r.n_tokens == 1:              # prefill-only: free right away
                self._finish_slot(slot)
        self.peak_busy = max(self.peak_busy, self.n_slots - len(self._free))

    def _try_admit(self) -> None:
        """Admit admissible requests in arrival order, chunked to power-of-2
        batch sizes (bounded set of prefill shapes -> bounded compiles). A
        chunk is a same-prompt-length prefix of the admissible run, bounded
        by free slots AND the controller's live-occupancy cap."""
        if not self._admission_open:          # QUIESCE: migration in flight
            return
        while self._free and self.queue:
            busy = self.n_slots - len(self._free)
            headroom = min(len(self._free), self.active_cap - busy)
            if headroom <= 0:
                return
            now = self.clock.now()
            n_adm = 0
            pages_acc = 0
            S0 = len(self.queue[0].prompt)
            for r in self.queue:
                if (r.arrival_time > now or len(r.prompt) != S0
                        or n_adm >= headroom):
                    break
                if self._paged:
                    need = PageAllocator.pages_for(
                        len(r.prompt) + r.n_tokens - 1, self.page_size)
                    if need > self.n_pages:
                        raise ValueError(
                            f"request {r.sample_id} needs {need} pages but "
                            f"the pool holds {self.n_pages} total — it can "
                            "never be admitted")
                    if pages_acc + need > self._alloc.n_free:
                        # free-list empty(ish): admission BACKPRESSURE, not
                        # a drop — the head request waits for pages to be
                        # freed by finishing slots (attrition)
                        break
                    pages_acc += need
                n_adm += 1
            if n_adm == 0:
                return
            k = 1 << (n_adm.bit_length() - 1)     # largest power of 2 <= n
            self._admit_chunk([self.queue.popleft() for _ in range(k)])

    # -- emission / completion ----------------------------------------------

    def _finish_slot(self, slot: int) -> None:
        """Free a slot whose request just emitted its last token, stamp
        the request's finish time, and append it to the finish feed (sid +
        its realized hardness tally — what ``drain_finished`` hands the
        router)."""
        sid = self._sid[slot]
        self._state[slot] = _FREE
        self._sid[slot] = -1
        self._free.append(slot)
        if self._paged and self._slot_pages[slot] > 0:
            # free-on-finish: the slot's pages go back on the free list and
            # its device block-table row is zeroed — a later flush clone of
            # this row must never let stage 2 append into recycled pages
            self._rows = self._alloc.free_slot(self._rows, slot,
                                               self._slot_pages[slot])
            self._slot_pages[slot] = 0
            self._slot_len[slot] = 0
        self.stats.record_finish(sid, self.clock.now())
        self._finished.append((sid, self._slot_hard[slot],
                               self._slot_dec[slot]))
        if self.events is not None:
            self.events.emit("finish", sid=sid,
                             n_hard=self._slot_hard[slot],
                             n_decisions=self._slot_dec[slot])

    def _advance_slot(self, slot: int) -> None:
        """One token emitted for this slot: finish when the budget is
        spent, else back to ACTIVE — the one completion rule both the easy
        (tick) and hard (bucket) paths share."""
        self._emitted[slot] += 1
        if self._emitted[slot] >= self._budget[slot]:
            self._finish_slot(slot)
        else:
            self._state[slot] = _ACTIVE

    def _emit(self, slot: int, token: int) -> None:
        self.results[self._sid[slot]].append(token)
        self._advance_slot(slot)

    # -- stage 2 dispatch ----------------------------------------------------

    def _dispatch_bucket(self) -> None:
        # the injection boundary sits BEFORE the pop — a retried dispatch
        # re-runs from an unmutated ring, so transient faults are safe to
        # absorb with faults.retry at every call site
        faults.fault_point("dispatch")
        popped = self.ring.pop()
        if popped is None:
            return
        bucket, ids, take = popped
        with observe.annotate("stage2_bucket_dispatch"):
            if self._paged:
                # paged stage 2: the bucket's "cache" lane carries block-
                # table rows (page indices — the whole ring hop is index-
                # sized). Flush lanes (id -1) cloned a live slot's bt row
                # out of the ring slab; sanitize them to the NULL table +
                # sentinel step so the shared pool is never appended
                # through a discarded row. The pool is donated through
                # s2_paged and comes back updated — no scatter-back (pages
                # are shared state, not slot rows).
                from repro.runtime.serve_loop import _sanitize_paged_bucket
                bt_safe, step_safe = _sanitize_paged_bucket(
                    bucket["cache"], ids, bucket["step"],
                    sentinel=self.max_len)
                logits, self._pool = self.fns.s2_paged(
                    bucket["h"], bt_safe, step_safe, self._pool)
            else:
                logits, new_rows = self.fns.s2(bucket["h"], bucket["cache"],
                                               bucket["step"])
                self._rows = _scatter_rows(self._rows, new_rows, ids)
        toks = _greedy_row(logits)
        # ex2 -> ex1 hop: greedy tokens come home to the slot lanes
        self._tok, self._pos, self._active_lane = _unpark_lanes(
            self._tok, self._pos, self._active_lane, self._start_lane,
            self._budget_lane, self.ex1.place_io(ids),
            self.ex1.place_io(toks))
        # host state transitions AND finish stamps NOW (the popped slots
        # are the FIFO head — no device sync needed; the next tick's sync
        # forces this bucket's compute within one window, so dispatch-time
        # stamps match the easy path's tick-time stamps); token VALUES land
        # at harvest, bounded by max_pending like the sync servers'
        # backlogs
        entries = []
        popped_slots = []
        for _ in range(take):
            slot = self._parked_fifo.popleft()
            sid = self._sid[slot]
            entries.append((sid, len(self.results[sid])))
            self.results[sid].append(None)       # filled at harvest
            popped_slots.append(slot)
        if self.events is not None:
            # bucket BEFORE the advance: a request finishing off this
            # bucket must close its stage-2 park span first
            self.events.emit("bucket",
                             sids=tuple(self._sid[s] for s in popped_slots),
                             take=take, capacity=self.sc.capacity)
        for slot in popped_slots:
            self._advance_slot(slot)
        self._pending.append((entries, toks))
        while len(self._pending) > self.sc.max_pending:
            self._harvest_one()

    def _harvest_one(self) -> None:
        entries, toks = self._pending.popleft()
        # bounded wait: a bucket whose device result never resolves raises
        # HarvestTimeout instead of blocking np.asarray forever — the
        # entries go back on the pending deque so a caller that survives
        # the error (or a later retry) still harvests every token
        try:
            bounded_wait(toks, self.sc.harvest_timeout_s,
                         what=f"stage-2 bucket ({len(entries)} tokens)")
        except HarvestTimeout:
            self._pending.appendleft((entries, toks))
            raise
        toks_np = np.asarray(toks)
        for j, (sid, idx) in enumerate(entries):
            self.results[sid][idx] = int(toks_np[j])

    # -- the tick ------------------------------------------------------------

    def _use_fused(self) -> bool:
        """The single-launch fused tick applies when stage 1, the ring and
        the stage-2 store share one submesh (degenerate placement) and the
        ring row spec could be derived abstractly. A migration onto a
        disaggregated placement flips this off mid-serve (and back)."""
        return (self._ring_row_spec is not None
                and not self.placement.disaggregated)

    def _tick(self) -> None:
        if self._use_fused():
            self._tick_fused()
        else:
            self._tick_composed()

    def _finish_tick(self, n_hard_dev, easy, hard, pred, conf):
        """The one per-tick host sync: n_hard (control flow) + the easy/
        hard masks, emitted tokens and confidences (results + the
        controller's reservoir feed), fetched together. Emits easy tokens
        and feeds the controller; returns the host-side pieces the hard
        path needs."""
        with observe.annotate("finish_tick_sync"):
            n_hard, easy_np, hard_np, emit_np, conf_np = jax.device_get(
                (n_hard_dev, easy, hard, pred, conf))
        n_hard = int(n_hard)
        n_dec = int(easy_np.sum()) + n_hard
        self.stats.record_decisions(n_dec, n_hard)
        if self.events is not None:
            self.events.emit("tick", n_decisions=n_dec, n_hard=n_hard)
        if self.controller is not None:
            # SENSE: only live rows' confidences are real (free/parked rows
            # compute garbage that the masks discard)
            self.controller.on_tick(self, n_dec, n_hard,
                                    conf_np[easy_np | hard_np])
        for i in np.nonzero(easy_np)[0]:
            self._slot_dec[int(i)] += 1
            self._emit(int(i), int(emit_np[i]))
        return n_hard, hard_np

    def _park_hard(self, hard_np) -> None:
        parked = []
        for i in np.nonzero(hard_np)[0]:         # ascending = slab order
            self._slot_dec[int(i)] += 1
            self._slot_hard[int(i)] += 1
            self._state[int(i)] = _PARKED
            self._parked_fifo.append(int(i))
            parked.append(int(i))
        if self.events is not None and parked:
            # one batched event per tick (like "bucket"): the park feed is
            # hot-path, and per-row emits would dominate the event volume
            self.events.emit("park",
                             sids=tuple(self._sid[s] for s in parked),
                             slots=tuple(parked))

    def _tick_composed(self) -> None:
        with observe.annotate("pool_tick"):
            (self._c1, slab, slots, steps, n_hard_dev, easy, hard, pred,
             self._tok, self._pos, self._active_lane, conf) = _pool_tick(
                self._tok, self._c1, self._pos, self._active_lane,
                self._start_lane, self._budget_lane, self.c_thr,
                s1=self.fns.s1_raw, backend=dispatch.kernel_backend())
        n_hard, hard_np = self._finish_tick(n_hard_dev, easy, hard, pred,
                                            conf)
        if n_hard > 0:
            self._park_hard(hard_np)
            # ex1 -> ex2 hop: the id lane crosses first (the cache gather
            # runs ON ex2 — the store never leaves stage 2's submesh); the
            # hidden slab + step lane cross inside the enqueue's place_io
            slots2 = self.ex2.place_io(slots)
            cache_slab = _gather_rows(self._rows, slots2)
            # retried: the enqueue fault boundary sits before any ring
            # mutation, so a transient failure re-runs the whole enqueue
            with observe.annotate("ring_enqueue"):
                faults.retry(self.ring.enqueue,
                             {"h": slab, "cache": cache_slab, "step": steps},
                             slots2, n_hard, self._dispatch_bucket,
                             what="ring-enqueue")

    def _tick_fused(self) -> None:
        ring_buf = self.ring.ensure(self._ring_row_spec)
        with observe.annotate("pool_tick_fused"):
            (self._c1, ring_buf, h, src, n_hard_dev, easy, hard, pred,
             self._tok, self._pos, self._active_lane,
             conf) = _pool_tick_fused(
                self._tok, self._c1, self._pos, self._active_lane,
                self._start_lane, self._budget_lane, ring_buf, self._rows,
                self.c_thr, s1=self.fns.s1_raw,
                backend=dispatch.kernel_backend())
        self.ring.put_buf(ring_buf)
        n_hard, hard_np = self._finish_tick(n_hard_dev, easy, hard, pred,
                                            conf)
        if n_hard > 0:
            # the enqueue happened IN the tick; its fault boundary fires
            # here (same visit cadence as the composed path — once per
            # hard tick). A transient fault is absorbed by the retry with
            # the device ring already consistent; only the host mirror
            # below was still pending.
            faults.retry(faults.fault_point, "enqueue", what="ring-enqueue")
            n_enq = min(n_hard, self.ring.size - self.ring.count)
            self.ring.note_enqueued(n_enq)
            self._park_hard(hard_np)
            if n_enq < n_hard:
                # overflow: the ring filled mid-batch. Re-materialize the
                # still-pending slab rows from src (hard rows' pos did not
                # advance, so the live lanes are still decision-time
                # steps) and push them through the composed backpressure
                # chain — stall/drain ordering and n_stalls match the
                # composed path exactly.
                slab = _gather_rows({"h": h, "cache": self._rows,
                                     "step": self._pos}, src)
                self.ring.enqueue(slab, src, n_hard, self._dispatch_bucket,
                                  off=n_enq, fire_fault=False)

    # -- the loop ------------------------------------------------------------

    def _n_state(self, state: int) -> int:
        return sum(1 for s in self._state if s == state)

    def _refresh_page_stats(self) -> None:
        """Fold the allocator's view + the host token tallies into the v3
        stats fields (once per scheduler iteration — the gauges are cheap
        host arithmetic)."""
        if not self._paged:
            return
        self.stats.cache_pages_in_use = self._alloc.n_in_use
        self.stats.live_tokens = sum(
            self._slot_len[i] + self._emitted[i] - 1
            for i in range(self.n_slots) if self._state[i] != _FREE)

    # -- ReplicaHandle introspection (serve_api.py) --------------------------

    @property
    def n_busy(self) -> int:
        """Slots holding an in-flight request (ACTIVE or PARKED) — the
        live-occupancy half of the router's load signal."""
        return self.n_slots - len(self._free)

    @property
    def queue_len(self) -> int:
        """Unadmitted requests awaiting a slot — the queue-depth half."""
        return len(self.queue)

    def next_arrival(self) -> Optional[float]:
        return self.queue.next_arrival()

    def revoke_queued(self, sample_ids=None) -> List[Request]:
        """Remove and return UNADMITTED queued requests (None = all) —
        the fleet preemption / degrade-redistribution primitive. Admitted
        requests are untouched, so a revoked request has never emitted a
        token and re-queueing it elsewhere preserves its stream."""
        return self.queue.revoke(sample_ids)

    def drain_finished(self) -> List:
        """Pop the per-request finish feed accumulated since the last
        call: (sample_id, n_hard_decisions, n_decisions) per finished
        request — the realized per-request hardness the router folds into
        its tenant difficulty estimates."""
        out = list(self._finished)
        self._finished.clear()
        return out

    def step(self) -> str:
        """ONE scheduler iteration — the replica state machine the fleet
        router (and ``drain``) drives. Admits what is admissible, then
        either ticks the pool (easy slots advance, full buckets dispatch
        eagerly, partial buckets under the starvation policy) or forces a
        partial bucket when every busy slot is parked — the HAPI-style
        staged policy, one iteration at a time.

        Returns ``"busy"`` (progressed), ``"waiting"`` (queued work whose
        arrival_time is still in the future — the caller owns the clock
        and should advance it toward ``next_arrival()``), or ``"idle"``
        (queue and pool fully drained; deferred token values may still be
        pending — ``drain`` harvests them)."""
        self._maybe_migrate()                # discrete re-plan points only
        self._maybe_apply_capacity()
        self._try_admit()
        self._refresh_page_stats()
        if self._n_state(_ACTIVE) > 0:
            self._tick()
            while self.ring.count >= self.sc.capacity:
                faults.retry(self._dispatch_bucket, what="full-drain")
            # starved pool: partial buckets beat idle stage-1 width
            while (self.ring.count > 0
                   and self._n_state(_ACTIVE) < self.eager_drain_below):
                faults.retry(self._dispatch_bucket, what="eager-drain")
            return "busy"
        if self.ring.count > 0:
            # forced partial: all parked
            faults.retry(self._dispatch_bucket, what="forced-drain")
            return "busy"
        if self.queue:
            if not self._free:               # full pool, all parked, empty
                raise AssertionError("scheduler wedged: parked slots "
                                     "with an empty ring")
            return "waiting"
        return "idle"

    def drain(self) -> Dict[int, List[int]]:
        """Drive ``step`` until the queue and every slot drain (advancing
        the clock over idle gaps), then harvest every deferred token
        value. Idempotent: a drained scheduler returns its results."""
        while True:
            state = self.step()
            if state == "waiting":
                self.clock.advance_to(self.queue.next_arrival())
            elif state == "idle":
                break
        while self._pending:                 # final harvest: fill the
            self._harvest_one()              # deferred token values
        assert self._n_state(_FREE) == self.n_slots, \
            "scheduler drained with busy slots"
        return self.results

    def run(self) -> Dict[int, List[int]]:
        """Drive the pool until the queue and every slot drain — the
        standalone entry point (``drain`` under its original name)."""
        return self.drain()


# ---------------------------------------------------------------------------
# the degenerate sync policy: static batch formation over a step-synchronous
# server's generate()
# ---------------------------------------------------------------------------

class SyncScheduler:
    """Batch-formation wrapper over a step-synchronous decode server
    (``DecodeServer`` or ``HostLoopDecoder``): admit requests in arrival
    order into static batches of ``n_slots``, wait for the batch's last
    arrival, run ``generate`` to the batch's *longest* request (lockstep:
    finished samples ride along until the whole batch completes — the
    utilization gap continuous batching removes), truncate per request.
    Prompts within a batch must share one length. A partial tail batch
    runs at its own (smaller) shape — one extra compile, but the stats
    (realized q, decisions, occupancy) count only real traffic, never
    padding rows.

    Implements the same ``ReplicaHandle`` surface (``serve_api.py``) as
    the continuous scheduler — shared submit-side validation (``max_len``
    bounds requests only when given: the static-batch regime has no
    pooled cache width), one ``step`` per static batch, the finish feed,
    revocation — so a fleet router can mix sync and continuous replicas.
    ``request_capacity`` re-sizes the server's stage-2 bucket at the next
    batch boundary (always a shape-change-safe point: nothing is in
    flight between generates); ``request_migration`` raises — the sync
    policy has no live pool to migrate (use the continuous scheduler)."""

    def __init__(self, server, n_slots: int, clock=None,
                 max_len: Optional[int] = None, events=None):
        self.server = server
        self.n_slots = n_slots
        self.max_len = max_len
        self.events = events                 # see ContinuousScheduler
        self.clock = clock or Clock()
        self.queue: RequestQueue = RequestQueue(
            max_len=max_len, is_dup=lambda sid: sid in self.results)
        self.results: Dict[int, List[int]] = {}
        self.controller = None               # attached via controller.attach
        self._seen_decisions = 0
        self._seen_hard = 0
        self._busy_sids: set = set()         # admitted, mid-generate (empty
        self._finished: Deque = deque(maxlen=_SERIES_CAP)   # between steps)

    @property
    def stats(self) -> ServeStats:
        return self.server.stats

    def set_c_thr(self, c_thr: float) -> None:
        """Threshold actuation on the sync policy: batch granularity (the
        step-synchronous server re-reads its threshold per generate)."""
        self.server.set_c_thr(c_thr)

    def request_capacity(self, capacity: int) -> None:
        """Re-size the stage-2 bucket from the next static batch on —
        batch boundaries are always discrete re-plan points for the sync
        policy (no in-flight state between generates)."""
        cap = max(1, int(capacity))
        sc = self.server.sc
        if cap == sc.capacity:
            return
        new_sc = ServeConfig(capacity=cap, queue_depth=sc.queue_depth,
                             c_thr=sc.c_thr, max_pending=sc.max_pending,
                             harvest_timeout_s=sc.harvest_timeout_s)
        self.server.sc = new_sc
        self.server.ring = RingQueue(new_sc, self.server.ex2, self.stats)

    def request_migration(self, plan) -> None:
        raise NotImplementedError(
            "the sync policy has no live slot pool to migrate — live "
            "migration needs the continuous scheduler")

    # -- ReplicaHandle introspection -----------------------------------------

    @property
    def n_busy(self) -> int:
        return len(self._busy_sids)          # 0 between steps (lockstep)

    @property
    def queue_len(self) -> int:
        return len(self.queue)

    def next_arrival(self) -> Optional[float]:
        return self.queue.next_arrival()

    def revoke_queued(self, sample_ids=None) -> List[Request]:
        return self.queue.revoke(sample_ids)

    def drain_finished(self) -> List:
        """Pop the finish feed: (sid, n_hard, n_decisions) per finished
        request. The sync server tallies hardness per batch, not per row,
        so each request carries its batch's realized q scaled to its own
        decision count — an unbiased estimate at batch granularity."""
        out = list(self._finished)
        self._finished.clear()
        return out

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        if self.events is not None:
            self.events.emit("submit", sid=req.sample_id,
                             arrival=req.arrival_time,
                             n_tokens=req.n_tokens)

    def step(self) -> str:
        """Form and run ONE static batch (waiting for its last arrival —
        the sync policy's admission rule). Returns ``"busy"`` when a batch
        ran, ``"idle"`` when the queue is empty; never ``"waiting"`` (the
        batch wait IS the policy, so the clock advances internally)."""
        if not self.queue:
            return "idle"
        batch = [self.queue.popleft()
                 for _ in range(min(self.n_slots, len(self.queue)))]
        self._busy_sids = {r.sample_id for r in batch}
        self.clock.advance_to(max(r.arrival_time for r in batch))
        for r in batch:
            self.stats.record_submit(r.sample_id, r.arrival_time)
            if self.events is not None:
                self.events.emit("admit", sid=r.sample_id, slot=-1,
                                 prompt_len=len(r.prompt))
        prompts = [np.asarray(r.prompt, np.int32) for r in batch]
        n_max = max(r.n_tokens for r in batch)
        dec0, hard0 = self.stats.n_decisions, self.stats.n_stage2
        out = self.server.generate(np.stack(prompts), n_max)
        q_batch = ((self.stats.n_stage2 - hard0)
                   / max(self.stats.n_decisions - dec0, 1))
        t = self.clock.now()
        for i, r in enumerate(batch):
            self.results[r.sample_id] = [
                int(x) for x in out["tokens"][i, :r.n_tokens]]
            self.stats.record_finish(r.sample_id, t)
            n_dec = r.n_tokens - 1
            self._finished.append((r.sample_id, q_batch * n_dec, n_dec))
            if self.events is not None:
                self.events.emit("finish", sid=r.sample_id,
                                 n_decisions=n_dec)
        self._busy_sids = set()
        if self.controller is not None:
            # one controller visit per static batch (the sync policy's
            # natural actuation granularity); confidences arrive via
            # the server's conf sink, wired at attach
            st = self.stats
            n_dec = st.n_decisions - self._seen_decisions
            n_hard = st.n_stage2 - self._seen_hard
            self._seen_decisions = st.n_decisions
            self._seen_hard = st.n_stage2
            self.controller.on_tick(self, n_dec, n_hard, None)
        return "busy"

    def drain(self) -> Dict[int, List[int]]:
        while self.step() != "idle":
            pass
        return self.results

    def run(self) -> Dict[int, List[int]]:
        return self.drain()


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival times for ``n`` requests at
    ``rate`` (requests/second); ``rate`` <= 0 or inf means all at t=0."""
    if not np.isfinite(rate) or rate <= 0:
        return np.zeros(n)
    gaps = np.random.default_rng(seed).exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)
