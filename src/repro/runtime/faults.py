"""Deterministic fault injection for the serving runtime.

A serving stack is only fault-tolerant if its failure handling is
*executable on demand*: device loss, stuck transfers and mid-dispatch
errors are rare in CI exactly when they are common in production (the
dynamic-conditions gap the adaptive-inference survey pins on early-exit
systems). This module plants named **fault points** at the runtime's
dispatch / enqueue / transfer / migration-stage boundaries and arms them
from a seeded, fully deterministic ``FaultPlan`` — so every "what if the
3rd bucket dispatch dies?" scenario is a reproducible test case, not a
postmortem.

Fault points fire by *visit count*: the plan ``dispatch@3`` raises an
``InjectedFault`` on the third arrival at the ``dispatch`` point and never
again. Faults come in two kinds:

  * **fatal** (default) — models a hard failure. Callers either propagate
    it (a serving hot loop dies loudly, never hangs) or compensate (the
    migration state machine rolls back to the pre-migration placement);
  * **transient** (``dispatch@3#transient``) — models a retryable blip
    (a flaky transfer, a transiently wedged drain). ``retry`` wrappers at
    the drain / cross-stage ``device_put`` boundaries absorb these with
    exponential backoff, so the request stream never notices.

Activation:

  * ``REPRO_FAULT_PLAN`` environment variable — the ambient plan, parsed
    once on first use (the CI chaos job sweeps this across the
    scheduler/migration test suites);
  * ``install(plan)`` / ``clear()`` / ``installed(plan)`` — programmatic
    (tests); an installed plan shadows the ambient one, ``clear()``
    restores it.

Every injection, retry and survival is appended to a bounded structured
event log (``telemetry.EventLog``). When ``REPRO_FAULT_LOG`` names a
file, the log is flushed there as JSON lines at process exit — the CI
chaos job uploads it as the fault-sweep artifact.

With neither env var set and nothing installed, ``fault_point`` is a
single module-global ``None`` check — the hot loops pay nanoseconds.
"""
from __future__ import annotations

import atexit
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.telemetry import EventLog

ENV_PLAN = "REPRO_FAULT_PLAN"
ENV_LOG = "REPRO_FAULT_LOG"

FAULT_KINDS = ("fatal", "transient")

# the runtime's named fault points (kept here so seeded plan generation and
# the chaos sweep agree on the universe of injectable boundaries)
POINTS = ("dispatch", "enqueue", "transfer",
          "migrate:quiesce", "migrate:snapshot", "migrate:replace",
          "migrate:resume", "ckpt:leaf", "ckpt:precommit")

LOG = EventLog(cap=4096)


class InjectedFault(RuntimeError):
    """A fault raised by an armed fault point. ``transient`` marks it
    retryable — ``retry`` absorbs those; everything else must be
    propagated or compensated by the caller."""

    def __init__(self, point: str, *, transient: bool = False,
                 visit: int = 0):
        kind = "transient" if transient else "fatal"
        super().__init__(f"injected {kind} fault at '{point}' "
                         f"(visit {visit})")
        self.point = point
        self.transient = transient
        self.visit = visit


@dataclass
class FaultPlan:
    """Deterministic visit-count triggers: ``{point: [(nth, kind), ...]}``.
    Each trigger fires exactly once, on the nth arrival at its point
    (1-based). Counters live on the plan, so installing a fresh plan
    re-arms everything."""
    triggers: Dict[str, List[Tuple[int, str]]] = field(default_factory=dict)
    visits: Dict[str, int] = field(default_factory=dict, repr=False)
    origin: str = ""                 # the as-parsed spec (triggers mutate
                                     # as they fire; the log wants the
                                     # armed plan, not the residue)

    def __post_init__(self):
        if not self.origin:
            self.origin = self.spec()

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse ``"dispatch@3;transfer@2#transient"`` — entries separated
        by ';' or ',', each ``point@nth[#kind]`` (point names may contain
        ':', so the '@' is split from the right)."""
        triggers: Dict[str, List[Tuple[int, str]]] = {}
        for entry in spec.replace(",", ";").split(";"):
            entry = entry.strip()
            if not entry:
                continue
            point, _, tail = entry.rpartition("@")
            if not point:
                raise ValueError(f"bad fault entry {entry!r}: want "
                                 f"'point@nth[#kind]'")
            nth_s, _, kind = tail.partition("#")
            kind = kind or "fatal"
            if kind not in FAULT_KINDS:
                raise ValueError(f"bad fault kind {kind!r} in {entry!r}; "
                                 f"choose from {FAULT_KINDS}")
            try:
                nth = int(nth_s)
            except ValueError:
                raise ValueError(f"bad visit count {nth_s!r} in {entry!r}")
            if nth < 1:
                raise ValueError(f"visit count must be >= 1 in {entry!r}")
            triggers.setdefault(point, []).append((nth, kind))
        return cls(triggers=triggers)

    @classmethod
    def seeded(cls, seed: int, points: Sequence[str] = POINTS,
               n_faults: int = 1, max_nth: int = 8,
               p_transient: float = 0.5) -> "FaultPlan":
        """A reproducible random plan — the chaos sweep / property tests'
        generator. Same seed, same plan, always."""
        rng = np.random.default_rng(seed)
        triggers: Dict[str, List[Tuple[int, str]]] = {}
        for _ in range(n_faults):
            point = points[int(rng.integers(len(points)))]
            nth = int(rng.integers(1, max_nth + 1))
            kind = ("transient" if rng.random() < p_transient else "fatal")
            triggers.setdefault(point, []).append((nth, kind))
        return cls(triggers=triggers)

    def spec(self) -> str:
        """Inverse of ``parse`` (for logs and the sweep artifact)."""
        parts = []
        for point, trigs in sorted(self.triggers.items()):
            for nth, kind in trigs:
                suffix = "" if kind == "fatal" else f"#{kind}"
                parts.append(f"{point}@{nth}{suffix}")
        return ";".join(parts)

    def visit(self, point: str) -> Optional[str]:
        """Register one arrival at ``point``; return the armed kind when a
        trigger fires (consuming it), else None."""
        n = self.visits.get(point, 0) + 1
        self.visits[point] = n
        trigs = self.triggers.get(point)
        if not trigs:
            return None
        for i, (nth, kind) in enumerate(trigs):
            if nth == n:
                del trigs[i]
                return kind
        return None


# ---------------------------------------------------------------------------
# the active plan: installed > ambient (env) > none
# ---------------------------------------------------------------------------

_UNSET = object()
_installed: object = _UNSET          # sentinel: nothing installed
_ambient: object = _UNSET            # parsed lazily from REPRO_FAULT_PLAN


def ambient() -> Optional[FaultPlan]:
    """The env-derived plan (parsed once; None when REPRO_FAULT_PLAN is
    unset/empty). The chaos sweep sets this; tests that must distinguish
    'my installed fault' from 'sweep noise' consult it."""
    global _ambient
    if _ambient is _UNSET:
        spec = os.environ.get(ENV_PLAN, "").strip()
        _ambient = FaultPlan.parse(spec) if spec else None
    return _ambient


def active_plan() -> Optional[FaultPlan]:
    if _installed is not _UNSET:
        return _installed            # may be None: installed(None) muffles
    return ambient()


def install(plan: Optional[FaultPlan]) -> None:
    """Arm ``plan`` for this process (shadows the ambient env plan).
    ``install(None)`` suppresses fault injection entirely until
    ``clear()``."""
    global _installed
    _installed = plan


def clear() -> None:
    """Drop the installed plan; the ambient env plan (if any) resumes."""
    global _installed
    _installed = _UNSET


class installed:
    """Context manager: arm a plan for the body, restore on exit.
    ``installed(None)`` runs the body fault-free."""

    def __init__(self, plan: Optional[FaultPlan]):
        self.plan = plan
        self._prev: object = _UNSET

    def __enter__(self):
        global _installed
        self._prev = _installed
        _installed = self.plan
        return self.plan

    def __exit__(self, *exc):
        global _installed
        _installed = self._prev
        return False


def fault_point(point: str) -> None:
    """One arrival at a named fault boundary. No-op (one global check)
    unless an active plan has an armed trigger for this point and visit."""
    plan = active_plan()
    if plan is None:
        return
    kind = plan.visit(point)
    if kind is None:
        return
    visit = plan.visits[point]
    LOG.emit("inject", point=point, kind=kind, visit=visit)
    raise InjectedFault(point, transient=(kind == "transient"), visit=visit)


# ---------------------------------------------------------------------------
# retry-with-backoff: the survival half for transient faults
# ---------------------------------------------------------------------------

def is_transient(exc: BaseException) -> bool:
    return bool(getattr(exc, "transient", False))


def retry(fn: Callable, *args, retries: int = 3, base_delay: float = 0.005,
          what: str = "", **kwargs):
    """Call ``fn``; on a *transient* failure, back off exponentially and
    retry up to ``retries`` times. Anything non-transient (real bugs,
    fatal injected faults) propagates on first raise — retries must never
    mask a correctness error. The wrapped call must be idempotent up to
    its first side effect (the runtime's fault points sit before any
    mutation, so a retried drain/transfer re-runs cleanly)."""
    attempt = 0
    while True:
        try:
            return fn(*args, **kwargs)
        except BaseException as exc:
            if not is_transient(exc) or attempt >= retries:
                raise
            LOG.emit("retry", what=what or getattr(fn, "__name__", "call"),
                     attempt=attempt + 1, error=str(exc))
            time.sleep(base_delay * (2.0 ** attempt))
            attempt += 1


# ---------------------------------------------------------------------------
# the fault-log artifact
# ---------------------------------------------------------------------------

def flush_log(path: Optional[str] = None) -> Optional[str]:
    """Append the event log as JSON lines to ``path`` (default:
    ``REPRO_FAULT_LOG``; no-op when neither is set). Appending keeps one
    artifact across a multi-process sweep; each line carries the pid and
    the plan spec that was armed.

    Writes through ``observe.export_events_jsonl`` — the ONE event-feed
    exporter the observability plane uses — so the fault artifact and the
    request-span JSONL share a line format and dropped-event accounting
    (``LOG.n_dropped``) instead of maintaining a private serializer."""
    path = path or os.environ.get(ENV_LOG)
    if not path or not len(LOG):
        return None
    plan = active_plan()
    spec = plan.origin if plan is not None else ""
    from repro.runtime import observe
    observe.export_events_jsonl(path, LOG, pid=os.getpid(), plan=spec)
    LOG.clear()
    return path


if os.environ.get(ENV_LOG):          # pragma: no cover - process teardown
    atexit.register(flush_log)
