from repro.runtime import elastic, serve_loop, stage_executor, train_loop
