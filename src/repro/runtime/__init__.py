from repro.runtime import (controller, elastic, serve_loop, stage_executor,
                           telemetry, train_loop)
