from repro.runtime import elastic, serve_loop, train_loop
