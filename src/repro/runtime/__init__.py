from repro.runtime import (controller, elastic, faults, migration,
                           scheduler, serve_loop, stage_executor, telemetry,
                           train_loop)
