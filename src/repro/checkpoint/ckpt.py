"""Async sharded checkpointing with an atomic commit-marker protocol.

Layout:
    <dir>/step_<n>.tmp/          — leaves being written
    <dir>/step_<n>/              — renamed into place once all leaves landed
    <dir>/step_<n>/COMMITTED     — marker written LAST; restore ignores any
                                   step directory without it (a crash mid-
                                   write can never be restored from)

Each pytree leaf is saved as its own .npy keyed by its flattened tree path,
so per-shard writers on different hosts could each write disjoint leaf sets
(single-host here, but the layout is the multi-host one). Saving runs on a
background thread (``save_async``) so the train loop overlaps the HBM->host
transfer + disk write with the next step's compute; ``wait`` joins before
the next save or at shutdown.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.runtime import faults


_COMMIT = "COMMITTED"


def _leaf_key(path) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", jax.tree_util.keystr(path))


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None
         ) -> str:
    """Blocking save with the atomic protocol. Returns the final dir."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    fin = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {}
    for path, leaf in leaves:
        # crash-simulation boundary: a fault here models a writer dying
        # mid-leaf — only the .tmp dir exists, nothing restorable
        faults.fault_point("ckpt:leaf")
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        dtype_str = str(arr.dtype)
        if arr.dtype.kind == "V" or not arr.dtype.isbuiltin:
            # bfloat16 / fp8 (ml_dtypes): npy round-trips them as raw void —
            # store the bit pattern as uint and record the logical dtype.
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        np.save(os.path.join(tmp, key + ".npy"), arr)
        manifest[key] = {"shape": list(arr.shape), "dtype": dtype_str}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest, "extra": extra or {}}, f)
    if os.path.exists(fin):
        shutil.rmtree(fin)
    os.rename(tmp, fin)
    # crash-simulation boundary: a fault here models a crash between the
    # rename and the commit marker — the directory exists fully written but
    # UNCOMMITTED, and restore/latest_step must treat it as absent
    faults.fault_point("ckpt:precommit")
    # the commit marker is written only after the rename lands
    with open(os.path.join(fin, _COMMIT), "w") as f:
        f.write(str(step))
    return fin


def restore(ckpt_dir: str, step: int, tree_like: Any) -> Any:
    """Restore into the structure of ``tree_like`` (values ignored)."""
    fin = os.path.join(ckpt_dir, f"step_{step}")
    if not os.path.exists(os.path.join(fin, _COMMIT)):
        raise FileNotFoundError(f"step {step} has no committed checkpoint")
    with open(os.path.join(fin, "manifest.json")) as f:
        man = json.load(f)["leaves"]
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, _ in paths:
        key = _leaf_key(p)
        raw = np.load(os.path.join(fin, key + ".npy"))
        want = man[key]["dtype"]
        if str(raw.dtype) != want:
            raw = raw.view(jnp.dtype(want))      # bf16/fp8 bit patterns back
        leaves.append(raw)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest committed step, or None."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, _COMMIT)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` committed checkpoints (and any
    stale .tmp dirs from crashed writers)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (
        int(m.group(1)) for m in (
            re.fullmatch(r"step_(\d+)", n) for n in os.listdir(ckpt_dir))
        if m) if os.path.exists(os.path.join(ckpt_dir, f"step_{s}", _COMMIT)))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    for name in os.listdir(ckpt_dir):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


class AsyncCheckpointer:
    """One background writer; a new save waits for the previous to finish
    (bounded queue depth 1 — matches typical production checkpointers)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, *, extra: Optional[dict] = None):
        self.wait()
        # device_get on the caller thread: the values are snapshot before the
        # train loop mutates buffers (donated args would invalidate them)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            try:
                save(self.ckpt_dir, step, host_tree, extra=extra)
                gc_old(self.ckpt_dir, self.keep)
            except BaseException as e:          # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
