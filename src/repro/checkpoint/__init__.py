from repro.checkpoint import ckpt
