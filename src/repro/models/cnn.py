"""The paper's own benchmark networks, in JAX.

B-LeNet is the modified Branchy-LeNet of ATHEENA Fig. 8 (5x5 convs, maxpool
moved before conv, exit-1 after the first conv stage with one extra conv +
linear). B-AlexNet follows BranchyNet's CIFAR-10 AlexNet variant with one
early exit; Triple-Wins LeNet follows Hu et al. (ICLR'20) with its first
exit. Backbone-only versions (no exits) are the paper's baselines.

These are small enough to *run* (train + profile + serve) on CPU in this
container, which is how we validate the toolflow end-to-end against the
paper's claims.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclass(frozen=True)
class CNNStage:
    """A chunk of backbone between exit points."""
    convs: Tuple[dict, ...]      # [{out, kernel, stride, pool}] per conv
    flatten: bool = False
    linear: Tuple[int, ...] = ()


@dataclass(frozen=True)
class CNNExit:
    convs: Tuple[dict, ...]
    linear: Tuple[int, ...]      # hidden dims; final classes appended


@dataclass(frozen=True)
class CNNConfig:
    name: str
    in_shape: Tuple[int, int, int]          # (H, W, C)
    n_classes: int
    stages: Tuple[CNNStage, ...]
    exits: Tuple[CNNExit, ...]              # len == len(stages) - 1
    dtype: str = "float32"


def _conv_init(key, k, cin, cout, dtype):
    return {
        "w": dense_init(key, (k, k, cin, cout), dtype, scale=(1.0 / (k * k * cin)) ** 0.5),
        "b": jnp.zeros((cout,), dtype),
    }


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool(x, k=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, k, k, 1), "VALID")


def _stage_out_shape(cfg: CNNConfig, upto: int) -> Tuple[int, int, int]:
    h, w, c = cfg.in_shape
    for st in cfg.stages[:upto]:
        for cv in st.convs:
            s = cv.get("stride", 1)
            h, w = -(-h // s), -(-w // s)
            if cv.get("pool"):
                h, w = h // cv["pool"], w // cv["pool"]
            c = cv["out"]
    return h, w, c


def init_cnn(key, cfg: CNNConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    params = {"stages": [], "exits": []}
    h, w, c = cfg.in_shape
    for si, st in enumerate(cfg.stages):
        sp = {"convs": [], "linear": []}
        for ci, cv in enumerate(st.convs):
            kk = jax.random.fold_in(key, si * 100 + ci)
            sp["convs"].append(_conv_init(kk, cv["kernel"], c, cv["out"], dt))
            s = cv.get("stride", 1)
            h, w = -(-h // s), -(-w // s)
            if cv.get("pool"):
                h, w = h // cv["pool"], w // cv["pool"]
            c = cv["out"]
        feat = h * w * c
        if st.flatten:
            dims = list(st.linear) + ([cfg.n_classes] if si == len(cfg.stages) - 1 else [])
            din = feat
            for li, dout in enumerate(dims):
                kk = jax.random.fold_in(key, 9000 + si * 100 + li)
                sp["linear"].append({"w": dense_init(kk, (din, dout), dt),
                                     "b": jnp.zeros((dout,), dt)})
                din = dout
        params["stages"].append(sp)

    for ei, ex in enumerate(cfg.exits):
        eh, ew, ec = _stage_out_shape(cfg, ei + 1)
        ep = {"convs": [], "linear": []}
        cc = ec
        for ci, cv in enumerate(ex.convs):
            kk = jax.random.fold_in(key, 5000 + ei * 100 + ci)
            ep["convs"].append(_conv_init(kk, cv["kernel"], cc, cv["out"], dt))
            s = cv.get("stride", 1)
            eh, ew = -(-eh // s), -(-ew // s)
            if cv.get("pool"):
                eh, ew = eh // cv["pool"], ew // cv["pool"]
            cc = cv["out"]
        din = eh * ew * cc
        for li, dout in enumerate(list(ex.linear) + [cfg.n_classes]):
            kk = jax.random.fold_in(key, 7000 + ei * 100 + li)
            ep["linear"].append({"w": dense_init(kk, (din, dout), dt),
                                 "b": jnp.zeros((dout,), dt)})
            din = dout
        params["exits"].append(ep)
    return params


def run_stage(params, cfg: CNNConfig, si: int, x):
    st = cfg.stages[si]
    sp = params["stages"][si]
    for cv, p in zip(st.convs, sp["convs"]):
        x = _conv(p, x, cv.get("stride", 1))
        x = jax.nn.relu(x)
        if cv.get("pool"):
            x = _maxpool(x, cv["pool"])
    if st.flatten:
        x = x.reshape(x.shape[0], -1)
        for li, p in enumerate(sp["linear"]):
            x = x @ p["w"] + p["b"]
            if li < len(sp["linear"]) - 1:
                x = jax.nn.relu(x)
    return x


def run_exit(params, cfg: CNNConfig, ei: int, x):
    ex = cfg.exits[ei]
    ep = params["exits"][ei]
    for cv, p in zip(ex.convs, ep["convs"]):
        x = _conv(p, x, cv.get("stride", 1))
        x = jax.nn.relu(x)
        if cv.get("pool"):
            x = _maxpool(x, cv["pool"])
    x = x.reshape(x.shape[0], -1)
    for li, p in enumerate(ep["linear"]):
        x = x @ p["w"] + p["b"]
        if li < len(ep["linear"]) - 1:
            x = jax.nn.relu(x)
    return x


def forward_all_exits(params, cfg: CNNConfig, x) -> List[jnp.ndarray]:
    """Returns logits at every exit + final: [exit0, ..., final]."""
    outs = []
    for si in range(len(cfg.stages)):
        x = run_stage(params, cfg, si, x)
        if si < len(cfg.stages) - 1:
            outs.append(run_exit(params, cfg, si, x))
    outs.append(x)
    return outs


def forward_backbone(params, cfg: CNNConfig, x):
    """Baseline: straight through, no exits (the paper's red line)."""
    for si in range(len(cfg.stages)):
        x = run_stage(params, cfg, si, x)
    return x


# ---------------------------------------------------------------------------
# the three paper networks
# ---------------------------------------------------------------------------

def b_lenet() -> CNNConfig:
    """ATHEENA's modified B-LeNet (Fig. 8): 5x5 convs, stride/pool adjusted."""
    return CNNConfig(
        name="b-lenet", in_shape=(28, 28, 1), n_classes=10,
        stages=(
            CNNStage(convs=({"out": 5, "kernel": 5, "stride": 1, "pool": 2},)),
            CNNStage(convs=({"out": 10, "kernel": 5, "pool": 2},
                            {"out": 20, "kernel": 5, "pool": 2}),
                     flatten=True, linear=()),
        ),
        exits=(CNNExit(convs=({"out": 10, "kernel": 3, "pool": 2},), linear=()),),
    )


def b_alexnet() -> CNNConfig:
    """BranchyNet's CIFAR-10 AlexNet with the first early exit."""
    return CNNConfig(
        name="b-alexnet", in_shape=(32, 32, 3), n_classes=10,
        stages=(
            CNNStage(convs=({"out": 32, "kernel": 5, "pool": 2},
                            {"out": 64, "kernel": 5, "pool": 2})),
            CNNStage(convs=({"out": 96, "kernel": 3},
                            {"out": 96, "kernel": 3},
                            {"out": 64, "kernel": 3, "pool": 2}),
                     flatten=True, linear=(256, 128)),
        ),
        exits=(CNNExit(convs=({"out": 32, "kernel": 3, "pool": 2},), linear=(128,)),),
    )


def triple_wins_lenet() -> CNNConfig:
    """Triple-Wins (Hu et al. ICLR'20) LeNet-style net, first exit."""
    return CNNConfig(
        name="triple-wins-lenet", in_shape=(28, 28, 1), n_classes=10,
        stages=(
            CNNStage(convs=({"out": 16, "kernel": 5, "pool": 2},)),
            CNNStage(convs=({"out": 32, "kernel": 5, "pool": 2},),
                     flatten=True, linear=(120, 84)),
        ),
        exits=(CNNExit(convs=(), linear=(64,)),),
    )


CNN_REGISTRY = {
    "b-lenet": b_lenet,
    "b-alexnet": b_alexnet,
    "triple-wins-lenet": triple_wins_lenet,
}
