"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * sigmoid(r_t)). Full-sequence form uses
an associative scan; decode keeps O(1) state (rnn state + conv tail).
The block follows Griffin's recurrent block: in-proj to (x, gate) branches,
causal conv on x, RG-LRU, gated by GeLU(gate), out-proj.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init


def _width(cfg: ArchConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru(key, cfg: ArchConfig) -> dict:
    r = cfg.rglru
    d = cfg.d_model
    w = _width(cfg)
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 6)
    # Lambda init so a^c in (0.9, 0.999) roughly — standard LRU init
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * r.c)))   # softplus^-1
    return {
        "w_x": dense_init(ks[0], (d, w), dt),
        "w_gate": dense_init(ks[1], (d, w), dt),
        "conv_w": dense_init(ks[2], (r.conv_kernel, w), dt, scale=0.5),
        "conv_b": jnp.zeros((w,), dt),
        "w_rec_gate": dense_init(ks[3], (w, w), dt),       # r_t gate
        "w_in_gate": dense_init(ks[5], (w, w), dt),        # i_t gate
        "Lambda": lam,
        "w_out": dense_init(jax.random.fold_in(key, 9), (w, d), dt),
    }


def _gates(params, cfg: ArchConfig, x):
    """x: (..., w) conv output -> (a (fp32), gated input (fp32))."""
    r = cfg.rglru
    xf = x.astype(jnp.float32)
    rec = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xf,
                                    params["w_rec_gate"].astype(jnp.float32)))
    inp = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xf,
                                    params["w_in_gate"].astype(jnp.float32)))
    log_a = -r.c * jax.nn.softplus(params["Lambda"]) * rec
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (inp * xf)
    return a, gated


def _causal_conv(x, w, b):
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out + b


def rglru_fwd(params, cfg: ArchConfig, h) -> Tuple[jnp.ndarray, dict]:
    """h: (B, S, d) -> (out, state) with an associative scan over S."""
    B, S, _ = h.shape
    x = jnp.einsum("bsd,dw->bsw", h, params["w_x"])
    gate = jnp.einsum("bsd,dw->bsw", h, params["w_gate"])
    conv_in = x
    x = _causal_conv(x, params["conv_w"], params["conv_b"])
    a, gx = _gates(params, cfg, x)                        # (B,S,w) fp32

    def combine(c1, c2):
        a1, h1 = c1
        a2, h2 = c2
        return (a1 * a2, h1 * a2 + h2)

    _, states = jax.lax.associative_scan(combine, (a, gx), axis=1)
    y = states.astype(h.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, params["w_out"])
    K = cfg.rglru.conv_kernel
    tail = conv_in[:, -(K - 1):]
    if tail.shape[1] < K - 1:
        tail = jnp.pad(tail, ((0, 0), (K - 1 - tail.shape[1], 0), (0, 0)))
    return out, {"rnn": states[:, -1], "conv": tail}


def init_rglru_state(cfg: ArchConfig, batch: int) -> dict:
    w = _width(cfg)
    K = cfg.rglru.conv_kernel
    return {
        "rnn": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, w), cfg.act_dtype()),
    }


def rglru_decode(params, cfg: ArchConfig, h, state) -> Tuple[jnp.ndarray, dict]:
    """One-token step. h: (B, 1, d)."""
    B = h.shape[0]
    x = jnp.einsum("bd,dw->bw", h[:, 0], params["w_x"])
    gate = jnp.einsum("bd,dw->bw", h[:, 0], params["w_gate"])
    conv_in = jnp.concatenate([state["conv"], x[:, None]], axis=1)   # (B,K,w)
    xc = jnp.einsum("bkw,kw->bw", conv_in.astype(jnp.float32),
                    params["conv_w"].astype(jnp.float32)) + \
        params["conv_b"].astype(jnp.float32)
    a, gx = _gates(params, cfg, xc)
    new = state["rnn"] * a + gx
    y = new.astype(h.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("bw,wd->bd", y, params["w_out"])
    return out[:, None], {"rnn": new, "conv": conv_in[:, 1:]}
