"""Multi-head Latent Attention (DeepSeek-V2), with compressed-KV cache.

MLA projects hidden states into a low-rank KV latent (kv_lora_rank) plus a
shared rope key; per-head K/V are decompressed from the latent. The decode
cache stores only (latent, k_rope) — the paper-relevant 8-9x KV compression.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import (apply_rope, blocked_attention, dense_init,
                                 init_rmsnorm, rmsnorm)


def init_mla(key, cfg: ArchConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dt = cfg.p_dtype()
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        # queries: full rank for V2-Lite (q_lora_rank == 0)
        "wq": dense_init(ks[0], (d, H * qk_head), dt),
        # joint latent projection: [kv latent | shared rope key]
        "w_dkv": dense_init(ks[1], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dt),
        # decompression
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, H * m.qk_nope_head_dim), dt),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, H * m.v_head_dim), dt),
        "wo": dense_init(ks[4], (H * m.v_head_dim, d), dt),
    }
    return p


def _mla_qkv(params, cfg: ArchConfig, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(
        B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,de->bse", x, params["w_dkv"])
    latent, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    latent = rmsnorm(params["kv_norm"], latent, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,r)
    return q_nope, q_rope, latent, k_rope


def _decompress(params, cfg: ArchConfig, latent):
    m = cfg.mla
    B, S, _ = latent.shape
    H = cfg.n_heads
    k_nope = jnp.einsum("bsr,re->bse", latent, params["w_uk"]).reshape(
        B, S, H, m.qk_nope_head_dim)
    v = jnp.einsum("bsr,re->bse", latent, params["w_uv"]).reshape(
        B, S, H, m.v_head_dim)
    return k_nope, v


def mla_fwd(params, cfg: ArchConfig, x, positions=None):
    """Full-sequence MLA (training / prefill). Returns (out, cache_entries)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    q_nope, q_rope, latent, k_rope = _mla_qkv(params, cfg, x, positions)
    k_nope, v = _decompress(params, cfg, latent)
    # assemble per-head q/k with shared rope part broadcast over heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, H, m.qk_rope_head_dim))], axis=-1)
    # pad v to qk head dim so the blocked kernel is reusable, then slice back
    pad = q.shape[-1] - m.v_head_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = blocked_attention(q, k, v_p, causal=True)[..., :m.v_head_dim]
    out = jnp.einsum("bse,ed->bsd", out.reshape(B, S, -1), params["wo"])
    return out, (latent, k_rope[:, :, 0, :])


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int):
    m = cfg.mla
    dt = cfg.act_dtype()
    return {
        "latent": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt),
    }


def init_paged_mla_cache(cfg: ArchConfig, batch: int, max_len: int,
                         page_size: int, n_pages: int):
    """Paged compressed cache for ONE MLA layer: shared page pools for the
    latent and the rope key (page 0 = NULL, all-zeros) plus the per-row
    ``bt`` block table — same discipline as
    ``attention.init_paged_kv_cache``."""
    if max_len % page_size != 0:
        raise ValueError(f"max_len={max_len} must be a multiple of "
                         f"page_size={page_size} (bitwise paged/dense "
                         f"parity needs the gathered span == max_len)")
    m = cfg.mla
    dt = cfg.act_dtype()
    return {
        "latent": jnp.zeros((n_pages, page_size, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((n_pages, page_size, m.qk_rope_head_dim), dt),
        "bt": jnp.zeros((batch, max_len // page_size), jnp.int32),
    }


def _mla_attend(params, cfg: ArchConfig, q_nope, q_rope, lat_cache,
                kr_cache, valid, dtype):
    """The post-write absorbed-decode math, shared by the dense and paged
    paths: identical cache bytes -> bitwise-identical output."""
    m = cfg.mla
    B = q_nope.shape[0]
    H = cfg.n_heads
    # score = q_nope·(W_uk latent) + q_rope·k_rope
    # absorb W_uk into q (the standard MLA decode trick): q_abs (B,H,r)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s = jnp.einsum("bhr,bsr->bhs", q_abs, lat_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                       kr_cache.astype(jnp.float32))
    s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    # out = p · V = p · (W_uv latent); absorb W_uv on the way out
    ctx = jnp.einsum("bhs,bsr->bhr", p, lat_cache.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(B, -1).astype(dtype)
    return jnp.einsum("be,ed->bd", out, params["wo"])


def mla_decode(params, cfg: ArchConfig, x, cache, step):
    """One-token MLA decode against the compressed cache (dense
    {latent, k_rope}, or paged {latent pool, k_rope pool, bt} — detected by
    the ``bt`` key). ``step`` is the scalar absolute position, or a (B,)
    int32 vector of per-row positions (continuous-batching decode); the
    scalar path is untouched for bitwise parity with the step-synchronous
    servers."""
    B = x.shape[0]
    per_row = jnp.ndim(step) == 1
    pos = step[:, None] if per_row else jnp.full((B, 1), step, jnp.int32)
    q_nope, q_rope, latent, k_rope = _mla_qkv(params, cfg, x, pos)
    if "bt" in cache:
        from repro.kernels import dispatch
        bt = cache["bt"]
        M, page = bt.shape[1], cache["latent"].shape[1]
        pos_vec = step if per_row else jnp.full((B,), step, jnp.int32)
        glat, gkr, lat_pool, kr_pool = dispatch.paged_gather_append(
            cache["latent"], cache["k_rope"], latent[:, 0], k_rope[:, 0, 0, :],
            bt, pos_vec, backend=dispatch.kernel_backend())
        L = M * page
        lat_cache = glat.reshape(B, L, -1)
        kr_cache = gkr.reshape(B, L, -1)
        # sentinel rows (pos >= L) attend over all-zero pages with an
        # all-true mask: finite garbage on a discarded row, never NaN
        valid = (jnp.arange(L)[None, :] <= pos_vec[:, None]) | (
            pos_vec[:, None] >= L)
        out = _mla_attend(params, cfg, q_nope, q_rope, lat_cache, kr_cache,
                          valid, x.dtype)
        return out[:, None, :], {"latent": lat_pool, "k_rope": kr_pool,
                                 "bt": bt}
    if per_row:
        rows = jnp.arange(B, dtype=jnp.int32)
        lat_cache = cache["latent"].at[rows, step].set(latent[:, 0])
        kr_cache = cache["k_rope"].at[rows, step].set(k_rope[:, 0, 0, :])
    else:
        lat_cache = jax.lax.dynamic_update_slice(cache["latent"], latent,
                                                 (0, step, 0))
        kr_cache = jax.lax.dynamic_update_slice(cache["k_rope"],
                                                k_rope[:, :, 0, :],
                                                (0, step, 0))
    Smax = lat_cache.shape[1]
    valid = (jnp.arange(Smax)[None, :] <= step[:, None] if per_row
             else jnp.broadcast_to(jnp.arange(Smax) <= step, (B, Smax)))
    out = _mla_attend(params, cfg, q_nope, q_rope, lat_cache, kr_cache,
                      valid, x.dtype)
    return out[:, None, :], {"latent": lat_cache, "k_rope": kr_cache}
