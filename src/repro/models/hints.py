"""Ambient distribution hints for model code.

The model definitions are mesh-agnostic; the cell builders (launch/steps.py)
publish the production mesh here so perf-critical layers can opt into
explicit sharding (shard_map sequence-parallel attention, Megatron-SP
activation constraints) when the mesh supports it. With no mesh set (unit
tests, single-host examples) every hint is a no-op.

Set at trace time: ``with hints.use_mesh(mesh): jit(f).lower(...)``.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

_MESH = None
_SP_ATTENTION = True         # master switch for the beyond-paper SP path


def mesh():
    return _MESH


@contextlib.contextmanager
def use_mesh(m, sp_attention: bool = True):
    global _MESH, _SP_ATTENTION
    old, olds = _MESH, _SP_ATTENTION
    _MESH, _SP_ATTENTION = m, sp_attention
    try:
        yield
    finally:
        _MESH, _SP_ATTENTION = old, olds


def set_mesh(m) -> None:
    global _MESH
    _MESH = m


def batch_axes() -> Tuple[str, ...]:
    if _MESH is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in _MESH.axis_names)


def constrain_seq(x):
    """Residual-stream layout constraint between blocks, matching the
    attention decomposition: batch-split (training shapes — everything
    local, weights stream FSDP-style) or Megatron-SP seq-split (long
    prefill — elementwise/norm traffic is 1/TP per device; XLA all-gathers
    only where the full sequence is truly needed). No-op without a mesh."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    if x.ndim != 3 or _MESH is None:
        return x
    split = attn_split(x.shape[1], x.shape[0])
    if split is None:
        return x
    kind, baxes = split
    if kind == "batch":
        spec = P((*baxes, "model"), None, None)
    else:
        spec = P(baxes if baxes else None, "model", None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def sp_axis(seq_len: int, batch: int) -> Optional[Tuple[str, Tuple[str, ...]]]:
    """If sequence-parallel attention applies: returns ("model", batch_axes).
    Conditions: a 'model' axis exists, S divides it, and the global batch
    divides the batch axes (so shard_map in_specs are exact)."""
    if _MESH is None or not _SP_ATTENTION:
        return None
    names = _MESH.axis_names
    if "model" not in names:
        return None
    m = _MESH.shape["model"]
    if m <= 1 or seq_len % m != 0 or seq_len // m < 128:
        return None
    return "model", _fit_batch_axes(batch)


def _fit_batch_axes(batch: int) -> Tuple[str, ...]:
    """Largest batch-axis subset whose size divides the batch — e.g. the
    stage-2 slab (capacity 16) on the 2x16x16 multi-pod mesh shards over
    ('data',) and replicates over 'pod' instead of replicating everywhere
    (which would redundantly compute the slab 32x)."""
    axes = batch_axes()
    cands = [axes] + [(a,) for a in sorted(
        axes, key=lambda a: -_MESH.shape[a])] + [()]
    for c in cands:
        nb = 1
        for a in c:
            nb *= _MESH.shape[a]
        if nb and batch % nb == 0:
            return c
    return ()


def attn_split(seq_len: int, batch: int):
    """How to decompose attention over the mesh:
      ("batch", baxes)  — batch large enough to split over (baxes + model):
                          each device holds whole sequences, zero K/V comm
                          and per-sample VMEM-sized tiles (training shapes);
      ("seq", baxes)    — sequence-parallel with q-offset (long prefill);
      None              — single-device / tiny mesh: plain path.
    """
    if _MESH is None or not _SP_ATTENTION or "model" not in _MESH.axis_names:
        return None
    m = _MESH.shape["model"]
    if m <= 1:
        return None
    baxes = _fit_batch_axes(batch)
    nb = 1
    for a in baxes:
        nb *= _MESH.shape[a]
    if batch % max(nb * m, 1) == 0 and batch >= nb * m:
        return ("batch", baxes)
    sp = sp_axis(seq_len, batch)
    if sp is not None:
        return ("seq", sp[1])
    return None
