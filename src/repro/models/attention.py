"""Attention blocks: GQA/MQA (+bias, +qk_norm, sliding window) and KV caches."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models import hints
from repro.models.config import ArchConfig
from repro.models.layers import (apply_rope, blocked_attention,
                                 dense_init, init_rmsnorm,
                                 masked_decode_attention, rmsnorm)


def attention_core(q, k, v, *, causal: bool, window: Optional[int],
                   softcap: Optional[float], use_kernel: bool = False):
    """Dispatch: sequence-parallel shard_map attention when the ambient mesh
    supports it (beyond-paper optimization — each device computes S/TP query
    rows with ALL heads local, K/V gathered once; removes the per-block
    all-reduce XLA emits when head counts don't divide the model axis),
    else the plain blocked path.

    ``use_kernel``: route the per-shard computation through the Pallas flash
    kernel (serving paths — the kernel has no VJP; training keeps the
    differentiable jnp block scan). softcap archs stay on the jnp path."""
    split = hints.attn_split(q.shape[1], q.shape[0])
    if split is None or q.shape[1] != k.shape[1]:
        return blocked_attention(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    kind, baxes = split
    mesh = hints.mesh()
    kernel_ok = use_kernel and softcap is None and causal

    def kern(q_l, k_l, v_l, off):
        if kernel_ok:
            from repro.kernels.flash_attention.kernel import \
                flash_attention_pallas
            o = flash_attention_pallas(
                q_l.transpose(0, 2, 1, 3), k_l.transpose(0, 2, 1, 3),
                v_l.transpose(0, 2, 1, 3), off, causal=True, window=window,
                interpret=jax.default_backend() == "cpu")
            return o.transpose(0, 2, 1, 3)
        if softcap is None:
            # custom-VJP flash: backward recomputes p-blocks instead of
            # stacking them as AD residuals (the dominant train HBM term)
            from repro.models.layers import flash_attention_diff
            return flash_attention_diff(q_l, k_l, v_l, off, causal, window)
        return blocked_attention(q_l, k_l, v_l, causal=causal, window=window,
                                 softcap=softcap, q_offset=off)

    if kind == "batch":
        # whole sequences per device, batch over (baxes + model): no K/V
        # comm at all, per-sample VMEM tiles (training decomposition)
        bspec = (*baxes, "model")
        spec = P(bspec, None, None, None)
        return compat.shard_map(
            lambda a, b, c: kern(a, b, c, 0), mesh=mesh,
            in_specs=(spec, spec, spec), out_specs=spec,
            check_vma=False)(q, k, v)

    # sequence-parallel: q rows sharded over model, K/V whole (long prefill)
    axis = "model"
    s_local = q.shape[1] // mesh.shape[axis]
    bspec = baxes if baxes else None
    return compat.shard_map(
        lambda a, b, c: kern(a, b, c, jax.lax.axis_index(axis) * s_local),
        mesh=mesh,
        in_specs=(P(bspec, axis, None, None), P(bspec, None, None, None),
                  P(bspec, None, None, None)),
        out_specs=P(bspec, axis, None, None),
        check_vma=False,
    )(q, k, v)


def init_attention(key, cfg: ArchConfig) -> dict:
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dt),
        "wk": dense_init(ks[1], (d, KH * hd), dt),
        "wv": dense_init(ks[2], (d, KH * hd), dt),
        "wo": dense_init(ks[3], (H * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KH * hd,), dt)
        p["bv"] = jnp.zeros((KH * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def _project_qkv(params, cfg: ArchConfig, x, positions):
    B, S, _ = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KH, hd)
    v = v.reshape(B, S, KH, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_fwd(params, cfg: ArchConfig, x, *, window: Optional[int] = None,
                  causal: bool = True, positions=None,
                  kv: Optional[tuple] = None, use_kernel: bool = False):
    """Full-sequence attention (training / prefill).

    x: (B, S, d_model). ``kv`` overrides self-attention K/V inputs for
    cross-attention: a tuple (k_src, v_src) already shaped (B, Sk, KH, hd).
    Returns (out, (k, v)) so prefill can retain the cache.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    if kv is None:
        q, k, v = _project_qkv(params, cfg, x, positions)
    else:
        H, hd = cfg.n_heads, cfg.resolved_head_dim
        q = jnp.einsum("bsd,de->bse", x, params["wq"])
        if cfg.qkv_bias:
            q = q + params["bq"]
        q = q.reshape(B, S, H, hd)
        if cfg.qk_norm:
            q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k, v = kv
        causal = False
    out = attention_core(q, k, v, causal=causal, window=window,
                         softcap=cfg.logit_softcap, use_kernel=use_kernel)
    out = jnp.einsum("bse,ed->bsd", out.reshape(B, S, -1), params["wo"])
    return out, (k, v)


def cross_kv(params, cfg: ArchConfig, memory):
    """Project encoder memory to (k, v) once for cross-attention reuse."""
    B, Sk, _ = memory.shape
    KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = jnp.einsum("bsd,de->bse", memory, params["wk"])
    v = jnp.einsum("bsd,de->bse", memory, params["wv"])
    if cfg.qkv_bias:
        k = k + params["bk"]
        v = v + params["bv"]
    k = k.reshape(B, Sk, KH, hd)
    v = v.reshape(B, Sk, KH, hd)
    if cfg.qk_norm:
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    return k, v


# ----------------------------------------------------------------------------
# KV cache (decode)
# ----------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, window: Optional[int] = None):
    """Cache arrays for ONE attention layer. Windowed layers allocate only
    the window (ring buffer)."""
    KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    L = min(max_len, window) if window else max_len
    dt = cfg.act_dtype()
    return {
        "k": jnp.zeros((batch, L, KH, hd), dt),
        "v": jnp.zeros((batch, L, KH, hd), dt),
    }


def init_paged_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                        page_size: int, n_pages: int):
    """Paged cache pytree for ONE attention layer: a shared page pool
    ``(n_pages, page, KH, hd)`` (page 0 = NULL, kept all-zeros) plus a
    per-row block table ``bt: (batch, max_pages)`` of pool page indices
    (0 = unused). Keys mirror the dense cache ({k, v}) so the segment
    helpers (``ee.split_caches``) pair pool leaves with dense-row leaves
    structurally; the ``bt`` leaf marks the cache as paged."""
    if max_len % page_size != 0:
        raise ValueError(f"max_len={max_len} must be a multiple of "
                         f"page_size={page_size} (bitwise paged/dense "
                         f"parity needs the gathered span == max_len)")
    KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.act_dtype()
    return {
        "k": jnp.zeros((n_pages, page_size, KH, hd), dt),
        "v": jnp.zeros((n_pages, page_size, KH, hd), dt),
        "bt": jnp.zeros((batch, max_len // page_size), jnp.int32),
    }


def attention_decode(params, cfg: ArchConfig, x, cache, step, *,
                     window: Optional[int] = None):
    """One-token decode. x: (B, 1, d). cache: this layer's {k,v}, or the
    paged {k pool, v pool, bt block table} (detected by the ``bt`` key).
    step: scalar int32 — current absolute position shared by the batch — or
    a (B,) int32 vector of PER-ROW positions (continuous-batching decode,
    where slots in one pool batch sit at different depths). The scalar path
    is untouched (bitwise parity with the step-synchronous servers); the
    vector path scatters each row's k/v at its own slot and masks each
    row's attention span by its own length. Every path routes through the
    ONE masked attention core (``layers.masked_decode_attention``), so
    dense/windowed/paged agree bitwise given identical cache bytes.
    Returns (out, new_cache)."""
    B = x.shape[0]
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    per_row = jnp.ndim(step) == 1
    pos = step[:, None] if per_row else jnp.full((B, 1), step, jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, pos)
    q = q[:, 0]                                    # (B, H, hd)
    if "bt" in cache:
        if window:
            raise NotImplementedError("windowed layers keep the dense ring "
                                      "cache; paged mode rejects them")
        from repro.kernels import dispatch
        bt = cache["bt"]
        M, page = bt.shape[1], cache["k"].shape[1]
        pos_vec = step if per_row else jnp.full((B,), step, jnp.int32)
        gk, gv, k_pool, v_pool = dispatch.paged_gather_append(
            cache["k"], cache["v"], k[:, 0], v[:, 0], bt, pos_vec,
            backend=dispatch.kernel_backend())
        L = M * page
        k_cache = gk.reshape(B, L, KH, hd)
        v_cache = gv.reshape(B, L, KH, hd)
        # sentinel rows (pos >= L, parked/flush slots) keep an all-true
        # mask over all-zero gathered pages: attention over zeros is
        # finite garbage on a discarded row, never a NaN softmax
        valid = (jnp.arange(L)[None, :] <= pos_vec[:, None]) | (
            pos_vec[:, None] >= L)
        out = masked_decode_attention(q, k_cache, v_cache, valid,
                                      softcap=cfg.logit_softcap)
        out = jnp.einsum("be,ed->bd", out.reshape(B, -1), params["wo"])
        return out[:, None, :], {"k": k_pool, "v": v_pool, "bt": bt}
    L = cache["k"].shape[1]
    slot = (step % L) if window else step
    if per_row:
        rows = jnp.arange(B, dtype=jnp.int32)
        k_cache = cache["k"].at[rows, slot].set(k[:, 0])
        v_cache = cache["v"].at[rows, slot].set(v[:, 0])
    else:
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    if window:
        # ring buffer: all L slots valid once step >= L; positions are
        # implicit. Reconstruct per-slot absolute positions for masking:
        # slot i holds position step - ((slot - i) mod L)
        idx = jnp.arange(L)
        if per_row:
            abs_pos = step[:, None] - ((slot[:, None] - idx[None, :]) % L)
            valid = ((abs_pos >= 0) & (abs_pos <= step[:, None])
                     & (abs_pos > step[:, None] - L))       # (B, L)
        else:
            abs_pos = step - ((slot - idx) % L)
            valid = (abs_pos >= 0) & (abs_pos <= step) & (abs_pos > step - L)
            valid = jnp.broadcast_to(valid[None, :], (B, L))
        out = masked_decode_attention(q, k_cache, v_cache, valid,
                                      softcap=cfg.logit_softcap)
    else:
        cache_len = (step + 1 if per_row
                     else jnp.full((B,), step + 1, jnp.int32))
        valid = jnp.arange(L)[None, :] < cache_len[:, None]
        out = masked_decode_attention(q, k_cache, v_cache, valid,
                                      softcap=cfg.logit_softcap)
    out = jnp.einsum("be,ed->bd", out.reshape(B, -1), params["wo"])
    return out[:, None, :], {"k": k_cache, "v": v_cache}
