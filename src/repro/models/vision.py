"""Modality frontend STUBS.

Per the assignment, [audio]/[vlm] entries specify the transformer BACKBONE
only — the modality frontend supplies precomputed frame/patch embeddings.
These helpers define the stub shapes and a deterministic synthetic generator
so smoke tests and input_specs agree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


def frontend_embed_shape(cfg: ArchConfig, batch: int, seq_len: int):
    """Shape of the precomputed embedding tensor handed to the backbone."""
    if cfg.frontend == "vit_stub":
        # InternViT patches projected into LM space; count fixed by config.
        return (batch, cfg.n_frontend_tokens, cfg.d_model)
    if cfg.frontend == "speech_stub":
        # seamless: speech frames after the (stubbed) conformer frontend.
        # Frame count scales with the shape's sequence budget, capped.
        frames = min(max(seq_len // 4, 256), 4096)
        return (batch, frames, cfg.d_model)
    raise ValueError(f"{cfg.name} has no frontend")


def synth_frontend_embeds(key, cfg: ArchConfig, batch: int, seq_len: int):
    shape = frontend_embed_shape(cfg, batch, seq_len)
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(cfg.act_dtype())
