"""Mixture-of-Experts with fixed-capacity sort-based dispatch.

Dispatch is gather/scatter based (no one-hot (T,E,C) tensor): tokens are
replicated top_k times, sorted by expert id, and each expert processes a
fixed-capacity contiguous slab. This is static-shaped (XLA/TPU friendly),
shards cleanly (experts over the "model" axis, capacity over "data"), and
drops overflow tokens exactly like capacity-factor MoE implementations.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init, init_mlp, mlp


def expert_capacity(n_tokens: int, cfg: ArchConfig, multiple: int = 128) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    return max(multiple, ((c + multiple - 1) // multiple) * multiple)


def init_moe(key, cfg: ArchConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), jnp.float32),
        # stacked expert weights (E, ...), SwiGLU experts
        "e_gate": dense_init(ks[1], (m.n_experts, d, m.d_ff_expert), dt),
        "e_up": dense_init(ks[2], (m.n_experts, d, m.d_ff_expert), dt),
        "e_down": dense_init(ks[3], (m.n_experts, m.d_ff_expert, d), dt),
    }
    if m.n_shared:
        p["shared"] = init_mlp(jax.random.fold_in(key, 7), d,
                               m.d_ff_expert * m.n_shared, "swiglu", dt)
    return p


def route(params, cfg: ArchConfig, x_flat) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x_flat: (T, d) -> (topk_idx (T,k), topk_w (T,k), aux_loss ())."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, m.top_k)
    topk_w = topk_w / jnp.maximum(jnp.sum(topk_w, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    T = x_flat.shape[0]
    f = jnp.zeros((m.n_experts,), jnp.float32).at[topk_idx.reshape(-1)].add(
        1.0 / (T * m.top_k))
    P = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(f * P)
    return topk_idx, topk_w, aux


def moe_fwd(params, cfg: ArchConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss ())."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    C = expert_capacity(T, cfg)
    xf = x.reshape(T, d)
    topk_idx, topk_w, aux = route(params, cfg, xf)

    # ---- dispatch: sort (token,slot) assignments by expert -----------------
    flat_e = topk_idx.reshape(-1)                       # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), m.top_k)         # token id per assignment
    flat_w = topk_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)            # group by expert
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    w_sorted = flat_w[order]
    # position within the expert group
    pos_in_e = jnp.arange(T * m.top_k) - jnp.searchsorted(
        e_sorted, e_sorted, side="left")
    keep = pos_in_e < C                                  # capacity drop
    slot = e_sorted * C + jnp.minimum(pos_in_e, C - 1)   # (T*k,) flat slab slot

    # gather tokens into the (E*C, d) slab; dropped tokens contribute nothing
    slab = jnp.zeros((m.n_experts * C, d), x.dtype)
    slab = slab.at[slot].add(jnp.where(keep[:, None], xf[t_sorted], 0))
    slab = slab.reshape(m.n_experts, C, d)

    # ---- expert computation (E, C, d) x (E, d, f) --------------------------
    g = jnp.einsum("ecd,edf->ecf", slab, params["e_gate"])
    u = jnp.einsum("ecd,edf->ecf", slab, params["e_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["e_down"]).reshape(m.n_experts * C, d)

    # ---- combine: weighted scatter-add back to tokens ----------------------
    contrib = y[slot] * jnp.where(keep, w_sorted, 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[t_sorted].add(contrib)

    if m.n_shared:
        out = out + mlp(params["shared"], xf, "swiglu")
    return out.reshape(B, S, d), aux


def moe_fwd_dense(params, cfg: ArchConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference dense formulation: every expert sees every token (oracle for
    tests; O(E/topk) more FLOPs, never used in the hot path)."""
    m = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    topk_idx, topk_w, aux = route(params, cfg, xf)
    g = jnp.einsum("td,edf->tef", xf, params["e_gate"])
    u = jnp.einsum("td,edf->tef", xf, params["e_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("tef,efd->ted", h, params["e_down"])       # (T, E, d)
    w_full = jnp.zeros((xf.shape[0], m.n_experts), jnp.float32).at[
        jnp.arange(xf.shape[0])[:, None], topk_idx].set(topk_w)
    out = jnp.einsum("te,ted->td", w_full.astype(x.dtype), y)
    if m.n_shared:
        out = out + mlp(params["shared"], xf, "swiglu")
    return out.reshape(B, S, d), aux
