"""Mamba-2 SSD (state-space duality) mixer, chunked matmul formulation.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks; intra-chunk interactions are computed as (masked) matmuls
(MXU-friendly) and inter-chunk state is carried by an associative scan over
chunk summaries. Decode keeps O(1) state: (conv_state, ssd_state).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def init_mamba2(key, cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H = _dims(cfg)
    dt = cfg.p_dtype()
    G, N = s.n_groups, s.d_state
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * d_inner + 2 * G * N + H      # [z, x, B, C, dt]
    p = {
        "w_in": dense_init(ks[0], (d, d_in_proj), dt),
        "conv_w": dense_init(ks[1], (s.conv_kernel, d_inner + 2 * G * N), dt,
                             scale=0.5),
        "conv_b": jnp.zeros((d_inner + 2 * G * N,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(jnp.float32),
        "norm": init_rmsnorm(d_inner, dt),
        "w_out": dense_init(ks[2], (d_inner, d), dt),
    }
    return p


def _split_proj(cfg: ArchConfig, zxbcdt):
    s = cfg.ssm
    d_inner, H = _dims(cfg)
    G, N = s.n_groups, s.d_state
    z, x, B_, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + G * N,
                 2 * d_inner + 2 * G * N], axis=-1)
    return z, x, B_, C, dt


def _causal_conv(x, w, b):
    """x: (B, S, D); w: (K, D) depthwise causal conv."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x, dt, A_log, B_, C, D, chunk: int):
    """Core SSD. x: (B,S,H,P); dt: (B,S,H); B_,C: (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    nc = S // chunk
    rep = H // G
    A = -jnp.exp(A_log)                                   # (H,) negative decay

    xc = x.reshape(Bb, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bb, nc, chunk, H).astype(jnp.float32)
    Bc = B_.reshape(Bb, nc, chunk, G, N).astype(jnp.float32)
    Cc = C.reshape(Bb, nc, chunk, G, N).astype(jnp.float32)

    dA = dtc * A                                          # (B,nc,l,H)
    cum = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum
    seg_total = cum[:, :, -1]                             # (B,nc,H)

    # intra-chunk (the "attention-like" quadratic-in-chunk term)
    # L[i,j] = exp(cum_i - cum_j) * dt_j  for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,l,l,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bclgn,bcsgn->bclsg", Cc, Bc)         # (B,nc,l,l,G)
    CB = jnp.repeat(CB, rep, axis=-1)                     # broadcast groups->heads
    scores = CB * L * dtc[:, :, None, :, :]               # (B,nc,l,l,H)
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", scores, xc)

    # chunk summary states: sum_j exp(total - cum_j) dt_j B_j x_j
    decay_to_end = jnp.exp(seg_total[:, :, None] - cum)   # (B,nc,l,H)
    wB = jnp.repeat(Bc, rep, axis=-2)                     # (B,nc,l,H,N)
    states = jnp.einsum("bclh,bclhn,bclhp->bchpn", decay_to_end * dtc, wB, xc)

    # inter-chunk recurrence over chunk states (associative scan)
    seg_decay = jnp.exp(seg_total)                        # (B,nc,H)

    def combine(a, b):
        da, sa = a
        db, sb = b
        return (da * db, sa * db[..., None, None] + sb)

    d_all, s_all = jax.lax.associative_scan(
        combine, (seg_decay, states), axis=1)
    # state entering chunk c = scanned state of chunk c-1 (shift right)
    init_states = jnp.pad(s_all[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    final_state = s_all[:, -1]                            # (B,H,P,N)

    # contribution of carried-in state to each position
    decay_from_start = jnp.exp(cum)                       # (B,nc,l,H)
    wC = jnp.repeat(Cc, rep, axis=-2)
    y_inter = jnp.einsum("bclhn,bchpn,bclh->bclhp", wC, init_states,
                         decay_from_start)

    y = y_intra + y_inter + (D[None, None, None, :, None] * xc)
    return y.reshape(Bb, S, H, P), final_state


def mamba2_fwd(params, cfg: ArchConfig, h) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence mixer. h: (B, S, d_model). Returns (out, final_state)."""
    s = cfg.ssm
    d_inner, H = _dims(cfg)
    G, N = s.n_groups, s.d_state
    B, S, _ = h.shape
    zxbcdt = jnp.einsum("bsd,de->bse", h, params["w_in"])
    z, x, B_, C, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, B_, C], axis=-1)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    x, B_, C = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    x = x.reshape(B, S, H, s.head_dim)
    B_ = B_.reshape(B, S, G, N)
    C = C.reshape(B, S, G, N)
    pad = (-S) % s.chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, state = ssd_chunked(x, dt, params["A_log"], B_, C, params["D"], s.chunk)
    y = y[:, :S].reshape(B, S, d_inner).astype(h.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype),
                cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, {"ssd": state.astype(jnp.float32),
                 "conv": _last_conv_state(cfg, h, zxbcdt)}


def _last_conv_state(cfg: ArchConfig, h, zxbcdt):
    """Keep the last K-1 pre-conv activations for decode."""
    s = cfg.ssm
    d_inner, _ = _dims(cfg)
    G, N = s.n_groups, s.d_state
    _, x, B_, C, _ = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, B_, C], axis=-1)
    K = s.conv_kernel
    B = h.shape[0]
    tail = xbc[:, -(K - 1):]
    pad = (K - 1) - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return tail


def init_mamba2_state(cfg: ArchConfig, batch: int) -> dict:
    s = cfg.ssm
    d_inner, H = _dims(cfg)
    G, N = s.n_groups, s.d_state
    return {
        "ssd": jnp.zeros((batch, H, s.head_dim, N), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_kernel - 1, d_inner + 2 * G * N),
                          cfg.act_dtype()),
    }


def mamba2_decode(params, cfg: ArchConfig, h, state) -> Tuple[jnp.ndarray, dict]:
    """One-token step. h: (B, 1, d). state: {"ssd","conv"}."""
    s = cfg.ssm
    d_inner, H = _dims(cfg)
    G, N = s.n_groups, s.d_state
    B = h.shape[0]
    zxbcdt = jnp.einsum("bsd,de->bse", h, params["w_in"])[:, 0]
    z, x, B_, C, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, B_, C], axis=-1)              # (B, D')
    conv_in = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)  # (B,K,D')
    w = params["conv_w"]
    out = jnp.einsum("bkd,kd->bd", conv_in.astype(jnp.float32),
                     w.astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(out).astype(h.dtype)
    x, B_, C = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,H)
    x = x.reshape(B, H, s.head_dim).astype(jnp.float32)
    B_ = jnp.repeat(B_.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    C = jnp.repeat(C.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    A = -jnp.exp(params["A_log"])                                      # (H,)
    dA = jnp.exp(dt * A)                                               # (B,H)
    new_state = state["ssd"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, B_, x)
    y = jnp.einsum("bhn,bhpn->bhp", C, new_state) + params["D"][None, :, None] * x
    y = y.reshape(B, d_inner).astype(h.dtype)
    y = rmsnorm(params["norm"],
                y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype), cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["w_out"])
    return out[:, None], {"ssd": new_state, "conv": conv_in[:, 1:]}
