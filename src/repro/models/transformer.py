"""Backbone assembly: pattern-scanned layer stacks with staged execution.

The layer stack is organised as
    [first_k_dense unrolled layers] ++ [n_superblocks x pattern (lax.scan)]
    ++ [remainder unrolled layers]
so that 64-layer models lower as a single scanned superblock body, and the
early-exit stage boundary can slice the scanned stack at superblock
granularity (ATHEENA stage partitioning).

Three execution modes share the block code:
    mode="train"   full sequence, no cache returned
    mode="prefill" full sequence, caches returned
    mode="decode"  one token against caches (step = absolute position)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import hints
from repro.models import mamba2 as m2
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models.config import ArchConfig
from repro.models.layers import (embed, init_embedding, init_mlp, init_rmsnorm,
                                 mlp, rmsnorm, unembed)


# ----------------------------------------------------------------------------
# per-block init / apply
# ----------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, kind: str, *, dense_mlp: bool = False,
                cross: bool = False) -> dict:
    """One backbone block of the given kind."""
    dt = cfg.p_dtype()
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(d, dt)}
    if kind in ("attn", "lattn"):
        if cfg.mla is not None and kind == "attn":
            p["attn"] = mla_mod.init_mla(ks[0], cfg)
        else:
            p["attn"] = attn.init_attention(ks[0], cfg)
    elif kind == "mamba2":
        p["mixer"] = m2.init_mamba2(ks[0], cfg)
    elif kind == "rglru":
        p["mixer"] = rg.init_rglru(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = init_rmsnorm(d, dt)
        p["cross"] = attn.init_attention(ks[3], cfg)
    if cfg.d_ff > 0 or (cfg.moe and not dense_mlp):
        p["norm2"] = init_rmsnorm(d, dt)
        if cfg.moe is not None and not dense_mlp:
            p["moe"] = moe_mod.init_moe(ks[1], cfg)
        else:
            ff = cfg.dense_ff if (dense_mlp and cfg.dense_ff) else cfg.d_ff
            p["mlp"] = init_mlp(ks[1], d, ff, cfg.mlp_act, dt)
    return p


def _init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      cross_len: int = 0) -> dict:
    if kind == "attn":
        if cfg.mla is not None:
            c = mla_mod.init_mla_cache(cfg, batch, max_len)
        else:
            c = attn.init_kv_cache(cfg, batch, max_len)
    elif kind == "lattn":
        c = attn.init_kv_cache(cfg, batch, max_len, window=cfg.window)
    elif kind == "mamba2":
        c = m2.init_mamba2_state(cfg, batch)
    elif kind == "rglru":
        c = rg.init_rglru_state(cfg, batch)
    else:
        raise ValueError(kind)
    if cross_len:
        hd = cfg.resolved_head_dim
        c = dict(c)
        c["xk"] = jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd), cfg.act_dtype())
        c["xv"] = jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd), cfg.act_dtype())
    return c


def _apply_block(params, cfg: ArchConfig, kind: str, h, *, mode: str,
                 cache=None, step=None, causal: bool = True,
                 memory=None, dense_mlp: bool = False):
    """Returns (h, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = rmsnorm(params["norm1"], h, cfg.norm_eps)
    new_cache = cache
    if kind in ("attn", "lattn"):
        window = cfg.window if kind == "lattn" else None
        if cfg.mla is not None and kind == "attn":
            if mode == "decode":
                y, new_cache = mla_mod.mla_decode(params["attn"], cfg, x,
                                                  cache, step)
            else:
                y, (latent, k_rope) = mla_mod.mla_fwd(params["attn"], cfg, x)
                if mode == "prefill":
                    new_cache = {"latent": latent, "k_rope": k_rope}
        elif mode == "decode":
            y, kv = attn.attention_decode(params["attn"], cfg, x, cache, step,
                                          window=window if kind == "lattn" else None)
            new_cache = dict(cache)
            new_cache.update(kv)
        else:
            y, (k, v) = attn.attention_fwd(
                params["attn"], cfg, x, window=window, causal=causal,
                # the Pallas kernel is the TPU hot path; on the CPU host
                # (tests + dry-run) the lowered path is the jnp block scan —
                # interpret-mode pallas lowers refs as full-array copies,
                # which misrepresents the kernel's VMEM behaviour.
                use_kernel=(mode == "prefill" and
                            jax.default_backend() != "cpu"))
            if mode == "prefill":
                new_cache = {"k": k, "v": v}
                if kind == "lattn" and cfg.window and k.shape[1] > cfg.window:
                    # ring-buffer layout: decode expects position P at slot
                    # P % window; the last-window slice holds positions
                    # [S-w, S) contiguously, so rotate right by S % w.
                    r = k.shape[1] % cfg.window
                    new_cache = {
                        "k": jnp.roll(k[:, -cfg.window:], r, axis=1),
                        "v": jnp.roll(v[:, -cfg.window:], r, axis=1),
                    }
    elif kind == "mamba2":
        if mode == "decode":
            y, new_cache = m2.mamba2_decode(params["mixer"], cfg, x, cache)
        else:
            y, st = m2.mamba2_fwd(params["mixer"], cfg, x)
            new_cache = st if mode == "prefill" else None
    elif kind == "rglru":
        if mode == "decode":
            y, new_cache = rg.rglru_decode(params["mixer"], cfg, x, cache)
        else:
            y, st = rg.rglru_fwd(params["mixer"], cfg, x)
            new_cache = st if mode == "prefill" else None
    h = h + y
    if "cross" in params and (memory is not None or mode == "decode"):
        x = rmsnorm(params["norm_x"], h, cfg.norm_eps)
        if mode == "decode":
            xk, xv = cache["xk"], cache["xv"]
            hd = cfg.resolved_head_dim
            B = x.shape[0]
            q = jnp.einsum("bsd,de->bse", x, params["cross"]["wq"])
            if cfg.qkv_bias:
                q = q + params["cross"]["bq"]
            q = q.reshape(B, cfg.n_heads, hd)
            from repro.models.layers import decode_attention
            clen = jnp.full((B,), xk.shape[1], jnp.int32)
            y = decode_attention(q, xk, xv, clen)
            y = jnp.einsum("be,ed->bd", y.reshape(B, -1),
                           params["cross"]["wo"])[:, None]
        else:
            kv = attn.cross_kv(params["cross"], cfg, memory)
            y, _ = attn.attention_fwd(params["cross"], cfg, x, kv=kv)
            if mode == "prefill":
                new_cache = dict(new_cache or {})
                new_cache["xk"], new_cache["xv"] = kv
        h = h + y
    if "moe" in params:
        x = rmsnorm(params["norm2"], h, cfg.norm_eps)
        y, aux = moe_mod.moe_fwd(params["moe"], cfg, x)
        h = h + y
    elif "mlp" in params:
        x = rmsnorm(params["norm2"], h, cfg.norm_eps)
        ff = cfg.dense_ff if (dense_mlp and cfg.dense_ff) else cfg.d_ff
        h = h + mlp(params["mlp"], x, cfg.mlp_act)
    if mode != "decode" and "moe" not in params:
        # Megatron-SP residual layout. MoE blocks are exempt: the routed
        # all-to-all wants token-sharded layouts and the seq constraint
        # forces extra gathers around the dispatch (measured regression:
        # grok train t_coll 228 -> 359 s with the constraint applied).
        h = hints.constrain_seq(h)
    return h, new_cache, aux


# ----------------------------------------------------------------------------
# stack init
# ----------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig, *, decoder_cross: bool = False) -> dict:
    """Full parameter pytree for the decoder-only (or decoder-side) backbone.
    For encdec archs this also builds the encoder stack."""
    ks = jax.random.split(key, 16)
    p: Dict[str, Any] = {"embed": init_embedding(ks[0], cfg.vocab, cfg.d_model,
                                                 cfg.p_dtype())}
    cross = cfg.encdec or decoder_cross

    # leading dense layers (unrolled)
    p["first"] = [
        _init_block(jax.random.fold_in(ks[1], i), cfg, cfg.layer_kind(i),
                    dense_mlp=True, cross=cross)
        for i in range(cfg.first_k_dense)
    ]

    # scanned superblocks: one stacked param set per pattern position
    def stack_init(pos: int):
        kind = cfg.pattern[pos]
        def one(i):
            return _init_block(jax.random.fold_in(ks[2], pos * 10_000 + i),
                               cfg, kind, cross=cross)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[one(i) for i in range(cfg.n_superblocks)]) \
            if cfg.n_superblocks else None

    p["blocks"] = tuple(stack_init(pos) for pos in range(cfg.pattern_len))

    # remainder (unrolled)
    p["rem"] = [
        _init_block(jax.random.fold_in(ks[3], i), cfg, cfg.pattern[i], cross=cross)
        for i in range(cfg.n_remainder)
    ]

    p["final_norm"] = init_rmsnorm(cfg.d_model, cfg.p_dtype())
    if not cfg.tie_embeddings:
        from repro.models.layers import dense_init
        p["head"] = dense_init(ks[4], (cfg.d_model, cfg.vocab), cfg.p_dtype())

    if cfg.encdec:
        enc_cfg = cfg.replace(encdec=False, pattern=("attn",), first_k_dense=0,
                              n_layers=cfg.n_enc_layers)
        enc_stack = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_init_block(jax.random.fold_in(ks[5], i), enc_cfg, "attn")
              for i in range(cfg.n_enc_layers)])
        enc = {"blocks": (enc_stack,),
               "final_norm": init_rmsnorm(cfg.d_model, cfg.p_dtype())}
        p["encoder"] = enc
    return p


def param_shapes(cfg: ArchConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ----------------------------------------------------------------------------
# cache init
# ----------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               cross_len: int = 0) -> dict:
    """Cache pytree matching the param layout."""
    c: Dict[str, Any] = {}
    c["first"] = [_init_block_cache(cfg, cfg.layer_kind(i), batch, max_len,
                                    cross_len)
                  for i in range(cfg.first_k_dense)]

    def stack_cache(pos: int):
        kind = cfg.pattern[pos]
        if cfg.n_superblocks == 0:
            return None
        one = _init_block_cache(cfg, kind, batch, max_len, cross_len)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_superblocks,) + x.shape), one)

    c["blocks"] = tuple(stack_cache(pos) for pos in range(cfg.pattern_len))
    c["rem"] = [_init_block_cache(cfg, cfg.pattern[i], batch, max_len, cross_len)
                for i in range(cfg.n_remainder)]
    return c


def cache_shapes(cfg: ArchConfig, batch: int, max_len: int, cross_len: int = 0):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, cross_len))


def pad_caches(cfg: ArchConfig, caches, max_len: int):
    """Grow prefill caches along their time axis to ``max_len`` so decode
    steps have slots to write into. Windowed (lattn) caches stay at window
    size (ring buffer; prefill rotates them onto the P % window slot
    layout); recurrent states (mamba2/rglru) have no time axis.
    """
    def pad_axis(x, axis, target):
        cur = x.shape[axis]
        if cur >= target:
            return x
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, target - cur)
        return jnp.pad(x, pads)

    def pad_block(c, kind):
        if c is None:
            return None
        c = dict(c)
        if kind in ("attn", "lattn"):
            if "latent" in c:                       # MLA compressed cache
                c["latent"] = pad_axis(c["latent"], -2, max_len)
                c["k_rope"] = pad_axis(c["k_rope"], -2, max_len)
            else:
                tgt = min(max_len, cfg.window) if (
                    kind == "lattn" and cfg.window) else max_len
                c["k"] = pad_axis(c["k"], -3, tgt)
                c["v"] = pad_axis(c["v"], -3, tgt)
        return c

    out = {"first": [pad_block(c, cfg.layer_kind(i))
                     for i, c in enumerate(caches["first"])],
           "blocks": None, "rem": [pad_block(c, cfg.pattern[i])
                                   for i, c in enumerate(caches["rem"])]}
    if caches["blocks"] is not None:
        out["blocks"] = tuple(
            pad_block(caches["blocks"][pos], cfg.pattern[pos])
            for pos in range(len(caches["blocks"])))
    return out


# ----------------------------------------------------------------------------
# staged backbone execution
# ----------------------------------------------------------------------------

def embed_tokens(params, cfg: ArchConfig, tokens, frontend_embeds=None):
    """tokens: (B, S) int32. For vlm archs, frontend_embeds (B, P, d) replace
    the first P positions (image patches). For audio decode-side, tokens embed
    normally (the encoder consumes frontend embeds directly)."""
    h = embed(params["embed"], tokens).astype(cfg.act_dtype())
    if frontend_embeds is not None and cfg.frontend == "vit_stub":
        P = frontend_embeds.shape[1]
        h = jnp.concatenate([frontend_embeds.astype(h.dtype), h[:, P:]], axis=1)
    return h


def run_layers(params, cfg: ArchConfig, h, lo: int, hi: int, *, mode: str,
               caches=None, step=None, memory=None, causal: bool = True,
               cache_base_sb: int = 0, param_base_sb: int = 0):
    """Run backbone layers [lo, hi). lo/hi must land on superblock boundaries
    (or 0 / n_layers). Returns (h, new_caches_for_segment, aux).

    ``cache_base_sb``: when the caller passes a PRE-SLICED segment cache
    (ee.split_caches output), the superblock index its 'blocks' leaves start
    at — run_layers subtracts it before slicing. ``param_base_sb`` is the
    same offset for a PRE-SLICED param tree (ee.split_params output, a
    stage's resident slice on its own submesh)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {"first": [], "blocks": None, "rem": []}

    # --- leading dense layers ------------------------------------------------
    for i in range(cfg.first_k_dense):
        if lo <= i < hi:
            c = caches["first"][i] if caches else None
            h, nc, a = _apply_block(params["first"][i], cfg, cfg.layer_kind(i), h,
                                    mode=mode, cache=c, step=step, causal=causal,
                                    memory=memory, dense_mlp=True)
            new_caches["first"].append(nc)
            aux = aux + a

    # --- scanned superblocks --------------------------------------------------
    pl = cfg.pattern_len
    s_lo = max(0, (lo - cfg.first_k_dense + pl - 1) // pl)
    s_hi_layer = min(hi, cfg.first_k_dense + cfg.n_superblocks * pl)
    s_hi = max(s_lo, (s_hi_layer - cfg.first_k_dense) // pl)
    if s_hi > s_lo and cfg.n_superblocks:
        p_lo, p_hi = s_lo - param_base_sb, s_hi - param_base_sb
        seg_params = jax.tree.map(lambda x: x[p_lo:p_hi], params["blocks"])
        c_lo, c_hi = s_lo - cache_base_sb, s_hi - cache_base_sb
        seg_caches = (jax.tree.map(lambda x: x[c_lo:c_hi], caches["blocks"])
                      if caches else None)

        def body(carry, xs):
            hh = carry
            bp, bc = xs
            a_tot = jnp.zeros((), jnp.float32)
            ncs = []
            for pos in range(pl):
                c = bc[pos] if bc is not None else None
                hh, nc, a = _apply_block(bp[pos], cfg, cfg.pattern[pos], hh,
                                         mode=mode, cache=c, step=step,
                                         causal=causal, memory=memory)
                ncs.append(nc)
                a_tot = a_tot + a
            return hh, (tuple(ncs) if mode != "train" else None, a_tot)

        if mode == "train":
            body_fn = jax.checkpoint(body)  # remat each superblock
        else:
            body_fn = body
        h, (ncs, aux_s) = jax.lax.scan(body_fn, h, (seg_params, seg_caches))
        new_caches["blocks"] = ncs
        aux = aux + jnp.sum(aux_s)

    # --- remainder -------------------------------------------------------------
    rem_base = cfg.first_k_dense + cfg.n_superblocks * pl
    for i in range(cfg.n_remainder):
        li = rem_base + i
        if lo <= li < hi:
            c = caches["rem"][i] if caches else None
            h, nc, a = _apply_block(params["rem"][i], cfg, cfg.pattern[i], h,
                                    mode=mode, cache=c, step=step, causal=causal,
                                    memory=memory)
            new_caches["rem"].append(nc)
            aux = aux + a
    return h, new_caches, aux


def head(params, cfg: ArchConfig, h):
    """Final norm + unembed -> fp32 logits."""
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        return unembed(params["embed"], h)
    return jnp.einsum("...d,dv->...v", h.astype(jnp.float32),
                      params["head"].astype(jnp.float32))


def encode(params, cfg: ArchConfig, frame_embeds):
    """Encoder stack (audio family). frame_embeds: (B, F, d)."""
    enc = params["encoder"]
    h = frame_embeds.astype(cfg.act_dtype())

    def body(hh, bp):
        hh, _, _ = _apply_block(bp, cfg, "attn", hh, mode="train", causal=False)
        return hh, None

    h, _ = jax.lax.scan(body, h, enc["blocks"][0])
    return rmsnorm(enc["final_norm"], h, cfg.norm_eps)


# ----------------------------------------------------------------------------
# whole-model entry points (single-exit baseline; EE staging lives in
# core/early_exit.py and reuses run_layers with slicing)
# ----------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, tokens, *, frontend_embeds=None):
    """Training/eval forward to final logits. Returns (logits, aux)."""
    memory = None
    if cfg.encdec:
        memory = encode(params, cfg, frontend_embeds)
        frontend_embeds = None
    h = embed_tokens(params, cfg, tokens, frontend_embeds)
    h, _, aux = run_layers(params, cfg, h, 0, cfg.n_layers, mode="train",
                           memory=memory)
    return head(params, cfg, h), aux


def forward_hidden(params, cfg: ArchConfig, tokens, *, frontend_embeds=None):
    """Forward returning final hidden states (B, S, d) — used by losses that
    chunk the unembedding."""
    memory = None
    if cfg.encdec:
        memory = encode(params, cfg, frontend_embeds)
        frontend_embeds = None
    h = embed_tokens(params, cfg, tokens, frontend_embeds)
    h, _, aux = run_layers(params, cfg, h, 0, cfg.n_layers, mode="train",
                           memory=memory)
    return h, aux


def prefill(params, cfg: ArchConfig, tokens, *, frontend_embeds=None,
            max_len: int = 0):
    """Returns (last_logits (B, V), caches, memory). ``max_len`` > seq pads
    the caches so subsequent decode steps have write slots."""
    memory = None
    if cfg.encdec:
        memory = encode(params, cfg, frontend_embeds)
        frontend_embeds = None
    h = embed_tokens(params, cfg, tokens, frontend_embeds)
    h, caches, _ = run_layers(params, cfg, h, 0, cfg.n_layers, mode="prefill",
                              memory=memory)
    if max_len > tokens.shape[1]:
        caches = pad_caches(cfg, caches, max_len)
    return head(params, cfg, h[:, -1]), caches, memory


def decode_step(params, cfg: ArchConfig, token, caches, step, *, memory=None):
    """token: (B, 1) int32; step: scalar absolute position.
    Returns (logits (B, V), new_caches)."""
    h = embed_tokens(params, cfg, token)
    h, new_caches, _ = run_layers(params, cfg, h, 0, cfg.n_layers, mode="decode",
                                  caches=caches, step=step, memory=memory)
    return head(params, cfg, h[:, 0]), new_caches
