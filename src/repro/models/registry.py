"""Architecture registry: --arch <id> resolution for all entry points."""
from __future__ import annotations

from repro.configs.archs import ARCHS, SHAPES, shape_applicable, smoke_config
from repro.models.cnn import CNN_REGISTRY
from repro.models.config import ArchConfig


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke(name: str) -> ArchConfig:
    return smoke_config(get_arch(name))


def list_archs():
    return sorted(ARCHS)


def list_cells():
    """All (arch, shape) cells with applicability."""
    cells = []
    for a in sorted(ARCHS):
        for s in SHAPES:
            ok, why = shape_applicable(ARCHS[a], s)
            cells.append((a, s, ok, why))
    return cells
