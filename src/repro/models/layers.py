"""Common neural primitives, pure JAX (no flax).

Param convention: every module is a pair of functions
  init_<mod>(key, cfg, ...) -> params (pytree of jnp arrays)
  <mod>(params, x, ...)     -> y
Params are plain dicts so they stack cleanly along a leading layer axis for
``lax.scan`` over layers.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# initializers
# ----------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (the MaxText/T5 default)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv   # (..., S, D/2)
    if x.ndim == ang.ndim + 1:                        # (..., S, H, D): broadcast heads
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ----------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wo": dense_init(k2, (d_ff, d_model), dtype)}
    if act in ("swiglu", "geglu"):
        p["wi_gate"] = dense_init(k1, (d_model, d_ff), dtype)
        p["wi_up"] = dense_init(k3, (d_model, d_ff), dtype)
    else:
        p["wi"] = dense_init(k1, (d_model, d_ff), dtype)
    return p


def mlp(params, x, act: str):
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, params["wi_up"])
        nl = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        h = nl(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("...d,df->...f", x, params["wi"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["wo"])


def mlp_flops(d_model: int, d_ff: int, act: str) -> int:
    n_mats = 3 if act in ("swiglu", "geglu") else 2
    return 2 * n_mats * d_model * d_ff


# ----------------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype) -> dict:
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, h):
    """Tied unembedding: h @ table.T -> logits (fp32)."""
    return jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                      params["table"].astype(jnp.float32))


# ----------------------------------------------------------------------------
# Chunked causal attention core (pure JAX flash-style; the Pallas kernel in
# kernels/flash_attention mirrors this block structure for TPU).
# ----------------------------------------------------------------------------

def blocked_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                      q_block: int = 256, kv_block: int = 512,
                      softcap: Optional[float] = None,
                      q_offset=0):
    """Memory-bounded attention (the jnp mirror of the Pallas flash kernel).

    q: (B, Sq, H, D); k, v: (B, Sk, KH, D) with H % KH == 0.
    Three-level scan — kv-head groups, then query blocks, then kv blocks with
    an online softmax — so every loop-body tensor is a VMEM-sized tile (this
    is what the Pallas kernel enforces with BlockSpecs on TPU; the scan
    structure makes the lowered HLO's working set match it). ``q_offset`` is
    the absolute position of q[0] (sequence-parallel shards / decode
    continuation), int or traced scalar.
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    kb = min(kv_block, Sk)
    # adaptive q tile: biggest block keeping the (B, G, qb, kb) f32 score
    # tile within a VMEM budget — fewer K/V re-reads for small-G (MHA) archs
    budget = 4 * 1024 * 1024
    qb_fit = max(budget // (B * G * kb * 4), 1)
    qb_fit = 1 << (qb_fit.bit_length() - 1)            # floor pow2
    qb = min(max(q_block, qb_fit), 1024, Sq)
    # pad to multiples
    pad_q = (-Sq) % qb
    pad_k = (-Sk) % kb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // qb, k.shape[1] // kb

    # head-group-major layouts: one kv head's tiles per outer step
    qr = q.reshape(B, nq, qb, KH, G, D).transpose(3, 1, 0, 2, 4, 5)
    #    (KH, nq, B, qb, G, D)
    kr = k.reshape(B, nk, kb, KH, D).transpose(3, 0, 1, 2, 4)   # (KH,B,nk,kb,D)
    vr = v.reshape(B, nk, kb, KH, D).transpose(3, 0, 1, 2, 4)
    q_pos = q_offset + jnp.arange(nq * qb).reshape(nq, qb)
    k_pos = jnp.arange(nk * kb).reshape(nk, kb)
    k_valid = (jnp.arange(nk * kb) < Sk).reshape(nk, kb)

    def h_step(_, hi):
        qh, kh, vh = hi                    # (nq,B,qb,G,D), (B,nk,kb,D)
        kh_t = kh.transpose(1, 0, 2, 3)    # (nk, B, kb, D)
        vh_t = vh.transpose(1, 0, 2, 3)

        def q_step(_, qi):
            qblk, qp = qi                  # (B, qb, G, D), (qb,)

            def kv_step(carry, ki):
                m, l, acc = carry
                kblk, vblk, kp, kval = ki  # (B, kb, D), (kb,)
                # inputs stay in their storage dtype (bf16 streams on TPU);
                # the MXU accumulates in f32 (preferred_element_type)
                s = jnp.einsum("bqgd,bkd->bgqk", qblk, kblk,
                               preferred_element_type=jnp.float32) * scale
                if softcap is not None:
                    s = softcap * jnp.tanh(s / softcap)
                mask = kval[None, :]
                if causal:
                    mask = mask & (qp[:, None] >= kp[None, :])
                if window is not None:
                    mask = mask & (qp[:, None] - kp[None, :] < window)
                s = jnp.where(mask[None, None], s, -jnp.inf)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
                p = jnp.exp(s - m_safe[..., None])
                p = jnp.where(mask[None, None], p, 0.0)
                corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bgqk,bkd->bgqd", p.astype(vblk.dtype), vblk,
                    preferred_element_type=jnp.float32)
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((B, G, qb), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((B, G, qb), jnp.float32)
            a0 = jnp.zeros((B, G, qb, D), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          (kh_t, vh_t, k_pos, k_valid))
            out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B, G, qb, D)
            return None, out.transpose(0, 2, 1, 3)          # (B, qb, G, D)

        _, blocks = jax.lax.scan(q_step, None, (qh, q_pos))
        return None, blocks                                 # (nq, B, qb, G, D)

    _, hb = jax.lax.scan(h_step, None, (qr, kr, vr))        # (KH,nq,B,qb,G,D)
    out = hb.transpose(2, 1, 3, 0, 4, 5).reshape(B, nq * qb, H, D)
    return out[:, :Sq].astype(q.dtype)


# ----------------------------------------------------------------------------
# Differentiable flash attention (custom VJP): the backward recomputes the
# probability blocks from (q, k, v, L) instead of letting AD stack every
# (nq, nk, B, G, qb, kb) p-block as a residual — THE dominant HBM term of
# naive-AD attention training (403 MB/layer for qwen2-1.5b train_4k).
# ----------------------------------------------------------------------------

def _flash_fwd_stats(q, k, v, causal, window, q_offset, qb, kb):
    """blocked_attention forward that also returns the per-row logsumexp
    L = m + log(l), shaped (B, Sq, H). Same 3-level scan structure."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    nq, nk = Sq // qb, -(-Sk // kb)
    pad_k = nk * kb - Sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qr = q.reshape(B, nq, qb, KH, G, D).transpose(3, 1, 0, 2, 4, 5)
    kr = k.reshape(B, nk, kb, KH, D).transpose(3, 0, 1, 2, 4)
    vr = v.reshape(B, nk, kb, KH, D).transpose(3, 0, 1, 2, 4)
    q_pos = q_offset + jnp.arange(nq * qb).reshape(nq, qb)
    k_pos = jnp.arange(nk * kb).reshape(nk, kb)
    k_valid = (jnp.arange(nk * kb) < Sk).reshape(nk, kb)

    def h_step(_, hi):
        qh, kh, vh = hi
        kh_t = kh.transpose(1, 0, 2, 3)
        vh_t = vh.transpose(1, 0, 2, 3)

        def q_step(_, qi):
            qblk, qp = qi

            def kv_step(carry, ki):
                m, l, acc = carry
                kblk, vblk, kp, kval = ki
                s = jnp.einsum("bqgd,bkd->bgqk", qblk, kblk,
                               preferred_element_type=jnp.float32) * scale
                mask = kval[None, :]
                if causal:
                    mask = mask & (qp[:, None] >= kp[None, :])
                if window is not None:
                    mask = mask & (qp[:, None] - kp[None, :] < window)
                s = jnp.where(mask[None, None], s, -jnp.inf)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
                p = jnp.exp(s - m_safe[..., None])
                p = jnp.where(mask[None, None], p, 0.0)
                corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bgqk,bkd->bgqd", p.astype(vblk.dtype), vblk,
                    preferred_element_type=jnp.float32)
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((B, G, qb), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((B, G, qb), jnp.float32)
            a0 = jnp.zeros((B, G, qb, D), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          (kh_t, vh_t, k_pos, k_valid))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            m_s = jnp.where(jnp.isneginf(m), 0.0, m)
            L = m_s + jnp.log(jnp.maximum(l, 1e-30))     # (B, G, qb)
            return None, (out.transpose(0, 2, 1, 3), L.transpose(0, 2, 1))

        _, (blocks, Ls) = jax.lax.scan(q_step, None, (qh, q_pos))
        return None, (blocks, Ls)

    _, (hb, hL) = jax.lax.scan(h_step, None, (qr, kr, vr))
    out = hb.transpose(2, 1, 3, 0, 4, 5).reshape(B, nq * qb, H, D)
    L = hL.transpose(2, 1, 3, 0, 4).reshape(B, nq * qb, H)
    return out.astype(q.dtype), L


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def flash_attention_diff(q, k, v, q_offset, causal: bool = True,
                         window: Optional[int] = None, q_block: int = 256,
                         kv_block: int = 512):
    """Differentiable flash attention. Same semantics as blocked_attention
    (softcap unsupported — callers keep the plain path for softcap archs)."""
    return blocked_attention(q, k, v, causal=causal, window=window,
                             q_block=q_block, kv_block=kv_block,
                             q_offset=q_offset)


def _fad_fwd(q, k, v, q_offset, causal, window, q_block, kv_block):
    B, Sq, H, D = q.shape
    G = H // k.shape[2]
    kb = min(kv_block, k.shape[1])
    budget = 4 * 1024 * 1024
    qb_fit = max(budget // (max(B, 1) * max(G, 1) * kb * 4), 1)
    qb_fit = 1 << (qb_fit.bit_length() - 1)
    qb = min(max(q_block, qb_fit), 1024, Sq)
    pad_q = (-Sq) % qb
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    out, L = _flash_fwd_stats(qp, k, v, causal, window, q_offset, qb, kb)
    out = out[:, :Sq]
    L = L[:, :Sq]
    return out, (q, k, v, out, L, q_offset)


def _fad_bwd(causal, window, q_block, kv_block, res, dout):
    q, k, v, out, L, q_offset = res
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    # row-block backward over the full Sk: size qb so the (B, G, qb, Sk)
    # s/p/ds tiles stay VMEM-resident
    budget = 4 * 1024 * 1024
    qb_fit = max(budget // (max(B, 1) * max(G, 1) * Sk * 4), 1)
    qb = min(max(1 << (qb_fit.bit_length() - 1), 16), 128, Sq)
    pad_q = (-Sq) % qb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        out = jnp.pad(out, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        dout = jnp.pad(dout, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        L = jnp.pad(L, ((0, 0), (0, pad_q), (0, 0)))
    nq = q.shape[1] // qb
    # D_i = rowsum(dO * O) (the softmax-jacobian diagonal term)
    Drow = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)

    qr = q.reshape(B, nq, qb, KH, G, D).transpose(3, 1, 0, 2, 4, 5)
    dor = dout.reshape(B, nq, qb, KH, G, D).transpose(3, 1, 0, 2, 4, 5)
    Lr = L.reshape(B, nq, qb, KH, G).transpose(3, 1, 0, 2, 4)
    Dr = Drow.reshape(B, nq, qb, KH, G).transpose(3, 1, 0, 2, 4)
    kr = k.transpose(2, 0, 1, 3)                      # (KH, B, Sk, D)
    vr = v.transpose(2, 0, 1, 3)
    q_pos = q_offset + jnp.arange(nq * qb).reshape(nq, qb)
    k_pos = jnp.arange(Sk)

    def h_step(_, hi):
        qh, doh, Lh, Dh, kh, vh = hi      # per kv-head

        def q_step(carry, qi):
            dk_acc, dv_acc = carry         # (B, Sk, D) f32
            qblk, doblk, Lblk, Dblk, qp = qi
            s = jnp.einsum("bqgd,bkd->bgqk", qblk, kh,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qb, Sk), bool)
            if causal:
                mask = mask & (qp[:, None] >= k_pos[None, :])
            if window is not None:
                mask = mask & (qp[:, None] - k_pos[None, :] < window)
            Lg = Lblk.transpose(0, 2, 1)[..., None]     # (B, G, qb, 1)
            p = jnp.where(mask[None, None], jnp.exp(s - Lg), 0.0)
            dv_acc = dv_acc + jnp.einsum(
                "bgqk,bqgd->bkd", p.astype(doblk.dtype), doblk,
                preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqgd,bkd->bgqk", doblk, vh,
                            preferred_element_type=jnp.float32)
            Dg = Dblk.transpose(0, 2, 1)[..., None]
            ds = p * (dp - Dg) * scale
            dk_acc = dk_acc + jnp.einsum(
                "bgqk,bqgd->bkd", ds.astype(qblk.dtype), qblk,
                preferred_element_type=jnp.float32)
            dq_blk = jnp.einsum("bgqk,bkd->bqgd", ds.astype(kh.dtype), kh,
                                preferred_element_type=jnp.float32)
            return (dk_acc, dv_acc), dq_blk

        z = jnp.zeros((B, Sk, D), jnp.float32)
        (dk_h, dv_h), dq_blocks = jax.lax.scan(
            q_step, (z, z), (qh, doh, Lh, Dh, q_pos))
        return None, (dq_blocks, dk_h, dv_h)

    _, (dqb, dkh, dvh) = jax.lax.scan(
        h_step, None, (qr, dor, Lr, Dr, kr, vr))
    dq = dqb.transpose(2, 1, 3, 0, 4, 5).reshape(B, nq * qb, H, D)[:, :Sq]
    dk = dkh.transpose(1, 2, 0, 3)                    # (B, Sk, KH, D)
    dv = dvh.transpose(1, 2, 0, 3)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None)


flash_attention_diff.defvjp(_fad_fwd, _fad_bwd)


def masked_decode_attention(q, k_cache, v_cache, valid, *,
                            softcap: Optional[float] = None):
    """The ONE masked single-step attention core every decode path shares.

    q: (B, H, D); caches: (B, Smax, KH, D); valid: (B, Smax) bool — which
    cache positions participate. Callers build ``valid`` from their own
    bookkeeping (prefix length, sliding window over a ring buffer, paged
    block tables); the attention math itself is identical, which is what
    makes dense/windowed/paged parity *bitwise* rather than approximate.
    """
    B, Smax, KH, D = k_cache.shape
    H = q.shape[1]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qf = q.reshape(B, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: Optional[int] = None,
                     softcap: Optional[float] = None):
    """Single-step attention against a cache.

    q: (B, H, D); caches: (B, Smax, KH, D); cache_len: (B,) valid lengths
    (the new token's k/v must already be written at cache_len-1).
    """
    Smax = k_cache.shape[1]
    pos = jnp.arange(Smax)[None, :]                        # (1, Smax)
    valid = pos < cache_len[:, None]
    if window is not None:
        valid = valid & (pos >= cache_len[:, None] - window)
    return masked_decode_attention(q, k_cache, v_cache, valid,
                                   softcap=softcap)
