"""Architecture configuration.

One frozen dataclass describes every assigned architecture (plus the paper's
own CNNs, which live in models/cnn.py with their own small config). The
config is the single source of truth consumed by the model builder, the
sharding planner, the ATHEENA DSE cost model and the dry-run input specs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD mixer."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    chunk: int = 256
    conv_kernel: int = 4


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block."""
    lru_width: int = 0            # 0 => d_model
    conv_kernel: int = 4
    c: float = 8.0                # the fixed decay sharpness constant


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention variants
    head_dim: Optional[int] = None      # None => d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None        # sliding window for "lattn" blocks
    logit_softcap: Optional[float] = None

    # block pattern, repeated to fill n_layers. remainder uses the prefix.
    pattern: Tuple[str, ...] = ("attn",)   # attn | lattn | mamba2 | rglru
    mlp_act: str = "swiglu"                # swiglu | gelu
    first_k_dense: int = 0                 # MoE archs: leading dense-MLP layers
    dense_ff: Optional[int] = None         # d_ff of those dense layers

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # encoder-decoder (audio family)
    encdec: bool = False
    n_enc_layers: int = 0

    # modality frontend stubs: vlm/audio backbones receive precomputed embeds
    frontend: Optional[str] = None      # "vit_stub" | "speech_stub"
    n_frontend_tokens: int = 0

    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # early exit: backbone layer indices after which an exit head attaches.
    # () means the arch default (single exit at n_layers // 2) is used when an
    # EarlyExitModel is requested.
    exit_layers: Tuple[int, ...] = ()

    # dtypes
    dtype: str = "bfloat16"            # activation dtype
    param_dtype: str = "bfloat16"

    # sub-quadratic? governs long_500k applicability
    subquadratic: bool = False

    def act_dtype(self):
        return jnp.dtype(self.dtype)

    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- layer plan helpers -------------------------------------------------
    @property
    def pattern_len(self) -> int:
        return len(self.pattern)

    @property
    def n_scan_layers(self) -> int:
        """Layers covered by the repeating-pattern scan (after first_k_dense)."""
        return self.n_layers - self.first_k_dense

    @property
    def n_superblocks(self) -> int:
        return self.n_scan_layers // self.pattern_len

    @property
    def n_remainder(self) -> int:
        return self.n_scan_layers - self.n_superblocks * self.pattern_len

    def layer_kind(self, i: int) -> str:
        """Block kind of backbone layer index i (0-based, over all n_layers)."""
        if i < self.first_k_dense:
            return "attn"   # leading dense layers are plain attn+mlp
        return self.pattern[(i - self.first_k_dense) % self.pattern_len]

    def default_exit_layers(self) -> Tuple[int, ...]:
        if self.exit_layers:
            return self.exit_layers
        # default: one exit at the superblock boundary nearest half depth
        half = self.n_layers // 2
        pl = self.pattern_len
        k = self.first_k_dense + max(pl, ((half - self.first_k_dense) // pl) * pl)
        return (min(k, self.n_layers - pl),)
