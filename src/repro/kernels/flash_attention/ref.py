"""Pure-jnp oracle for blocked causal attention (fp32 softmax).

Semantics contract shared with the Pallas kernel:
  - q: (B, H, S, D), k/v: (B, KH, S, D) with H % KH == 0 (GQA: query head h
    attends kv head h * KH // H).
  - scores scaled by D**-0.5, causal mask (q_pos >= kv_pos), optional local
    window (q_pos - kv_pos < window), softmax in fp32, output cast back to
    q.dtype.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
            causal: bool = True, window: Optional[int] = None) -> jnp.ndarray:
    B, H, S, D = q.shape
    KH = k.shape[1]
    rep = H // KH
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= (qi - ki) < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
