"""Jit'd public wrapper for blocked flash attention.

Handles sequence padding to tile multiples and backend dispatch (interpret
on CPU for validation, compiled Pallas on TPU). Layout contract is
(B, H, S, D) — the models' (B, S, H, D) tensors are transposed here once.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import mha_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "use_pallas"))
def flash_attention_op(q, k, v, *, causal: bool = True,
                       window: Optional[int] = None, use_pallas: bool = True):
    """q: (B, S, H, D); k, v: (B, S, KH, D) — model layout. Returns same."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    S = qt.shape[2]
    if use_pallas:
        pad = (-S) % 128 if S > 128 else 0
        if pad:
            qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
            kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
            vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        o = flash_attention_pallas(qt, kt, vt, causal=causal, window=window,
                                   interpret=_on_cpu())
        o = o[:, :, :S]
    else:
        o = mha_ref(qt, kt, vt, causal=causal, window=window)
    return o.transpose(0, 2, 1, 3)
