"""Pallas TPU kernel: blocked causal (optionally windowed) flash attention.

The 32k-prefill is the dominant FLOP hot-spot of every attention arch in the
pool; this kernel keeps the (bq, bk) score tile resident in VMEM, carries the
online-softmax (m, l, acc) triple across kv tiles in VMEM scratch, and never
materializes the (S, S) score matrix in HBM — the same online (m, l) idiom as
the exit-decision kernel, which is the paper's Eq. (4) machinery.

TPU adaptation notes (vs. the CUDA flash-attention formulation):
  - tile shapes default to (128, 128): the MXU is a 128x128 systolic array
    and the lane dimension is 128, so both matmuls in the inner loop hit
    hardware-native shapes;
  - the kv axis is the innermost sequential grid dim; causal + window bounds
    prune whole tiles via @pl.when (the TPU grid is sequential, so a pruned
    tile costs control flow only — the block-skip analogue of warp-level
    early-out);
  - GQA is folded into the BlockSpec index_map (kv head = h * KH // H), so
    no repeated K/V is ever written to HBM.

Grid: (B, H, S/bq, S/bk).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, off_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, seq_len: int, block_q: int, block_k: int,
                  n_k_blocks: int, causal: bool, window: Optional[int]):
    i = pl.program_id(2)          # q tile
    j = pl.program_id(3)          # kv tile

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile-level causal/window pruning: run only tiles that intersect the
    # mask. ``off`` = absolute position of q row 0 (sequence-parallel shards
    # / chunked prefill pass their shard offset).
    off = off_ref[0, 0]
    q_lo, q_hi = i * block_q + off, i * block_q + block_q - 1 + off
    k_lo = j * block_k
    run = True
    if causal:
        run = jnp.asarray(k_lo <= q_hi)
    if window is not None:
        run = jnp.logical_and(run, q_lo - (k_lo + block_k - 1) < window)

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        qi = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ki = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = ki < seq_len
        if causal:
            mask &= qi >= ki
        if window is not None:
            mask &= (qi - ki) < window
        s = jnp.where(mask, s, NEG_INF)

        m_old = m_ref[...]                                   # (bq, 1)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
        # rows whose tiles are all masked keep m = -inf; guard the exp
        m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_old == NEG_INF, 0.0, jnp.exp(m_old - m_safe))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        # zero OOB kv rows: 0 * garbage would still poison the p @ v matmul
        kv_valid = (k_lo + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
                    < seq_len)
        v = jnp.where(kv_valid, v, 0.0)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(j == n_k_blocks - 1)
    def _():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                      # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention_pallas(q, k, v, q_offset=0, *, causal: bool = True,
                           window: Optional[int] = None, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, KH, Sk, D). Returns (B, H, Sq, D) in
    q.dtype. ``q_offset`` (int or traced scalar) is the absolute position of
    q[:, :, 0] — sequence-parallel shards pass shard_index * Sq."""
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    assert H % KH == 0 and k.shape == v.shape
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    n_q = pl.cdiv(Sq, bq)
    n_k = pl.cdiv(Sk, bk)

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, seq_len=Sk, block_q=bq, block_k=bk,
        n_k_blocks=n_k, causal=causal, window=window)

    grp = H // KH
    off = jnp.full((1, 1), q_offset, jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // grp, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // grp, j, 0)),
            pl.BlockSpec((1, 1), lambda b, h, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),                # running max
            pltpu.VMEM((bq, 1), jnp.float32),                # running sum
            pltpu.VMEM((bq, D), jnp.float32),                # output accum
        ],
        interpret=interpret,
    )(q, k, v, off)
