from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import mha_ref

__all__ = ["flash_attention_pallas", "flash_attention_op", "mha_ref"]
