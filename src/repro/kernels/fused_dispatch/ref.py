"""Pure-jnp oracle for the fused dispatch kernel: exit decision +
conditional-buffer compaction + ring enqueue in ONE traced program.

Semantics contract (the composition it is bitwise-equal to, enforced by
``tests/test_fused_dispatch.py``):

    exit_mask, pred, conf = exit_decision_ref(logits, c_thr)      (Eq. 4)
    hard                  = active & ~exit_mask
    slab, src, n_hard     = gather_compact_ref(payload, hard, B)  (§III-C.2)
    ring'                 = _ring_enqueue_range(ring, slab,
                                sample_ids[src], 0, n_hard)       (Fig. 7)

but with no intermediate slab ever materialized: each payload leaf's hard
rows are gathered straight into the ring slab at ``(head + count + i) %
size`` offsets, clipped to the ring's free space (``n_enq = min(n_hard,
size - count)``). Rows ``[n_enq, n_hard)`` are the caller's overflow — the
backpressure chunk/stall loop re-materializes them from ``src`` (rare, and
exactly the composed chain, so equivalence holds through overflow too).

Returns ``(ring', exit_mask, pred, conf, src, n_hard)`` where ``src`` is
the stable compaction vector: ``src[i]`` is the original row feeding slab
lane ``i`` for ``i < n_hard``, ``-1`` beyond (identical to
``gather_compact_ref``'s ``slab_ids`` at ``capacity = B``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.exit_decision.ref import exit_decision_ref


def compact_src(hard_mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable stream-compaction source vector: ``src`` (B,) int32 with the
    original row index per compacted lane (-1 pad past ``n_hard``). The
    same prefix-sum partition as the gather_compact kernels, at capacity =
    B — integer math, so every backend agrees bitwise."""
    b = hard_mask.shape[0]
    hard = hard_mask.astype(jnp.int32)
    n_hard = jnp.sum(hard)
    pos_hard = jnp.cumsum(hard) - 1
    pos_easy = jnp.cumsum(1 - hard) - 1
    slot = jnp.where(hard_mask, pos_hard, n_hard + pos_easy)
    perm = jnp.zeros((b,), jnp.int32).at[slot].set(
        jnp.arange(b, dtype=jnp.int32))
    valid = jnp.arange(b) < n_hard
    src = jnp.where(valid, perm, -1).astype(jnp.int32)
    return src, n_hard


def ring_offsets(src: jnp.ndarray, n_hard, head, count, size: int):
    """Ring write offsets for the compacted lanes: lane ``i`` lands at
    ``(head + count + i) % size`` for ``i < n_enq``; lanes past the free
    space map out of bounds (``size``) and drop on scatter."""
    b = src.shape[0]
    free = jnp.int32(size) - count
    n_enq = jnp.minimum(n_hard, free).astype(jnp.int32)
    lanes = jnp.arange(b, dtype=jnp.int32)
    idx = (head + count + lanes) % size
    idx = jnp.where(lanes < n_enq, idx, size)
    return idx, n_enq


def fused_dispatch_ref(logits: jnp.ndarray, active: Optional[jnp.ndarray],
                       sample_ids: jnp.ndarray, payload, ring: dict, c_thr):
    """logits (B, V); active (B,) bool or None (= all rows eligible);
    sample_ids (B,) int32; payload pytree of (B, *row) leaves matching
    ring['data'] rows; ring as ``ring_init`` lays it out. See module doc
    for the returned tuple."""
    exit_mask, pred, conf = exit_decision_ref(logits, c_thr)
    hard = ~exit_mask if active is None else active & ~exit_mask
    src, n_hard = compact_src(hard)
    size = ring["ids"].shape[0]
    idx, n_enq = ring_offsets(src, n_hard, ring["head"], ring["count"], size)
    take = jnp.maximum(src, 0)
    data = jax.tree.map(
        lambda d, p: d.at[idx].set(jnp.take(p, take, axis=0), mode="drop"),
        ring["data"], payload)
    ids = ring["ids"].at[idx].set(jnp.take(sample_ids, take), mode="drop")
    new_ring = {"data": data, "ids": ids, "head": ring["head"],
                "count": ring["count"] + n_enq}
    return new_ring, exit_mask, pred, conf, src, n_hard
