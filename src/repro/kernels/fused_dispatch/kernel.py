"""Pallas TPU kernel: fused dispatch — Exit Decision + Conditional Buffer
+ ring enqueue in one HBM pass over each operand.

Composition of two streamed kernels plus O(B + size) integer cursor math:

  1. ``exit_decision_pallas`` reads the stage-1 logits ONCE and emits
     (exit_mask, pred, conf) — the Eq. (4) online reduction.
  2. The compaction permutation and the ring write-cursor map are a few
     prefix sums over (B,)/(size,) int vectors — lowered inline by XLA,
     never worth a kernel of their own.
  3. ``_scatter_merge_kernel`` per payload leaf: streams the leaf's ring
     slab feature-tile by feature-tile, overwriting exactly the slots the
     cursor map claims with rows gathered from the payload. The ring slab
     is aliased input→output (``input_output_aliases``), so the slab is
     read+written in place in one pass and the easy rows are never copied —
     the Conditional Buffer's address-invalidation trick (§III-C.2), with
     the buffer being the inter-stage ring itself rather than a slab that
     XLA would scatter into the ring afterwards.

The slot→source map ``src_ring`` (size,) is precomputed in SMEM: ring slot
``r`` takes payload row ``src[(r - head - count) % size]`` iff that lane is
below ``n_enq = min(n_hard, free)``, else keeps its current bytes. Each
slot is claimed at most once because ``n_enq <= size``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.exit_decision.kernel import exit_decision_pallas
from repro.kernels.fused_dispatch.ref import compact_src


def _scatter_merge_kernel(srcmap_ref, x_ref, ring_ref, out_ref):
    sr = srcmap_ref[...]                                   # (size,) SMEM
    rows = jnp.take(x_ref[...], jnp.maximum(sr, 0), axis=0)
    out_ref[...] = jnp.where((sr >= 0)[:, None], rows.astype(out_ref.dtype),
                             ring_ref[...])


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def _scatter_merge(src_map, x, ring_leaf, *, block_f: int = 2048,
                   interpret: bool = False):
    """x: (B, F) payload leaf; ring_leaf: (size, F). Writes row
    ``x[src_map[r]]`` into slot r where ``src_map[r] >= 0``; other slots
    keep their bytes. Ring slab aliased in place."""
    size, F = ring_leaf.shape
    bf = min(block_f, F)
    n_f = pl.cdiv(F, bf)
    return pl.pallas_call(
        _scatter_merge_kernel,
        grid=(n_f,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # src_map (size,)
            pl.BlockSpec((x.shape[0], bf), lambda j: (0, j)),
            pl.BlockSpec((size, bf), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((size, bf), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((size, F), ring_leaf.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(src_map, x, ring_leaf)


def fused_dispatch_pallas(logits, active, sample_ids, payload, ring, c_thr,
                          *, interpret: bool = False):
    """Same contract as ``fused_dispatch_ref`` (see ref.py module doc);
    kernel-body backend. Traceable — jit at the dispatch layer."""
    exit_mask, pred, conf = exit_decision_pallas(logits, c_thr,
                                                 interpret=interpret)
    hard = ~exit_mask if active is None else active & ~exit_mask
    src, n_hard = compact_src(hard)

    b = src.shape[0]
    size = ring["ids"].shape[0]
    head, count = ring["head"], ring["count"]
    free = jnp.int32(size) - count
    n_enq = jnp.minimum(n_hard, free).astype(jnp.int32)
    # slot -> payload row map: invert lane = (r - head - count) % size
    slots = jnp.arange(size, dtype=jnp.int32)
    lane = (slots - head - count) % size
    src_map = jnp.where(
        lane < n_enq,
        jnp.take(src, jnp.minimum(lane, b - 1)), -1).astype(jnp.int32)

    def merge(d, p):
        feat = d.shape[1:]
        F = math.prod(feat)
        if F == 0:                       # degenerate leaf: nothing to move
            return d
        out = _scatter_merge(src_map, p.reshape(b, F), d.reshape(size, F),
                             interpret=interpret)
        return out.reshape((size,) + feat)

    with jax.named_scope("fused_dispatch_scatter_merge"):
        data = jax.tree.map(merge, ring["data"], payload)
        ids = merge(ring["ids"][:, None], sample_ids[:, None])[:, 0]
    new_ring = {"data": data, "ids": ids, "head": head, "count": count + n_enq}
    return new_ring, exit_mask, pred, conf, src, n_hard
