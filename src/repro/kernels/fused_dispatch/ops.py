"""Back-compat wrapper for the fused dispatch kernel.

Delegates to the dispatch layer (kernels/dispatch.py). ``use_pallas=True``
exercises the Pallas kernel body (interpreted on CPU, compiled on TPU);
``use_pallas=False`` runs the pure-jnp oracle. The serving hot path should
call ``dispatch.fused_dispatch_op`` instead.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import dispatch


def fused_dispatch_op(logits: jnp.ndarray, active: Optional[jnp.ndarray],
                      sample_ids: jnp.ndarray, payload, ring: dict, c_thr,
                      *, use_pallas: bool = True):
    """See ``fused_dispatch_ref`` for the contract. Returns
    (ring', exit_mask, pred, conf, src, n_hard)."""
    backend = "pallas" if use_pallas else "ref"
    return dispatch.fused_dispatch_op(logits, active, sample_ids, payload,
                                      ring, c_thr, backend=backend)
