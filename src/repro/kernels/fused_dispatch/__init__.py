from repro.kernels.fused_dispatch.kernel import fused_dispatch_pallas
from repro.kernels.fused_dispatch.ops import fused_dispatch_op
from repro.kernels.fused_dispatch.ref import (compact_src, fused_dispatch_ref,
                                              ring_offsets)

__all__ = ["fused_dispatch_pallas", "fused_dispatch_op", "fused_dispatch_ref",
           "compact_src", "ring_offsets"]
