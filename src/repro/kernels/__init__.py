"""Pallas TPU kernels for the paper's compute hot-spots.

exit_decision   — the Exit Decision layer (paper §III-C.1, Eq. 4) as one
                  fused online reduction over the class axis.
flash_attention — blocked causal attention; the 32k-prefill FLOP hot-spot.
gather_compact  — stream compaction; the Conditional Buffer (§III-C.2).

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with CPU-interpret dispatch) and ref.py (pure-jnp oracle used by the
allclose sweeps in tests/).
"""
from repro.kernels.exit_decision import exit_decision_op
from repro.kernels.flash_attention import flash_attention_op
from repro.kernels.gather_compact import gather_compact_op

__all__ = ["exit_decision_op", "flash_attention_op", "gather_compact_op"]
