"""Pallas TPU kernels for the paper's compute hot-spots.

exit_decision   — the Exit Decision layer (paper §III-C.1, Eq. 4) as one
                  fused online reduction over the class axis.
flash_attention — blocked causal attention; the 32k-prefill FLOP hot-spot.
gather_compact  — stream compaction; the Conditional Buffer (§III-C.2).
fused_dispatch  — decision + compaction + ring enqueue in one HBM pass;
                  the whole §III-C dispatch stage as a single program.
paged_attention — block-table paged KV-cache gather + tail-page append in
                  one launch; the decode-cache memory analogue of the
                  exit cascade's "pay only for what runs".

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with CPU-interpret dispatch) and ref.py (pure-jnp oracle used by the
allclose sweeps in tests/).

``dispatch`` is the runtime-facing layer: it selects compiled Pallas on TPU
and the fast jnp reference (or, on request, the interpreted kernel body) on
CPU, so the serving hot path never pays the Pallas-interpreter tax off-TPU.
The per-kernel ``*_op`` wrappers re-exported here keep their historical
``use_pallas`` switch for the parity tests.
"""
from repro.kernels import dispatch
from repro.kernels.exit_decision import exit_decision_op
from repro.kernels.flash_attention import flash_attention_op
from repro.kernels.fused_dispatch import fused_dispatch_op
from repro.kernels.gather_compact import gather_compact_op
from repro.kernels.paged_attention import paged_gather_append_op

__all__ = ["dispatch", "exit_decision_op", "flash_attention_op",
           "fused_dispatch_op", "gather_compact_op",
           "paged_gather_append_op"]
