from repro.kernels.gather_compact.kernel import gather_compact_pallas
from repro.kernels.gather_compact.ops import gather_compact_op
from repro.kernels.gather_compact.ref import gather_compact_ref

__all__ = ["gather_compact_pallas", "gather_compact_op", "gather_compact_ref"]
