"""Back-compat wrapper for stream compaction (Conditional Buffer).

Delegates to the dispatch layer (kernels/dispatch.py). ``use_pallas=True``
exercises the Pallas kernel body (interpreted on CPU, compiled on TPU);
``use_pallas=False`` runs the pure-jnp oracle. The serving hot path should
call ``dispatch.gather_compact_op`` instead.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels import dispatch


def gather_compact_op(x: jnp.ndarray, hard_mask: jnp.ndarray, capacity: int,
                      *, use_pallas: bool = True
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, ...); hard_mask: (B,). Returns (slab (C, ...), slab_ids (C,),
    n_hard ())."""
    backend = "pallas" if use_pallas else "ref"
    return dispatch.gather_compact_op(x, hard_mask, capacity,
                                      backend=backend)
