"""Jit'd public wrapper for stream compaction (Conditional Buffer).

Flattens trailing feature dims, dispatches Pallas (interpret on CPU) or the
jnp oracle, and restores the feature shape on the slab.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.gather_compact.kernel import gather_compact_pallas
from repro.kernels.gather_compact.ref import gather_compact_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("capacity", "use_pallas"))
def gather_compact_op(x: jnp.ndarray, hard_mask: jnp.ndarray, capacity: int,
                      *, use_pallas: bool = True
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, ...); hard_mask: (B,). Returns (slab (C, ...), slab_ids (C,),
    n_hard ())."""
    B = x.shape[0]
    feat = x.shape[1:]
    xf = x.reshape(B, -1)
    if use_pallas:
        slab, ids, nh = gather_compact_pallas(xf, hard_mask, capacity,
                                              interpret=_on_cpu())
    else:
        slab, ids, nh = gather_compact_ref(xf, hard_mask, capacity)
    return slab.reshape((capacity,) + feat), ids, nh
