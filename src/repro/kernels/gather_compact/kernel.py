"""Pallas TPU kernel: stream compaction — the Conditional Buffer (§III-C.2).

The FPGA conditional buffer drops an exiting sample's feature map by
invalidating its addresses in one cycle. The TPU analogue: a stable
prefix-sum partition computed ONCE into SMEM scratch (grid step 0), then a
row-gather of surviving samples streamed feature-tile by feature-tile —
x is read once from HBM and only the compacted slab is written back, so the
stage-2 input slab never round-trips through host memory (the paper keeps
the decision on-chip for exactly this reason).

Grid: (F / bf,), feature axis only; the (B,) mask and the (C,) take-vector
live in SMEM across all steps. Dynamic row-gather inside a tile lowers to
the TPU dynamic-gather over sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_compact_kernel(mask_ref, x_ref, slab_ref, ids_ref, nhard_ref,
                           take_ref, *, batch: int, capacity: int):
    j = pl.program_id(0)

    # -- step 0: prefix-sum partition -> take vector + ids + n_hard (SMEM) ----
    @pl.when(j == 0)
    def _():
        hard = mask_ref[...].astype(jnp.int32)              # (B,)
        n_hard = jnp.sum(hard)
        pos_hard = jnp.cumsum(hard) - 1                     # slot among hard
        pos_easy = jnp.cumsum(1 - hard) - 1                 # slot among easy
        slot = jnp.where(hard == 1, pos_hard, n_hard + pos_easy)
        perm = jnp.zeros((batch,), jnp.int32).at[slot].set(
            jnp.arange(batch, dtype=jnp.int32))
        take = perm[:capacity] if capacity <= batch else jnp.pad(
            perm, (0, capacity - batch))
        valid = jnp.arange(capacity, dtype=jnp.int32) < jnp.minimum(
            n_hard, capacity)
        take = jnp.where(valid, take, 0)
        take_ref[...] = take
        ids_ref[...] = jnp.where(valid, take, -1)
        nhard_ref[0] = n_hard

    # -- every step: gather surviving rows for this feature tile --------------
    xt = x_ref[...]                                         # (B, bf)
    slab_ref[...] = jnp.take(xt, take_ref[...], axis=0)     # (C, bf)


@functools.partial(jax.jit, static_argnames=("capacity", "block_f",
                                             "interpret"))
def gather_compact_pallas(x: jnp.ndarray, hard_mask: jnp.ndarray,
                          capacity: int, *, block_f: int = 2048,
                          interpret: bool = False):
    """x: (B, F); hard_mask: (B,) bool. Returns (slab (C, F), slab_ids (C,),
    n_hard ())."""
    B, F = x.shape
    bf = min(block_f, F)
    n_f = pl.cdiv(F, bf)

    kernel = functools.partial(_gather_compact_kernel, batch=B,
                               capacity=capacity)
    slab, ids, nh = pl.pallas_call(
        kernel,
        grid=(n_f,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # mask (B,)
            pl.BlockSpec((B, bf), lambda j: (0, j)),        # x feature tile
        ],
        out_specs=(
            pl.BlockSpec((capacity, bf), lambda j: (0, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),          # ids (C,)
            pl.BlockSpec(memory_space=pltpu.SMEM),          # n_hard (1,)
        ),
        out_shape=(
            jax.ShapeDtypeStruct((capacity, F), x.dtype),
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        scratch_shapes=[
            pltpu.SMEM((capacity,), jnp.int32),             # take vector
        ],
        interpret=interpret,
    )(hard_mask, x)
    return slab, ids, nh[0]
