"""Pure-jnp oracle for the stream-compaction (Conditional Buffer) kernel.

Semantics contract (paper §III-C.2 mapped to static shapes):
  Given x (B, F), hard_mask (B,) bool and a static capacity C:
    - slab (C, F): rows of x whose mask is True, in original order (stable),
      padded with x's row 0 for flush slots (the paper flushes the stage-2
      pipeline with unused data + an unused Sample ID);
    - slab_ids (C,): the original row index (Sample ID) per slab row, -1 for
      flush slots and for overflow-dropped rows;
    - n_hard (): total number of True rows (may exceed C: overflow).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def gather_compact_ref(x: jnp.ndarray, hard_mask: jnp.ndarray, capacity: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b = hard_mask.shape[0]
    hard = hard_mask.astype(jnp.int32)
    n_hard = jnp.sum(hard)
    pos_hard = jnp.cumsum(hard) - 1
    pos_easy = jnp.cumsum(1 - hard) - 1
    slot = jnp.where(hard_mask, pos_hard, n_hard + pos_easy)
    perm = jnp.zeros((b,), jnp.int32).at[slot].set(
        jnp.arange(b, dtype=jnp.int32))
    take = perm[:capacity]
    valid = jnp.arange(capacity) < jnp.minimum(n_hard, capacity)
    take = jnp.where(valid, take, 0)
    slab = jnp.take(x, take, axis=0)
    slab_ids = jnp.where(valid, take, -1).astype(jnp.int32)
    return slab, slab_ids, n_hard
