"""Pure-jnp oracle for the Exit Decision kernel (paper Eqs. 2-4).

Semantics contract (shared with the Pallas kernel):
    exit_mask[i] = max_softmax(logits[i]) > c_thr          (Eq. 2)
  computed division-free and max-shifted (Eq. 4 + stabilization):
    1 > c_thr * sum_j exp(x_ij - m_i),  m_i = max_j x_ij
    conf[i] = 1 / sum_j exp(x_ij - m_i)
    pred[i] = argmax_j x_ij   (first occurrence on ties, like jnp.argmax)
All internal arithmetic in fp32 regardless of input dtype.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def exit_decision_ref(logits: jnp.ndarray, c_thr: float
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """logits: (B, V) any float. Returns (exit bool (B,), pred i32 (B,),
    conf f32 (B,))."""
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1)
    s = jnp.sum(jnp.exp(x - m[:, None]), axis=-1)
    conf = 1.0 / s
    pred = jnp.argmax(x, axis=-1).astype(jnp.int32)
    exit_mask = jnp.float32(c_thr) * s < 1.0
    return exit_mask, pred, conf
