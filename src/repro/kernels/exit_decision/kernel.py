"""Pallas TPU kernel: fused online Exit Decision (paper §III-C.1).

The FPGA design evaluates Eq. (4) ``max_i exp(x_i) > C_thr * sum_j exp(x_j)``
with an fp32 exp/adder/comparator tree. The TPU-native form max-shifts the
exponent so the left side collapses to exp(0) = 1 and the entire decision is
ONE online reduction over the class axis:

    1 > C_thr * sum_j exp(x_j - m),   m = max_j x_j

tracked with the same (m, l) running pair flash attention uses. The kernel
streams vocab tiles (V up to 152k never fits VMEM at once), keeping per-row
(m, sum-exp, argmax) accumulators in VMEM scratch, and emits the fused triple
(exit_mask, argmax class, confidence) on the last tile — so the stage-1
logits are read from HBM exactly once and no (B, V) softmax is ever
materialized.

Grid: (B/bb, V/bv), vocab axis innermost (sequential on TPU, so scratch
accumulators carry across vocab tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _exit_decision_kernel(thr_ref, x_ref, exit_ref, pred_ref, conf_ref,
                          m_ref, s_ref, am_ref, *, n_v_blocks: int, vocab: int,
                          block_v: int):
    j = pl.program_id(1)

    # -- reset accumulators at the first vocab tile ---------------------------
    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        am_ref[...] = jnp.zeros_like(am_ref)

    x = x_ref[...].astype(jnp.float32)                     # (bb, bv)
    bb, bv = x.shape
    col = j * block_v + jax.lax.broadcasted_iota(jnp.int32, (bb, bv), 1)
    x = jnp.where(col < vocab, x, NEG_INF)                 # mask vocab padding

    bm = jnp.max(x, axis=-1, keepdims=True)                # (bb, 1) tile max
    # first-occurrence argmax inside the tile
    hit = x == bm
    bidx = jnp.min(jnp.where(hit, col, vocab), axis=-1, keepdims=True)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, bm)
    s_ref[...] = (s_ref[...] * jnp.exp(m_old - m_new)
                  + jnp.sum(jnp.exp(x - m_new), axis=-1, keepdims=True))
    # strictly-greater update keeps the earliest global argmax on ties
    am_ref[...] = jnp.where(bm > m_old, bidx, am_ref[...])
    m_ref[...] = m_new

    # -- finalize on the last vocab tile --------------------------------------
    @pl.when(j == n_v_blocks - 1)
    def _():
        s = s_ref[...]                                     # (bb, 1)
        thr = thr_ref[0]
        exit_ref[...] = thr * s < 1.0                      # Eq. (4), shifted
        conf_ref[...] = 1.0 / s
        pred_ref[...] = am_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_v", "interpret"))
def exit_decision_pallas(logits: jnp.ndarray, c_thr, *, block_b: int = 8,
                         block_v: int = 2048, interpret: bool = False):
    """logits: (B, V). Returns (exit bool (B,), pred i32 (B,), conf f32 (B,))."""
    B, V = logits.shape
    bb = min(block_b, B)
    bv = min(block_v, max(128, V))
    n_b = pl.cdiv(B, bb)
    n_v = pl.cdiv(V, bv)
    thr = jnp.asarray([c_thr], jnp.float32)

    kernel = functools.partial(_exit_decision_kernel, n_v_blocks=n_v,
                               vocab=V, block_v=bv)
    out_shape = (
        jax.ShapeDtypeStruct((B, 1), jnp.bool_),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        jax.ShapeDtypeStruct((B, 1), jnp.float32),
    )
    row_spec = pl.BlockSpec((bb, 1), lambda i, j: (i, 0))
    exit_m, pred, conf = pl.pallas_call(
        kernel,
        grid=(n_b, n_v),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),         # threshold scalar
            pl.BlockSpec((bb, bv), lambda i, j: (i, j)),   # logits tile
        ],
        out_specs=(row_spec, row_spec, row_spec),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bb, 1), jnp.float32),              # running max m
            pltpu.VMEM((bb, 1), jnp.float32),              # running sum-exp l
            pltpu.VMEM((bb, 1), jnp.int32),                # running argmax
        ],
        interpret=interpret,
    )(thr, logits)
    return exit_m[:, 0], pred[:, 0], conf[:, 0]
