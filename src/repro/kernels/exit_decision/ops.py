"""Jit'd public wrapper for the Exit Decision kernel.

Dispatches to the Pallas kernel (interpret=True on CPU so the kernel body is
validated here; compiled on TPU), with the pure-jnp oracle available as the
off-hot-path fallback. Leading batch dims are flattened.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.exit_decision.kernel import exit_decision_pallas
from repro.kernels.exit_decision.ref import exit_decision_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def exit_decision_op(logits: jnp.ndarray, c_thr, *, use_pallas: bool = True
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused exit decision. logits: (..., V) -> (exit bool, pred i32,
    conf f32), each shaped (...,)."""
    lead = logits.shape[:-1]
    x = logits.reshape((-1, logits.shape[-1]))
    if use_pallas:
        e, p, c = exit_decision_pallas(x, c_thr, interpret=_on_cpu())
    else:
        e, p, c = exit_decision_ref(x, c_thr)
    return e.reshape(lead), p.reshape(lead), c.reshape(lead)
