"""Back-compat wrapper for the Exit Decision kernel.

Delegates to the dispatch layer (kernels/dispatch.py). ``use_pallas=True``
exercises the Pallas kernel body (interpreted on CPU, compiled on TPU) —
this is what the parity sweeps in tests/ rely on; ``use_pallas=False`` runs
the pure-jnp oracle. The serving hot path should call
``dispatch.exit_decision_op`` instead, whose ``auto`` policy never pays the
interpreter tax off-TPU.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels import dispatch


def exit_decision_op(logits: jnp.ndarray, c_thr, *, use_pallas: bool = True
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused exit decision. logits: (..., V) -> (exit bool, pred i32,
    conf f32), each shaped (...,)."""
    backend = "pallas" if use_pallas else "ref"
    return dispatch.exit_decision_op(logits, c_thr, backend=backend)
