from repro.kernels.exit_decision.kernel import exit_decision_pallas
from repro.kernels.exit_decision.ops import exit_decision_op
from repro.kernels.exit_decision.ref import exit_decision_ref

__all__ = ["exit_decision_pallas", "exit_decision_op", "exit_decision_ref"]
