"""Kernel dispatch: one policy deciding which implementation serves a call.

The serving runtime (runtime/serve_loop.py) and the one-shot pipeline
(core/early_exit.serve_batch) route every exit-decision and conditional-
buffer call through this module instead of picking an implementation at the
call site. Three backends exist per kernel:

  pallas     — the compiled Pallas TPU kernel (kernel.py). Only meaningful
               on a TPU backend; requesting it elsewhere degrades to
               ``interpret``.
  interpret  — the same Pallas kernel body run under the Pallas interpreter.
               Validates the kernel on CPU but is orders of magnitude slower
               than XLA; used by the parity tests, never by the hot path.
  ref        — the pure-jnp oracle (ref.py). Fast under XLA on CPU/GPU and
               the semantics contract the kernels are tested against.

Resolution order: explicit ``backend=`` argument > ``set_backend()`` >
``REPRO_KERNEL_BACKEND`` env var > ``auto``. ``auto`` picks ``pallas`` on
TPU and ``ref`` everywhere else — i.e. the hot path always runs compiled
code, and the interpreter is something you must ask for.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.exit_decision.kernel import exit_decision_pallas
from repro.kernels.exit_decision.ref import exit_decision_ref
from repro.kernels.fused_dispatch.kernel import fused_dispatch_pallas
from repro.kernels.fused_dispatch.ref import fused_dispatch_ref
from repro.kernels.gather_compact.kernel import gather_compact_pallas
from repro.kernels.gather_compact.ref import gather_compact_ref
from repro.kernels.paged_attention.kernel import paged_gather_append_pallas
from repro.kernels.paged_attention.ref import paged_gather_append_ref

BACKENDS = ("auto", "pallas", "interpret", "ref")
_ENV_VAR = "REPRO_KERNEL_BACKEND"
_override: Optional[str] = None
_resolve_cache: dict = {}
_n_resolutions = 0


def n_backend_resolutions() -> int:
    """Lifetime count of ``kernel_backend`` memo MISSES (fresh platform
    probes + validations). A steadily climbing value under a steady-state
    server means something is thrashing the memo (e.g. a test sweeping
    ``REPRO_FAULT_LOG``-style env state or ``set_backend`` churn) — the
    observability plane exports it as
    ``repro_backend_resolutions_total``."""
    return _n_resolutions


@functools.lru_cache(maxsize=1)
def _on_tpu() -> bool:
    # jax.default_backend() initializes the platform — not free, and the
    # answer cannot change within a process, so ask exactly once.
    return jax.default_backend() == "tpu"


def set_backend(name: Optional[str]) -> None:
    """Process-wide backend override (None restores auto/env resolution)."""
    global _override
    if name is not None and name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"expected one of {BACKENDS}")
    _override = name
    _resolve_cache.clear()


def kernel_backend(backend: Optional[str] = None) -> str:
    """Resolve to a concrete backend: 'pallas' | 'interpret' | 'ref'.

    Memoized on (explicit arg, override, env var): the env var stays a live
    input — tests monkeypatch it — but the platform probe and validation run
    once per distinct key instead of on every hot-loop op call."""
    env = os.environ.get(_ENV_VAR)
    key = (backend, _override, env)
    hit = _resolve_cache.get(key)
    if hit is not None:
        return hit
    global _n_resolutions
    _n_resolutions += 1
    req = backend or _override or env or "auto"
    if req not in BACKENDS:
        raise ValueError(f"unknown kernel backend {req!r}; "
                         f"expected one of {BACKENDS}")
    if req == "auto":
        res = "pallas" if _on_tpu() else "ref"
    elif req == "pallas" and not _on_tpu():
        res = "interpret"           # kernel body still runs, just interpreted
    else:
        res = req
    _resolve_cache[key] = res
    return res


# ---------------------------------------------------------------------------
# dispatched ops (the serving hot path calls these)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def _exit_decision(logits, c_thr, backend: str):
    if backend == "ref":
        return exit_decision_ref(logits, c_thr)
    return exit_decision_pallas(logits, c_thr,
                                interpret=(backend == "interpret"))


def exit_decision_op(logits: jnp.ndarray, c_thr, *,
                     backend: Optional[str] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused exit decision (Eq. 4). logits: (..., V) -> (exit bool, pred
    i32, conf f32), each shaped (...,). One streamed read of the logits;
    no materialized softmax on any backend."""
    lead = logits.shape[:-1]
    x = logits.reshape((-1, logits.shape[-1]))
    e, p, c = _exit_decision(x, c_thr, kernel_backend(backend))
    return e.reshape(lead), p.reshape(lead), c.reshape(lead)


@functools.partial(jax.jit, static_argnames=("capacity", "backend"))
def _gather_compact(x, hard_mask, capacity: int, backend: str):
    if backend == "ref":
        return gather_compact_ref(x, hard_mask, capacity)
    return gather_compact_pallas(x, hard_mask, capacity,
                                 interpret=(backend == "interpret"))


def gather_compact_op(x: jnp.ndarray, hard_mask: jnp.ndarray, capacity: int,
                      *, backend: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Conditional-buffer compaction. x: (B, ...); hard_mask: (B,) bool.
    Returns (slab (capacity, ...), slab_ids (capacity,) int32 with -1 flush
    slots, n_hard ())."""
    B = x.shape[0]
    feat = x.shape[1:]
    xf = x.reshape(B, -1)
    slab, ids, nh = _gather_compact(xf, hard_mask, capacity,
                                    kernel_backend(backend))
    return slab.reshape((capacity,) + feat), ids, nh


def paged_gather_append(a_pool, b_pool, a_new, b_new, block_tables, pos, *,
                        backend: str):
    """Traceable paged-cache gather+append body for use INSIDE an enclosing
    jit (the paged decode step calls this per attention layer). ``backend``
    must already be resolved (call ``kernel_backend`` outside the trace).

    a_pool/b_pool: (P, page, *F) page pools (page 0 = null, all-zeros);
    a_new/b_new: (B, *F) new-token rows; block_tables: (B, M) i32; pos:
    (B,) i32 linear write positions (>= M*page skips the append). Returns
    (gathered_a (B, M, page, *Fa), gathered_b, a_pool', b_pool') — the
    gathered slabs reshaped to (B, M*page, *F) are exactly the dense cache
    rows, appended token included. Feature dims are flattened for the
    kernel and restored here, so every backend is bitwise-identical."""
    fa, fb = a_pool.shape[2:], b_pool.shape[2:]
    n_pages, page = a_pool.shape[:2]
    B, M = block_tables.shape
    with jax.named_scope("paged_gather_append"):
        if backend == "ref":
            ga, gb, ap, bp = paged_gather_append_ref(
                a_pool, b_pool, a_new, b_new, block_tables, pos)
            return ga, gb, ap, bp
        ga, gb, ap, bp = paged_gather_append_pallas(
            a_pool.reshape(n_pages, page, -1),
            b_pool.reshape(n_pages, page, -1),
            a_new.reshape(B, -1), b_new.reshape(B, -1), block_tables, pos,
            interpret=(backend == "interpret"))
        return (ga.reshape((B, M, page) + fa), gb.reshape((B, M, page) + fb),
                ap.reshape(a_pool.shape), bp.reshape(b_pool.shape))


@functools.partial(jax.jit, static_argnames=("backend",),
                   donate_argnums=(0, 1))
def _paged_gather_append_donated(a_pool, b_pool, a_new, b_new, block_tables,
                                 pos, backend: str):
    return paged_gather_append(a_pool, b_pool, a_new, b_new, block_tables,
                               pos, backend=backend)


@functools.partial(jax.jit, static_argnames=("backend",))
def _paged_gather_append_copy(a_pool, b_pool, a_new, b_new, block_tables,
                              pos, backend: str):
    return paged_gather_append(a_pool, b_pool, a_new, b_new, block_tables,
                               pos, backend=backend)


def paged_gather_append_op(a_pool, b_pool, a_new, b_new, block_tables, pos,
                           *, backend: Optional[str] = None,
                           donate: bool = True):
    """Standalone jitted paged gather+append. By default the pools are
    DONATED (the appended pools reuse their buffers); ``donate=False``
    keeps the inputs alive for paged-vs-dense comparisons."""
    fn = _paged_gather_append_donated if donate else _paged_gather_append_copy
    return fn(a_pool, b_pool, a_new, b_new, block_tables, pos,
              backend=kernel_backend(backend))


def fused_dispatch(logits, active, sample_ids, payload, ring, c_thr, *,
                   backend: str):
    """Traceable fused dispatch body (decision + compaction + ring enqueue
    in one pass) for use INSIDE an enclosing jit — the pool tick calls this
    so the whole decode step stays one program. ``backend`` must already be
    resolved (call ``kernel_backend`` outside the trace).

    logits (B, V); active (B,) bool or None; sample_ids (B,) i32; payload
    pytree of (B, *row) leaves matching ring['data']. Returns
    (ring', exit_mask, pred, conf, src, n_hard); rows past the ring's free
    space are NOT written (caller handles overflow via src)."""
    with jax.named_scope("fused_dispatch"):
        if backend == "ref":
            return fused_dispatch_ref(logits, active, sample_ids, payload,
                                      ring, c_thr)
        return fused_dispatch_pallas(logits, active, sample_ids, payload,
                                     ring, c_thr,
                                     interpret=(backend == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",),
                   donate_argnums=(4,))
def _fused_dispatch_donated(logits, active, sample_ids, payload, ring,
                            c_thr, backend: str):
    return fused_dispatch(logits, active, sample_ids, payload, ring, c_thr,
                          backend=backend)


@functools.partial(jax.jit, static_argnames=("backend",))
def _fused_dispatch_copy(logits, active, sample_ids, payload, ring, c_thr,
                         backend: str):
    return fused_dispatch(logits, active, sample_ids, payload, ring, c_thr,
                          backend=backend)


def fused_dispatch_op(logits: jnp.ndarray, active: Optional[jnp.ndarray],
                      sample_ids: jnp.ndarray, payload, ring: dict, c_thr,
                      *, backend: Optional[str] = None, donate: bool = True):
    """Standalone jitted fused dispatch. By default the ring argument is
    DONATED (its buffers are reused for the output ring — pass a ring you
    no longer read); ``donate=False`` keeps the input ring alive for
    composed-vs-fused comparisons."""
    fn = _fused_dispatch_donated if donate else _fused_dispatch_copy
    return fn(logits, active, sample_ids, payload, ring, c_thr,
              backend=kernel_backend(backend))
