"""Pure-jnp oracle for the paged KV-cache gather+append primitive.

The paged cache stores K/V in fixed-size pages inside a shared pool
``(n_pages, page, *feat)``; each decode row owns an int32 block-table row
``(max_pages,)`` of pool page indices. Page 0 is the NULL page: never
allocated, always all-zeros — unused block-table tail entries point at it,
so a gather over a row's full table reconstructs exactly the dense cache
row (dense positions past the written prefix are zeros too). That identity
is what makes paged-vs-dense decode parity *bitwise*, not approximate.

One call does, per row, in this order (matching the dense write-then-attend
decode step):

  1. APPEND — write the row's new-token features into its tail page at
     linear position ``pos[b]`` (page ``pos//page``, row ``pos%page``).
     Rows with ``pos >= max_pages*page`` (the parked/flush sentinel) write
     nothing.
  2. GATHER — read the row's pages out of the (already appended) pool into
     ``(B, max_pages, page, *feat)``; reshaped to ``(B, max_pages*page,
     *feat)`` this IS the dense cache row.

Two pools (K and V for attention; latent and rope for MLA) move through a
single call so the serving hot path pays one primitive per layer.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def paged_gather_append_ref(a_pool: jnp.ndarray, b_pool: jnp.ndarray,
                            a_new: jnp.ndarray, b_new: jnp.ndarray,
                            block_tables: jnp.ndarray, pos: jnp.ndarray
                            ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                       jnp.ndarray, jnp.ndarray]:
    """a_pool: (P, page, *Fa); b_pool: (P, page, *Fb); a_new: (B, *Fa);
    b_new: (B, *Fb); block_tables: (B, M) i32 pool page ids (0 = null);
    pos: (B,) i32 linear write position, >= M*page disables the append.

    Returns (gathered_a (B, M, page, *Fa), gathered_b, a_pool', b_pool')."""
    n_pages, page = a_pool.shape[:2]
    B, M = block_tables.shape
    pg = jnp.clip(pos // page, 0, M - 1)
    tail_page = jnp.take_along_axis(block_tables, pg[:, None], axis=1)[:, 0]
    # rows whose pos is out of range (the parked/flush sentinel) or whose
    # tail entry is the null page scatter at index n_pages -> dropped; the
    # null page stays all-zeros no matter what the caller hands us
    in_range = (pos < M * page) & (tail_page > 0)
    dst_page = jnp.where(in_range, tail_page, n_pages)
    dst_row = jnp.where(in_range, pos % page, 0)
    a_pool = a_pool.at[dst_page, dst_row].set(a_new, mode="drop")
    b_pool = b_pool.at[dst_page, dst_row].set(b_new, mode="drop")
    gathered_a = a_pool[block_tables]            # (B, M, page, *Fa)
    gathered_b = b_pool[block_tables]
    return gathered_a, gathered_b, a_pool, b_pool
