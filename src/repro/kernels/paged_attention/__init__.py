from repro.kernels.paged_attention.kernel import paged_gather_append_pallas
from repro.kernels.paged_attention.ops import paged_gather_append_op
from repro.kernels.paged_attention.ref import paged_gather_append_ref

__all__ = ["paged_gather_append_pallas", "paged_gather_append_op",
           "paged_gather_append_ref"]
