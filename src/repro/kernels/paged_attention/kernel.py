"""Pallas TPU kernel: paged KV-cache gather + tail-page append, one launch.

The block table and the per-row write positions ride as SCALAR-PREFETCH
operands (``pltpu.PrefetchScalarGridSpec``): they land in SMEM before the
body runs, so the pool BlockSpec's index map can look up ``bt[b, p]`` and
DMA exactly the pages each grid cell touches — the canonical Pallas
block-table paged-attention mechanism. Grid is ``(B, max_pages)``: cell
(b, p) streams pool page ``bt[b, p]`` through VMEM once, merges the row's
new-token features in-register when (b, p) is the row's tail cell, and
writes the merged page to BOTH the gathered output (``(B, max_pages, page,
F)`` — reshaped, the dense cache row) and back to the pool in place
(``input_output_aliases``: the pool never copies).

Null-page discipline: page 0 is shared by every unused block-table entry.
Its cells never satisfy the append predicate (``bt[b,p] > 0`` fails), so
each visit rewrites the identical all-zero bytes — the non-injective output
index map is deterministic by construction. Pool pages referenced by no
table entry are never visited and keep their bytes through the alias.

Both pools (K+V, or MLA latent+rope) move in the same launch; feature dims
are pre-flattened by the dispatch layer to ``(P, page, F)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _paged_gather_append_kernel(bt_ref, pos_ref, ap_ref, bp_ref, an_ref,
                                bn_ref, ga_ref, gb_ref, apo_ref, bpo_ref, *,
                                page: int, max_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(1)
    pos = pos_ref[b]
    # tail cell: this grid cell's page holds the row's write position, the
    # position is in range (not the parked/flush sentinel), and the page is
    # a real allocation (never append into the shared null page 0)
    tail = ((pos // page == p) & (pos < max_pages * page)
            & (bt_ref[b, p] > 0))
    rows = jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)
    write = tail & (rows == pos % page)                  # (page, 1)
    a_merged = jnp.where(write, an_ref[0][None, :], ap_ref[0])
    b_merged = jnp.where(write, bn_ref[0][None, :], bp_ref[0])
    ga_ref[0, 0] = a_merged
    gb_ref[0, 0] = b_merged
    apo_ref[0] = a_merged
    bpo_ref[0] = b_merged


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_gather_append_pallas(a_pool: jnp.ndarray, b_pool: jnp.ndarray,
                               a_new: jnp.ndarray, b_new: jnp.ndarray,
                               block_tables: jnp.ndarray, pos: jnp.ndarray,
                               *, interpret: bool = False):
    """a_pool: (P, page, Fa); b_pool: (P, page, Fb); a_new: (B, Fa);
    b_new: (B, Fb); block_tables: (B, M) i32; pos: (B,) i32. Returns
    (gathered_a (B, M, page, Fa), gathered_b, a_pool', b_pool')."""
    n_pages, page, fa = a_pool.shape
    fb = b_pool.shape[-1]
    B, M = block_tables.shape

    kernel = functools.partial(_paged_gather_append_kernel, page=page,
                               max_pages=M)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block_tables, pos -> SMEM
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, page, fa), lambda b, p, bt, pos: (bt[b, p], 0, 0)),
            pl.BlockSpec((1, page, fb), lambda b, p, bt, pos: (bt[b, p], 0, 0)),
            pl.BlockSpec((1, fa), lambda b, p, bt, pos: (b, 0)),
            pl.BlockSpec((1, fb), lambda b, p, bt, pos: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, page, fa),
                         lambda b, p, bt, pos: (b, p, 0, 0)),
            pl.BlockSpec((1, 1, page, fb),
                         lambda b, p, bt, pos: (b, p, 0, 0)),
            pl.BlockSpec((1, page, fa), lambda b, p, bt, pos: (bt[b, p], 0, 0)),
            pl.BlockSpec((1, page, fb), lambda b, p, bt, pos: (bt[b, p], 0, 0)),
        ],
    )
    with jax.named_scope("paged_gather_append_kernel"):
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((B, M, page, fa), a_pool.dtype),
                jax.ShapeDtypeStruct((B, M, page, fb), b_pool.dtype),
                jax.ShapeDtypeStruct(a_pool.shape, a_pool.dtype),
                jax.ShapeDtypeStruct(b_pool.shape, b_pool.dtype),
            ],
            # flat pallas_call inputs = (bt, pos, a_pool, b_pool, a_new,
            # b_new); the pools alias the in-place pool outputs (2 and 3)
            input_output_aliases={2: 2, 3: 3},
            interpret=interpret,
        )(block_tables, pos, a_pool, b_pool, a_new, b_new)
