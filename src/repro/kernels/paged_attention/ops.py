"""Back-compat wrapper for the paged-cache gather+append primitive.

Delegates to the dispatch layer (kernels/dispatch.py). ``use_pallas=True``
exercises the Pallas kernel body (interpreted on CPU, compiled on TPU);
``use_pallas=False`` runs the pure-jnp oracle. The serving hot path should
call ``dispatch.paged_gather_append_op`` (or the traceable
``dispatch.paged_gather_append`` inside an enclosing jit) instead.
"""
from __future__ import annotations

from repro.kernels import dispatch


def paged_gather_append_op(a_pool, b_pool, a_new, b_new, block_tables, pos,
                           *, use_pallas: bool = True, donate: bool = True):
    """a_pool/b_pool: (P, page, *F); a_new/b_new: (B, *F); block_tables:
    (B, M) i32; pos: (B,) i32. Returns (gathered_a (B, M, page, *Fa),
    gathered_b, a_pool', b_pool')."""
    backend = "pallas" if use_pallas else "ref"
    return dispatch.paged_gather_append_op(a_pool, b_pool, a_new, b_new,
                                           block_tables, pos,
                                           backend=backend, donate=donate)
