"""Zero-downtime live migration under drift: a mid-trace full re-plan
applied to a RUNNING scheduler vs a static server that keeps its phase-A
provisioning.

ATHEENA sizes the stage split for a measured exit probability p; PR 5's
controller re-solves the split when the live q drifts, but could only
*report* the new plan — actually moving a serving pool onto new chips
meant draining it offline. The live migrator (``runtime/migration.py``)
closes that gap: QUIESCE -> SNAPSHOT -> RE-PLACE -> RESUME on the running
scheduler, with compensations rolling back to the old placement on any
failure. This benchmark measures what that buys and what it costs, on the
same semi-synthetic drift workload as ``serve_drift`` (analytic
confidences + real matmul burn — see that module's rationale):

  * **static** — provisioned for phase A (capacity ~= p * slots, chips
    split by ``proportional(p)``), threshold fixed; when the trace shifts
    to the hard phase the stage-2 bucket saturates and goodput pays the
    off-design penalty;
  * **live-migrated** — identical until the admission front crosses the
    phase boundary, then ONE live migration re-sizes the bucket to the
    shifted hard rate q_C and (when the runner exposes >= 2 devices, as
    the CI perf-gate job does via XLA_FLAGS) re-splits the chips to
    ``proportional(q_C)`` — all without dropping a request.

Hard-gated contract (``benchmarks/compare.py``):

  * ``dropped_requests`` == 0 and ``stream_equivalence`` — every sample's
    token stream survives the migration bitwise-identical to the analytic
    reference (zero downtime means zero *damage*, not just zero refusals);
  * ``migration_pause_p99_ms`` below ``PAUSE_BUDGET_MS`` — the admission
    pause is bounded;
  * ``n_rollbacks`` == 0 — the fault-free path never trips compensation;
  * ``migrated_vs_static_goodput_ratio`` — the re-plan must recover real
    goodput, not just complete.

Run via ``PYTHONPATH=src python -m benchmarks.run --only serve_migration
[--json]``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax

from benchmarks.common import table
from benchmarks.serve_drift import (PROVISIONED_P, _S, _requests, conf_of,
                                    difficulty_trace, drift_fns,
                                    phase_threshold, token_of)
from repro.core.stage_mesh import StageMeshPlan, stage2_capacity
from repro.runtime import serve_loop as SL
from repro.runtime.migration import MigrationPlan
from repro.runtime.scheduler import ContinuousScheduler
from repro.runtime.stage_executor import StagePlacement

PAUSE_BUDGET_MS = 2000.0    # generous CI bound; locally the pause is ~3-10ms


class MigrateAt:
    """Controller shim: arms ONE live migration when the admission front
    crosses ``boundary`` (same front estimate as serve_drift's q-oracle)."""

    def __init__(self, boundary: int, make_plan, n_slots: int):
        self.boundary = boundary
        self.make_plan = make_plan
        self.n_slots = n_slots
        self.fired = False

    def on_tick(self, sched, n_decisions, n_hard, confidences=None) -> None:
        if self.fired:
            return
        front = max(0, sched.stats.n_samples - self.n_slots // 2)
        if front >= self.boundary:
            self.fired = True
            sched.request_migration(self.make_plan())


def _pass(fns, sc, n, n_tokens, n_slots, max_len, placement=None,
          attach=None):
    sched = ContinuousScheduler(fns, sc, n_slots=n_slots, max_len=max_len,
                                placement=placement)
    if attach is not None:
        attach(sched)
    for r in _requests(n, n_tokens):
        sched.submit(r)
    results = sched.run()
    makespan = sched.clock.now()
    n_tok = sum(len(v) for v in results.values())
    return n_tok / makespan, sched, results


def _audit(results, n, n_tokens):
    """(dropped, exact): dropped counts samples missing or truncated;
    exact demands every stream bitwise-equal to the analytic reference."""
    dropped = sum(1 for i in range(n)
                  if len(results.get(i, [])) != n_tokens)
    exact = all(results.get(i) == [token_of(i, t) for t in range(n_tokens)]
                for i in range(n))
    return dropped, exact


def run(fast: bool = False, iters: Optional[int] = None) -> dict:
    p = PROVISIONED_P
    n, n_tokens, n_slots = (128, 16, 8) if fast else (192, 20, 8)
    iters = iters if iters is not None else (3 if fast else 5)
    max_len = _S + n_tokens
    capacity = max(1, int(np.ceil(p * n_slots)))
    diff = difficulty_trace(n)
    fns = drift_fns(diff)

    b = n // 2
    thr0 = phase_threshold(diff, range(0, n // 4), n_tokens, p)
    # the shifted phase's hard rate at the FIXED phase-A threshold — what
    # the migrated server re-provisions for (the static one eats it)
    sids_c = np.arange(b, n)
    conf_c = np.concatenate([conf_of(sids_c, t, diff[sids_c])
                             for t in range(1, n_tokens)])
    q_c = float(np.mean(conf_c < thr0))
    cap_c = min(n_slots, stage2_capacity(n_slots, q_c, multiple=1))
    sc = SL.ServeConfig(capacity=capacity, queue_depth=4, c_thr=thr0)

    ndev = jax.device_count()
    resplit = ndev >= 2
    if resplit:
        devs = jax.devices()
        pl_a = StagePlacement.from_plan(
            StageMeshPlan.proportional(p, ndev), devs)
        pl_c = StagePlacement.from_plan(
            StageMeshPlan.proportional(min(0.9, max(0.1, q_c)), ndev), devs)
    else:
        pl_a = pl_c = None

    def make_plan():
        return MigrationPlan(placement=pl_c,
                             fns=(fns if pl_c is not None else None),
                             capacity=cap_c,
                             pause_budget_ms=PAUSE_BUDGET_MS,
                             reason=f"drift-replan:q={q_c:.2f}")

    def migrate_attach(sched):
        sched.controller = MigrateAt(b, make_plan, n_slots)

    passes = (("static", None), ("migrated", migrate_attach))
    for _, attach in passes:        # warmup: compiles BOTH placements
        _pass(fns, sc, n, n_tokens, n_slots, max_len, pl_a, attach)
    best = {name: (0.0, None) for name, _ in passes}
    ratios = []
    dropped_total, exact_all = 0, True
    for _ in range(iters):
        tps = {}
        for name, attach in passes:
            g, sched, results = _pass(fns, sc, n, n_tokens, n_slots,
                                      max_len, pl_a, attach)
            dropped, exact = _audit(results, n, n_tokens)
            dropped_total += dropped
            exact_all &= exact
            tps[name] = g
            if g > best[name][0]:
                best[name] = (g, sched)
        ratios.append(tps["migrated"] / tps["static"])
    ratio = float(np.median(ratios))

    st = best["static"][1].stats
    mg = best["migrated"][1].stats
    p50, p99 = mg.migration_pause_p50_ms, mg.migration_pause_p99_ms
    chips = (f"{mg.stage1_chips}+{mg.stage2_chips}" if resplit else "1")
    rows = [
        ["static", f"{best['static'][0]:,.0f}",
         f"{st.realized_q:.2f}", st.n_stalls, 0, "-"],
        ["live-migrated", f"{best['migrated'][0]:,.0f}",
         f"{mg.realized_q:.2f}", mg.n_stalls, mg.n_migrations,
         f"{p50:.1f}/{p99:.1f}"],
    ]
    txt = table(
        f"Live migration under drift (N={n}, T={n_tokens}, slots={n_slots}, "
        f"p={p}, C {capacity}->{cap_c}, q_C={q_c:.2f}, devices={ndev}, "
        f"final split={chips}, backend={jax.default_backend()})",
        ["server", "goodput tok/s", "lifetime q", "stalls", "migrations",
         "pause p50/p99 ms"], rows)
    txt += (f"\nmigrated/static {ratio:.2f}x | dropped {dropped_total} | "
            f"streams exact {exact_all} | rollbacks "
            f"{mg.n_migration_rollbacks}")
    return {
        "text": txt,
        "goodput_static": best["static"][0],
        "goodput_migrated": best["migrated"][0],
        "migrated_vs_static_goodput_ratio": ratio,
        "dropped_requests": dropped_total,
        "stream_equivalence": bool(exact_all),
        "migration_pause_p50_ms": p50,
        "migration_pause_p99_ms": p99,
        "n_migrations": mg.n_migrations,
        "n_rollbacks": mg.n_migration_rollbacks,
        "resplit": bool(resplit),
        "q_c": q_c,
        "capacity_migrated": cap_c,
    }


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--iters", type=int, default=None)
    a = ap.parse_args()
    print(run(fast=a.fast, iters=a.iters)["text"])
