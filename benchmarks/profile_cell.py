"""Dry-run profiler for the hillclimb: lower+compile one cell and print the
loop-weighted byte/flop breakdown (per-opcode + top instructions) plus
collective inventory. This is the 'profile' of the §Perf methodology —
no wall clock exists on this host, the lowered IR is the evidence.

  PYTHONPATH=src python -m benchmarks.profile_cell --arch qwen2-7b \
      --shape prefill_32k [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
import argparse
import re

import jax

from repro.configs.archs import ARCHS, SHAPES
from repro.launch import hlo_analysis as HA
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dump", default=None, help="write HLO text here")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cell = S.make_cell(ARCHS[args.arch], mesh, SHAPES[args.shape])
    with mesh:
        compiled = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                           donate_argnums=cell.donate
                           ).lower(*cell.args).compile()
    text = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(text)
    a = HA.analyze(text, breakdown=True)
    chips = mesh.size
    print(f"== {args.arch} x {args.shape} on {chips} chips ==")
    print(f"flops/dev {a['flops']:.3e}  bytes/dev {a['bytes_accessed']:.3e}  "
          f"coll/dev {a['coll_total']:.3e}  ({a['collective_count']:.0f} ops)")
    print("\n-- bytes by opcode --")
    for op, b in list(a["by_opcode"].items())[:14]:
        print(f"  {op:<28} {b:.3e}  ({b / a['bytes_accessed']:.1%})")
    print("\n-- top instructions (bytes x trips) --")
    # resolve op_name metadata for the top entries
    meta = {}
    for m in re.finditer(r"%([\w\.\-]+) = .*op_name=\"([^\"]+)\"", text):
        meta[m.group(1)] = m.group(2)
    for b, name, op, mult in a["top"][:22]:
        hint = meta.get(name, "")[:90]
        print(f"  {b:.3e}  x{mult:<6.0f} {op:<16} {name:<28} {hint}")
    print("\n-- collectives --")
    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute"):
        if a.get(f"coll_{k}"):
            print(f"  {k:<20} {a[f'coll_{k}']:.3e}")
    mem = compiled.memory_analysis()
    print(f"\n-- memory/dev -- args {mem.argument_size_in_bytes/1e9:.2f}GB  "
          f"temp {mem.temp_size_in_bytes/1e9:.2f}GB  "
          f"output {mem.output_size_in_bytes/1e9:.2f}GB")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
