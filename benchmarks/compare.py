"""CI perf-regression gate: diff a ``benchmarks.run --json`` payload
against the committed baseline and fail on tracked-metric regressions.

    PYTHONPATH=src python -m benchmarks.compare \
        --current bench.json [--baseline benchmarks/baseline_cpu.json] \
        [--out perf_diff.json]

The baseline tracks *machine-robust* metrics — device-vs-host speedup
ratios (both servers run on the same host, so the ratio survives runner
variance), bitwise-parity booleans, and per-bench ok flags — rather than
absolute samples/sec, which CI runner churn would make flaky. Each metric
is a dotted path into the payload's ``benches`` map with a baseline value
and per-metric tolerances (noise is per-metric: latency percentiles swing
far more than speedup ratios, so one global threshold either flaps or
masks regressions). A numeric metric spec supports:

  * ``tolerance`` — relative slack (default 25%): fail when a
    higher-is-better metric drops more than ``tolerance * baseline``, or a
    lower-is-better one grows by the same margin;
  * ``abs_tolerance`` — absolute slack in the metric's own units; the
    allowed band is ``max(tolerance * |baseline|, abs_tolerance)``
    (rtol/atol composition — absolute slack keeps near-zero baselines from
    flapping, relative slack keeps large ones meaningful);
  * ``min`` / ``max`` — hard bounds enforced REGARDLESS of tolerances (a
    contract floor like "goodput ratio >= 1.3x stays >= 1.3x" even when
    the recorded baseline would tolerate lower).

Booleans must match exactly. Absolute wall seconds ride along in the diff
artifact for the perf trajectory but are untracked.

Both files carry ``schema_version`` — a mismatch fails loudly instead of
quietly diffing the wrong fields (regenerate the baseline via
``python -m benchmarks.run --fast --json`` after a schema bump).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Optional


def _lookup(tree: dict, path: str) -> Optional[Any]:
    """Resolve a dotted path ('serve_decode.q0.3.speedup') against nested
    dicts. Keys themselves may contain dots ('q0.3'), so greedily match the
    longest key prefix at each level."""
    node: Any = tree
    rest = path
    while rest:
        if not isinstance(node, dict):
            return None
        key = None
        for k in sorted(node, key=len, reverse=True):
            if rest == k or rest.startswith(k + "."):
                key = k
                break
        if key is None:
            return None
        node = node[key]
        rest = rest[len(key) + 1:]
    return node


def compare(current: dict, baseline: dict) -> dict:
    """Returns the diff report; report['ok'] is the gate verdict."""
    if current.get("schema_version") != baseline.get("schema_version"):
        return {"ok": False, "schema_mismatch": True,
                "current_schema": current.get("schema_version"),
                "baseline_schema": baseline.get("schema_version"),
                "metrics": {}}
    benches = current.get("benches", {})
    default_tol = float(baseline.get("default_tolerance", 0.25))
    report = {"ok": True, "schema_mismatch": False,
              "backend": current.get("backend"),
              "fast": current.get("fast"), "metrics": {},
              "untracked_seconds": {
                  name: rec.get("seconds")
                  for name, rec in sorted(benches.items())}}

    for path, spec in sorted(baseline.get("metrics", {}).items()):
        got = _lookup(benches, path)
        want = spec.get("value")
        entry = {"baseline": want, "current": got}
        if got is None:
            entry["status"] = "MISSING"
            report["ok"] = False
        elif isinstance(want, bool):
            entry["status"] = "ok" if got == want else "MISMATCH"
            report["ok"] &= got == want
        else:
            tol = float(spec.get("tolerance", default_tol))
            slack = max(tol * abs(want), float(spec.get("abs_tolerance",
                                                        0.0)))
            lower_is_better = spec.get("direction", "higher") == "lower"
            # tolerance bounds the regression direction only; hard min/max
            # clamp BOTH directions regardless of direction or slack
            lo = want + slack if lower_is_better else want - slack
            hi = float("inf")
            if lower_is_better:
                lo, hi = float("-inf"), lo
            if "min" in spec:
                lo = max(lo, float(spec["min"]))
            if "max" in spec:
                hi = min(hi, float(spec["max"]))
            # negated comparison so a NaN measurement FAILS the gate
            # instead of slipping through every < / > comparison as False
            bad = not (lo <= got <= hi)
            entry["delta"] = (got - want) / want if want else 0.0
            entry["bound_low"], entry["bound_high"] = lo, hi
            entry["status"] = "REGRESSION" if bad else "ok"
            report["ok"] &= not bad
        report["metrics"][path] = entry
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True,
                    help="benchmarks.run --json output to gate")
    ap.add_argument("--baseline", default="benchmarks/baseline_cpu.json")
    ap.add_argument("--out", default=None,
                    help="write the diff report here (CI artifact)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    report = compare(current, baseline)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, default=float)

    if report.get("schema_mismatch"):
        print(f"perf-gate: SCHEMA MISMATCH — current "
              f"{report['current_schema']} vs baseline "
              f"{report['baseline_schema']}; regenerate the baseline")
        return 1
    width = max((len(p) for p in report["metrics"]), default=10)
    for path, e in report["metrics"].items():
        cur = e["current"]
        cur_s = f"{cur:.3f}" if isinstance(cur, float) else str(cur)
        base = e["baseline"]
        base_s = f"{base:.3f}" if isinstance(base, float) else str(base)
        print(f"  {path:<{width}}  current={cur_s:<10} "
              f"baseline={base_s:<10} {e['status']}")
    verdict = "PASS" if report["ok"] else "FAIL"
    print(f"perf-gate: {verdict} "
          f"({sum(e['status'] != 'ok' for e in report['metrics'].values())}"
          f" failing of {len(report['metrics'])} tracked)")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
