"""Fig. 9 — Throughput-Area Pareto curves: optimized baseline (red line)
vs ATHEENA combined designs, with the q = p ± 5% robustness band.

9a analogue: the analytic optimizer's predicted points over resource
budgets. 9b analogue: runtime throughput from the two-stage queue
simulator on randomized test sequences with known q (the board-measurement
stand-in this container supports)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import table
from repro.core import dse, perf_model as pm
from repro.core.tap import combine
from repro.models.cnn import b_lenet
from repro.core.conditional import simulate_two_stage_queue

P_PAPER = 0.25
BUDGETS = (32, 64, 96, 128, 192, 256, 384, 512)


def run(n_seeds: int = 3) -> dict:
    cfg = b_lenet()
    w1 = pm.cnn_stage_workloads(cfg, 0) + pm.cnn_exit_workloads(cfg, 0)
    w2 = pm.cnn_stage_workloads(cfg, 1)
    wb = pm.cnn_stage_workloads(cfg, 0) + pm.cnn_stage_workloads(cfg, 1)
    tap1 = dse.cnn_tap_sa(w1, BUDGETS, n_seeds=n_seeds, name="stage1")
    tap2 = dse.cnn_tap_sa(w2, BUDGETS, n_seeds=n_seeds, name="stage2")
    base = dse.cnn_tap_sa(wb, BUDGETS, n_seeds=n_seeds, name="baseline")

    rows, curve = [], []
    rng = np.random.default_rng(0)
    for budget in BUDGETS:
        comb = combine(tap1, tap2, P_PAPER, (budget, budget))
        bpt = base.query((budget, budget))
        if comb is None or bpt is None:
            continue
        qthr = {}
        for q in (0.20, 0.25, 0.30):
            seq = (rng.random(2048) < q).astype(int)
            r = simulate_two_stage_queue(
                seq, stage1_rate=comb.stage1.throughput,
                stage2_rate=comb.stage2.throughput,
                buffer_depth=max(16, int(0.15 * 2048)))
            qthr[q] = r["throughput"]
        rows.append([budget, f"{bpt.throughput:.0f}",
                     f"{comb.design_throughput:.0f}",
                     f"{comb.design_throughput / bpt.throughput:.2f}x",
                     f"{qthr[0.20]:.0f}", f"{qthr[0.25]:.0f}",
                     f"{qthr[0.30]:.0f}"])
        curve.append({"budget": budget, "baseline": bpt.throughput,
                      "atheena": comb.design_throughput, "sim_q": qthr})
    txt = table(
        f"Fig. 9 TAP curves — B-LeNet, p={P_PAPER} (samples/s, 125MHz model)",
        ["budget(MACs)", "baseline", "ATHEENA(pred)", "gain",
         "sim q=20%", "sim q=25%", "sim q=30%"], rows)
    return {"text": txt, "curve": curve}


def main() -> None:
    print(run()["text"])


if __name__ == "__main__":
    main()
