"""Benchmark harness entry point: one benchmark per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME] [--json]``

``--json`` emits one machine-readable object on stdout — a schema-versioned
envelope (``schema_version``, the jax backend, a ``fast`` flag) around a
``benches`` map of per-bench wall seconds, pass/fail, and whatever
structured fields the benchmark returned besides its table text — so CI
can record the perf trajectory over time and ``benchmarks/compare.py`` can
gate regressions against a committed baseline. The human tables go to
stderr in that mode.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

# benchmarks/compare.py validates this before diffing; bump it whenever the
# payload shape changes so a stale baseline fails loudly instead of quietly
# comparing the wrong fields.
# v2: envelope records jax_version / device_count alongside the backend, so
# a perf diff between two CI runs is attributable to the runtime it ran on.
SCHEMA_VERSION = 2


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer SA seeds / smaller serving sets (CI smoke)")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable per-bench results on stdout")
    args = ap.parse_args(argv)

    from benchmarks import (fig9_tap, kernel_dispatch, roofline,
                            serve_continuous, serve_decode, serve_drift,
                            serve_fleet, serve_migration, serve_observed,
                            serve_paged, serve_pipeline, table1_resources,
                            table2_overhead, table3_throughput,
                            table4_networks)
    seeds = 1 if args.fast else 3
    benches = [
        ("fig9_tap", lambda: fig9_tap.run(n_seeds=seeds)),
        ("table1_resources", lambda: table1_resources.run(n_seeds=seeds)),
        ("table2_overhead", table2_overhead.run),
        ("table3_throughput", table3_throughput.run),
        ("table4_networks", lambda: table4_networks.run(n_seeds=seeds)),
        ("roofline", roofline.run),
        ("kernel_dispatch", lambda: kernel_dispatch.run(fast=args.fast)),
        ("serve_pipeline", lambda: serve_pipeline.run(fast=args.fast)),
        ("serve_decode", lambda: serve_decode.run(fast=args.fast)),
        ("serve_continuous", lambda: serve_continuous.run(fast=args.fast)),
        ("serve_paged", lambda: serve_paged.run(fast=args.fast)),
        ("serve_drift", lambda: serve_drift.run(fast=args.fast)),
        ("serve_migration", lambda: serve_migration.run(fast=args.fast)),
        ("serve_fleet", lambda: serve_fleet.run(fast=args.fast)),
        ("serve_observed", lambda: serve_observed.run(fast=args.fast)),
    ]
    if args.only and args.only not in {n for n, _ in benches}:
        ap.error(f"unknown benchmark {args.only!r}; "
                 f"choose from {[n for n, _ in benches]}")
    text_out = sys.stderr if args.json else sys.stdout
    report = {}
    failures = 0
    for name, fn in benches:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            out = fn()
            dt = time.time() - t0
            print(out["text"], file=text_out)
            print(f"[{name}: {dt:.1f}s]\n", file=text_out, flush=True)
            report[name] = {"seconds": round(dt, 3), "ok": True,
                            **{k: v for k, v in out.items() if k != "text"}}
        except Exception:
            failures += 1
            report[name] = {"seconds": round(time.time() - t0, 3),
                            "ok": False}
            print(f"[{name}: FAILED]", file=text_out, flush=True)
            traceback.print_exc()
    if args.json:
        import jax
        payload = {"schema_version": SCHEMA_VERSION,
                   "backend": jax.default_backend(),
                   "jax_version": jax.__version__,
                   "device_count": jax.device_count(),
                   "fast": bool(args.fast),
                   "benches": report}
        print(json.dumps(payload, indent=1, default=float))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
