"""Benchmark harness entry point: one benchmark per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]``
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer SA seeds (CI smoke)")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    args = ap.parse_args(argv)

    from benchmarks import (fig9_tap, roofline, table1_resources,
                            table2_overhead, table3_throughput,
                            table4_networks)
    seeds = 1 if args.fast else 3
    benches = [
        ("fig9_tap", lambda: fig9_tap.run(n_seeds=seeds)),
        ("table1_resources", lambda: table1_resources.run(n_seeds=seeds)),
        ("table2_overhead", table2_overhead.run),
        ("table3_throughput", table3_throughput.run),
        ("table4_networks", lambda: table4_networks.run(n_seeds=seeds)),
        ("roofline", roofline.run),
    ]
    failures = 0
    for name, fn in benches:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            out = fn()
            print(out["text"])
            print(f"[{name}: {time.time() - t0:.1f}s]\n", flush=True)
        except Exception:
            failures += 1
            print(f"[{name}: FAILED]", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
