"""§Roofline — render the per-(arch x shape x mesh) roofline table from the
dry-run JSON records (launch/dryrun.py --json). Pure formatting: the
numbers come from the compiled HLO via the loop-aware analyzer."""
from __future__ import annotations

import json
import os

from benchmarks.common import table

DEFAULT_FILES = ("/root/repo/dryrun_single.json", "/root/repo/dryrun_multi.json")


def _fmt_row(r) -> list:
    rl = r["roofline"]
    return [
        r["arch"], r["shape"], r["chips"],
        f"{rl['t_compute']:.4f}", f"{rl['t_memory']:.4f}",
        f"{rl['t_collective']:.4f}", rl["bottleneck"],
        f"{rl['useful_flops_frac']:.1%}", f"{rl['mfu_bound']:.2%}",
        f"{rl['throughput']:,.1f}",
    ]


def run(files=DEFAULT_FILES) -> dict:
    rows, skips, missing = [], [], []
    recs = []
    for f in files:
        if not os.path.exists(f):
            missing.append(f)
            continue
        with open(f) as fh:
            recs.extend(json.load(fh))
    for r in recs:
        if r.get("status") == "ok":
            rows.append(_fmt_row(r))
        elif r.get("status") == "skipped":
            skips.append([r["arch"], r["shape"], r["reason"][:60]])
    txt = table(
        "§Roofline — per-cell terms (seconds/step; v5e: 197TF bf16, "
        "819GB/s HBM, 50GB/s ICI)",
        ["arch", "shape", "chips", "t_comp", "t_mem", "t_coll",
         "bound", "useful-FLOPs", "MFU@bound", "samples/s"], rows)
    if skips:
        txt += "\n" + table("documented skips",
                            ["arch", "shape", "reason"], skips)
    if missing:
        txt += f"\n(missing dry-run files: {missing} — run " \
               "`python -m repro.launch.dryrun --json <f>` first)\n"
    return {"text": txt, "n_ok": len(rows), "n_skip": len(skips)}


def main() -> None:
    print(run()["text"])


if __name__ == "__main__":
    main()
