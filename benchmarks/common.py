"""Shared benchmark plumbing: table printing + a trained B-LeNet cached
per process (several tables reuse it)."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses
from repro.data.pipeline import mnist_like
from repro.models import cnn as C


def table(title: str, headers, rows) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    out = [f"== {title} ==", fmt.format(*headers),
           fmt.format(*("-" * w for w in widths))]
    out += [fmt.format(*(str(c) for c in r)) for r in rows]
    return "\n".join(out) + "\n"


@functools.lru_cache(maxsize=None)
def trained_blenet(steps: int = 150, n: int = 2048):
    """Train the paper's B-LeNet on the synthetic MNIST-like set."""
    cfg = C.b_lenet()
    data = mnist_like(n, seed=0, hard_frac=0.3)
    params = C.init_cnn(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def step(p, x, y, lr):
        def loss_fn(p):
            outs = C.forward_all_exits(p, cfg, x)
            return losses.cnn_joint_loss(outs, y, (0.3, 1.0))[0]
        return jax.tree.map(lambda a, b: a - lr * b, p,
                            jax.grad(loss_fn)(p))

    x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])
    for i in range(steps):
        lo = (i * 128) % (n - 128)
        params = step(params, x[lo:lo + 128], y[lo:lo + 128], 0.05)
    return cfg, params, data


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (jit-compiled callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
