"""Table I analogue — resource comparison of implemented design points:
three baseline (B1-B3) and three ATHEENA (A1-A3) designs at increasing
resource tiers, with limiting resource and modeled throughput."""
from __future__ import annotations

from benchmarks.common import table
from repro.core import dse, perf_model as pm
from repro.core.tap import combine
from repro.models.cnn import b_lenet

P_PAPER = 0.25
TIERS = (96, 160, 320)          # the B1/B2/B3 ~35/52/98% analogues


def run(n_seeds: int = 3) -> dict:
    cfg = b_lenet()
    w1 = pm.cnn_stage_workloads(cfg, 0) + pm.cnn_exit_workloads(cfg, 0)
    w2 = pm.cnn_stage_workloads(cfg, 1)
    wb = pm.cnn_stage_workloads(cfg, 0) + pm.cnn_stage_workloads(cfg, 1)
    budgets = sorted(set(TIERS) | {t // 2 for t in TIERS} |
                     {int(t * 0.75) for t in TIERS} | {24, 48})
    tap1 = dse.cnn_tap_sa(w1, budgets, n_seeds=n_seeds)
    tap2 = dse.cnn_tap_sa(w2, budgets, n_seeds=n_seeds)
    base = dse.cnn_tap_sa(wb, budgets, n_seeds=n_seeds)

    rows, recs = [], []
    for i, tier in enumerate(TIERS, 1):
        bpt = base.query((tier, tier))
        comb = combine(tap1, tap2, P_PAPER, (tier, tier))
        if bpt:
            rows.append([f"B{i}", int(bpt.resources[0]),
                         f"{bpt.resources[1]:.0f}", "-",
                         f"{bpt.throughput:.0f}", "1.00x"])
        if comb and bpt:
            used = comb.resources
            rows.append([f"A{i}", int(used[0]), f"{used[1]:.0f}",
                         f"{int(comb.stage1.resources[0])}+"
                         f"{int(comb.stage2.resources[0])}",
                         f"{comb.design_throughput:.0f}",
                         f"{comb.design_throughput / bpt.throughput:.2f}x"])
            recs.append({"tier": tier, "gain":
                         comb.design_throughput / bpt.throughput})

    # the paper's iso-throughput claim: resources to match max baseline
    from repro.core.tap import TAPFunction, DesignPoint, iso_throughput_resources
    comb_pts = []
    for b in budgets:
        c = combine(tap1, tap2, P_PAPER, (b, b))
        if c:
            comb_pts.append(DesignPoint(resources=c.resources,
                                        throughput=c.design_throughput))
    iso = iso_throughput_resources(TAPFunction(comb_pts), base)
    iso_line = ""
    if iso:
        iso_line = (f"\niso-throughput: ATHEENA matches the best baseline "
                    f"({iso[1]:.0f} MAC units) using {iso[0]:.0f} "
                    f"({100 * iso[2]:.0f}% of baseline resources; "
                    f"paper: 46%)\n")
    txt = table(
        f"Table I — implemented design points, B-LeNet, p={P_PAPER}",
        ["design", "MAC units", "buf(BRAM-eq)", "stage split",
         "thr (samples/s)", "gain"], rows) + iso_line
    return {"text": txt, "designs": recs, "iso": iso}


def main() -> None:
    print(run()["text"])


if __name__ == "__main__":
    main()
