"""Table III analogue — MEASURED throughput of baseline vs Early-Exit
inference on this host (the CPU row of the paper's table), plus the modeled
TPU v5e numbers from the roofline model.

The EE pipeline here is the real staged execution: stage 1 on the full
batch, exit decision, compaction, stage 2 on the hard slab only — so the
measured gain reflects genuine compute skipped, exactly like the board."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import table, time_fn, trained_blenet
from repro.core import exit_decision as ed
from repro.core.conditional import conditional_buffer, exit_merge
from repro.models import cnn as C


def _measure(batch: int = 512, c_thr: float = 0.9) -> dict:
    cfg, params, data = trained_blenet()
    x = jnp.asarray(data["x"][:batch])
    y = np.asarray(data["y"][:batch])

    @jax.jit
    def baseline(x):
        return C.forward_backbone(params, cfg, x)

    # profile p on a held-out slice, then size the stage-2 bucket
    prof_logits = C.run_exit(params, cfg, 0,
                             C.run_stage(params, cfg, 0,
                                         jnp.asarray(data["x"][batch:
                                                               batch * 2])))
    p_hard = float((~np.asarray(ed.exit_decision(prof_logits, c_thr))).mean())
    cap = max(8, int(np.ceil((p_hard + 0.1) * batch / 8)) * 8)

    @jax.jit
    def ee_pipeline(x):
        h1 = C.run_stage(params, cfg, 0, x)                  # stage-1 backbone
        exit_logits = C.run_exit(params, cfg, 0, h1)         # exit classifier
        mask, pred, conf = ed.decision_and_argmax(exit_logits, c_thr)
        ids = jnp.arange(x.shape[0], dtype=jnp.int32)
        slab, slab_ids, n_hard, ovf = conditional_buffer(h1, ids, ~mask, cap)
        final = C.run_stage(params, cfg, 1, slab)            # stage 2: slab only
        merged = exit_merge(x.shape[0], jnp.where(mask, ids, -1),
                            exit_logits, slab_ids, final)
        return merged, mask, ovf

    t_base = time_fn(baseline, x)
    t_ee = time_fn(ee_pipeline, x)
    merged, mask, ovf = ee_pipeline(x)
    acc_ee = float((np.asarray(jnp.argmax(merged, -1)) == y).mean())
    acc_b = float((np.asarray(jnp.argmax(baseline(x), -1)) == y).mean())
    return {"batch": batch, "p_hard": p_hard, "cap": cap,
            "thr_base": batch / t_base, "thr_ee": batch / t_ee,
            "acc_base": acc_b, "acc_ee": acc_ee,
            "overflow": int(ovf)}


def run() -> dict:
    m = _measure()
    # modeled TPU v5e single-chip: backbone vs EE expected-MACs ratio applied
    # to the paper's measured-class gap is left to the roofline report; here
    # we report the analytic MAC ratio for reference.
    from repro.core import perf_model as pm
    cfg, _, _ = trained_blenet()
    w1 = sum(pm.cnn_stage_workloads(cfg, 0)) + \
        sum(pm.cnn_exit_workloads(cfg, 0))
    w2 = sum(pm.cnn_stage_workloads(cfg, 1))
    mac_ratio = (w1 + w2 - sum(pm.cnn_exit_workloads(cfg, 0))) / \
        (w1 + m["p_hard"] * w2)
    rows = [
        ["LeNet backbone (measured, this host)", f"{m['thr_base']:,.0f}",
         f"{m['acc_base']:.4f}", "-"],
        ["B-LeNet EE (measured, this host)", f"{m['thr_ee']:,.0f}",
         f"{m['acc_ee']:.4f}", f"{m['thr_ee'] / m['thr_base']:.2f}x"],
        ["analytic expected-MAC gain", "-", "-", f"{mac_ratio:.2f}x"],
    ]
    txt = table(
        f"Table III — measured EE vs baseline (batch={m['batch']}, "
        f"p={m['p_hard']:.2f}, capacity={m['cap']}, overflow="
        f"{m['overflow']})",
        ["network", "samples/s", "top-1 acc", "gain"], rows)
    return {"text": txt, **m, "mac_ratio": mac_ratio}


def main() -> None:
    print(run()["text"])


if __name__ == "__main__":
    main()
